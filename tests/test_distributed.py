"""Distributed runtime tests — run in a subprocess with 8 host devices so
the single-device test session isn't polluted (jax locks device count on
first init). The subprocess rig lives in ``tests/_mesh.py``, shared with
the 2D-mesh and fault-drill suites."""
import json

import pytest

from _mesh import run_with_devices

pytestmark = pytest.mark.multidevice


class TestDistributedKMeans:
    def test_one_pass_backend_shards(self):
        """fuses_update backends psum the kernel's own (sums, counts) —
        no second pass over the shard — and match the single-device fit."""
        out = run_with_devices("""
        import jax
        from repro.api import KMeans
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.data.blobs import make_blobs

        mesh = jax.make_mesh((8,), ("data",))
        x, _ = make_blobs(4096, 16, 8, seed=3)
        est = KMeans(8, max_iter=20, backend="lloyd_xla", random_state=0)
        c0 = est.init_centroids(x)
        dk = DistributedKMeans(est, mesh)
        c, am, inertia, iters, det = dk.fit(dk.shard_data(x), c0)
        ref = KMeans(8, max_iter=20, random_state=0).fit(x, centroids=c0)
        rel = abs(float(inertia) - ref.inertia_) / abs(ref.inertia_)
        print("REL", rel)
        """)
        rel = float(out.split("REL ")[1].split()[0])
        assert rel < 1e-3

    def test_one_pass_ft_backend_shards_with_reduce_checksums(self):
        """The protected one-pass path composes with sharding: off-TPU the
        lloyd_ft backend maps to its XLA analogue, the shard-local update
        checksums psum alongside the partial (sums, counts), and a clean
        run re-verifies them after the reduce with zero detections while
        matching the single-device solution."""
        out = run_with_devices("""
        import jax
        from repro.api import FaultPolicy, KMeans
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.data.blobs import make_blobs

        mesh = jax.make_mesh((8,), ("data",))
        x, _ = make_blobs(4096, 16, 8, seed=3)
        est = KMeans(8, max_iter=20,
                     fault=FaultPolicy.correct(update_dmr=False),
                     random_state=0)
        c0 = est.init_centroids(x)
        dk = DistributedKMeans(est, mesh)
        assert dk._shard_backend().name == "lloyd_ft_xla"
        c, am, inertia, iters, det = dk.fit(dk.shard_data(x), c0)
        ref = KMeans(8, max_iter=20, random_state=0).fit(x, centroids=c0)
        rel = abs(float(inertia) - ref.inertia_) / abs(ref.inertia_)
        print("REL", rel)
        print("DET", int(det))
        """)
        rel = float(out.split("REL ")[1].split()[0])
        assert rel < 1e-3
        assert int(out.split("DET ")[1].split()[0]) == 0

    def test_matches_single_device_and_checkpoints(self, tmp_path):
        out = run_with_devices(f"""
        import jax, jax.numpy as jnp
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.core.kmeans import KMeansConfig, KMeans
        from repro.data.blobs import make_blobs
        from repro.ft.checkpoint import Checkpointer

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x, _ = make_blobs(4096, 32, 8, seed=3)
        cfg = KMeansConfig(k=8, max_iters=25, assignment="fused_ft", seed=0)
        dk = DistributedKMeans(cfg, mesh)
        c0 = KMeans(cfg).init_centroids(x)
        ck = Checkpointer(r'{tmp_path}', async_write=True)
        c, am, inertia, iters, det = dk.fit(
            dk.shard_data(x), c0, checkpointer=ck, checkpoint_interval=2)
        ck.wait()
        ref = KMeans(KMeansConfig(k=8, max_iters=25,
                                  assignment="gemm_fused", seed=0)).fit(
            x, centroids=c0)
        rel = abs(float(inertia) - float(ref.inertia)) / float(ref.inertia)
        print("REL", rel)
        print("STEPS", ck.available_steps())
        st = ck.restore()
        print("RESTORED", st["_step"], st["centroids"].shape)
        """)
        assert "REL" in out
        rel = float(out.split("REL ")[1].split()[0])
        assert rel < 1e-3
        assert "RESTORED" in out

    def test_restart_from_checkpoint_resumes(self, tmp_path):
        out = run_with_devices(f"""
        import jax, jax.numpy as jnp
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.core.kmeans import KMeansConfig, KMeans
        from repro.data.blobs import make_blobs
        from repro.ft.checkpoint import Checkpointer

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        x, _ = make_blobs(2048, 16, 4, seed=9)
        cfg = KMeansConfig(k=4, max_iters=12, tol=0.0,
                           assignment="gemm_fused", seed=0)
        dk = DistributedKMeans(cfg, mesh)
        c0 = KMeans(cfg).init_centroids(x)
        xs = dk.shard_data(x)
        ck = Checkpointer(r'{tmp_path}', async_write=False)
        # run 1: "crashes" after 6 iterations (simulated by max_iters)
        dk.fit(xs, c0, max_iters=6, checkpointer=ck, checkpoint_interval=3)
        st = ck.restore()
        # run 2: restart from snapshot, finish
        c, am, inertia, iters, det = dk.fit(
            xs, jnp.asarray(st["centroids"]),
            start_iteration=int(st["iteration"]))
        full, *_ = dk.fit(xs, c0)[:1]
        import numpy as np
        print("DIFF", float(jnp.max(jnp.abs(c - full))))
        """)
        diff = float(out.split("DIFF ")[1].split()[0])
        assert diff < 1e-3   # restart converges to the same solution

    def test_compressed_psum_error_feedback(self):
        out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import compressed_psum, quantize, dequantize

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

        def f(gl):
            gl = gl.reshape(1024)
            red, res = compressed_psum(gl, "data")
            return red[None], res[None]

        red, res = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P("data", None)),
            check_rep=False))(g)
        exact = jnp.sum(g, axis=0)
        err = float(jnp.max(jnp.abs(red[0] - exact)) /
                    jnp.max(jnp.abs(exact)))
        print("ERR", err)
        # error feedback residual is bounded by the quantization step
        print("RES", float(jnp.max(jnp.abs(res))))
        """)
        err = float(out.split("ERR ")[1].split()[0])
        assert err < 0.05    # int8 blockwise: ~1% typical

    def test_lm_train_step_runs_sharded(self):
        """End-to-end: the REAL train step (same code the dry-run lowers)
        executes on an 8-device mesh with a smoke config."""
        out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.train.steps import build_train_step
        from repro.train.optimizer import TrainConfig
        from repro.data.synthetic import TokenPipeline

        cfg = get_config("internlm2-1.8b", smoke=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = ShapeConfig("tiny", 32, 8, "train")
        # 4-step smoke: no warmup, lr high enough that descent beats noise
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                           total_steps=4, grad_accum=2)
        b = build_train_step(cfg, mesh, shape, tcfg)
        lm = b.lm
        params, axes = lm.init(jax.random.PRNGKey(0))
        from repro.dist.sharding import shard_params
        params = shard_params(mesh, params, axes)
        from repro.train.optimizer import init_opt_state
        opt = init_opt_state(params, tcfg)
        pipe = TokenPipeline(cfg.vocab_size, 32, 8)
        batch = pipe.next_batch(0)   # fixed batch: loss must descend
        losses = []
        for step in range(4):
            params, opt, m = b.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
        print("LOSSES", losses)
        """)
        losses = json.loads(out.split("LOSSES ")[1].replace("'", '"'))
        assert all(l == l for l in losses)  # finite
        assert losses[-1] < losses[0]       # structured data -> learnable


class TestElastic:
    def test_plan_rescale_drops_to_whole_tp_groups(self):
        from repro.ft.elastic import plan_rescale
        plan = plan_rescale(list(range(61)), model_parallel=8)
        assert plan.mesh_shape == (7, 8)
        assert plan.data_shards == 7

    def test_straggler_policy_two_strikes(self):
        from repro.ft.elastic import StragglerPolicy
        p = StragglerPolicy(deadline_factor=2.0, strikes=2)
        assert not p.observe(3, step_time=5.0, median_time=1.0)
        assert p.observe(3, step_time=5.0, median_time=1.0)
        p2 = StragglerPolicy(deadline_factor=2.0, strikes=2)
        assert not p2.observe(1, 5.0, 1.0)
        assert not p2.observe(1, 1.0, 1.0)   # recovered -> streak resets
        assert not p2.observe(1, 5.0, 1.0)
