"""Batched many-problem K-means: kernel/estimator bit-equality against the
single-problem path, per-problem convergence masks, the v4 autotune cache
schema (B buckets), and problem-axis sharding parity.

Pallas kernels run interpret=True (kernel bodies execute in Python on CPU).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AutotuneCache, BackendCapabilityError, BatchedKMeans,
                       batch_bucket, get_backend, shape_bucket)
from repro.api.cache import SCHEMA_VERSION
from repro.core.autotune import feasible, model_score, select_params
from repro.data.blobs import make_blobs
from repro.kernels import ops
from repro.kernels.ops import KernelParams

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BATCH_SHAPES = [
    (3, 200, 7, 33),          # every dim off-grid
    (2, 256, 8, 128),         # exact tiles
    (4, 70, 3, 16),           # tiny: block clamping
]


def _stack(b, n, f, k, seed=0):
    x = jnp.stack([make_blobs(n, f, k, seed=seed + i)[0] for i in range(b)])
    kc = jax.random.PRNGKey(seed + 99)
    c = jax.random.normal(kc, (b, k, f), jnp.float32)
    return x, c


class TestBatchedKernel:
    @pytest.mark.parametrize("b,n,k,f", BATCH_SHAPES)
    def test_bit_identical_to_single_problem_kernel(self, b, n, k, f):
        """The tentpole invariant: one batched launch == a loop of
        single-problem fused_lloyd calls, bit for bit, per problem."""
        x, c = _stack(b, n, f, k)
        p = ops.clamp_params(n, k, f, KernelParams(256, 128, 128))
        am, md, sums, counts = ops.fused_lloyd_batched(x, c, p,
                                                       interpret=True)
        assert am.shape == (b, n) and md.shape == (b, n)
        assert sums.shape == (b, k, f) and counts.shape == (b, k)
        for i in range(b):
            am1, md1, sums1, counts1 = ops.fused_lloyd(x[i], c[i], p,
                                                       interpret=True)
            np.testing.assert_array_equal(np.asarray(am[i]),
                                          np.asarray(am1))
            np.testing.assert_array_equal(np.asarray(md[i]),
                                          np.asarray(md1))
            np.testing.assert_array_equal(np.asarray(sums[i]),
                                          np.asarray(sums1))
            np.testing.assert_array_equal(np.asarray(counts[i]),
                                          np.asarray(counts1))

    def test_low_precision_dtypes_lower(self):
        b, n, k, f = 2, 96, 4, 32
        x, c = _stack(b, n, f, k)
        p = ops.clamp_params(n, k, f, KernelParams(256, 128, 128),
                             dtype=jnp.bfloat16)
        for dtype in (jnp.bfloat16, jnp.float16):
            am, md, sums, counts = ops.fused_lloyd_batched(
                x.astype(dtype), c.astype(dtype), p, interpret=True)
            assert sums.dtype == jnp.float32
            assert counts.dtype == jnp.float32
            assert md.dtype == jnp.float32
            # counts are exact whatever the tile dtype
            np.testing.assert_allclose(np.asarray(jnp.sum(counts, axis=1)),
                                       np.full(b, n), rtol=0)

    def test_batch_plan_reused_across_calls(self):
        """plan_data_batched pads the whole (B, N, F) block once; feeding
        the plan back in must give the raw-array result."""
        b, n, k, f = 2, 100, 5, 20
        x, c = _stack(b, n, f, k)
        p = ops.clamp_params(n, k, f, KernelParams(256, 128, 128))
        plan = ops.plan_data_batched(x, p)
        assert plan.xp.shape[1] % p.block_m == 0
        got = ops.fused_lloyd_batched(plan, c, interpret=True)
        want = ops.fused_lloyd_batched(x, c, p, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_plan_without_params_rejected(self):
        x, _ = _stack(2, 64, 8, 4)
        plan = ops.plan_data_batched(x)   # params=None: pads nothing
        with pytest.raises(ValueError, match="without KernelParams"):
            ops.fused_lloyd_batched(plan, jnp.zeros((2, 4, 8)))


class TestBatchedEstimator:
    def test_bit_identical_to_loop_of_fits(self):
        """fit on the (B, N, F) stack == a Python loop of B single-problem
        fits seeded ``random_state + b``, bit for bit (the contract that
        makes the batched path a pure performance move)."""
        b, n, f, k = 6, 256, 16, 4
        x, _ = _stack(b, n, f, k, seed=10)
        bkm = BatchedKMeans(n_clusters=k, max_iter=25, random_state=3)
        bkm.fit(x)
        for i in range(b):
            one = BatchedKMeans(n_clusters=k, max_iter=25,
                                random_state=3 + i).fit(x[i:i + 1])
            np.testing.assert_array_equal(
                np.asarray(one.cluster_centers_[0]),
                np.asarray(bkm.cluster_centers_[i]))
            np.testing.assert_array_equal(np.asarray(one.labels_[0]),
                                          np.asarray(bkm.labels_[i]))
            assert one.n_iter_[0] == bkm.n_iter_[i]
            assert one.inertia_[0] == bkm.inertia_[i]

    def test_convergence_mask_isolation(self):
        """One converged problem must not perturb the others: adding an
        instantly-converging problem to the batch leaves every other
        problem's full trajectory unchanged."""
        b, n, f, k = 4, 256, 8, 3
        x, _ = _stack(b, n, f, k, seed=42)
        base = BatchedKMeans(n_clusters=k, max_iter=30, random_state=5)
        base.fit(x)
        # problem 0 replaced by its own fitted centroids' data -> centroids
        # warm-started at the solution converge in one step
        warm = jnp.asarray(base.cluster_centers_)
        again = BatchedKMeans(n_clusters=k, max_iter=30, random_state=5)
        again.fit(x, centroids=warm)
        assert int(again.n_iter_[0]) <= 2     # instant convergers...
        # ...and a mixed batch (one frozen, rest live) matches per-problem
        mixed_c0 = warm.at[1:].set(
            BatchedKMeans(n_clusters=k, random_state=5)
            .init_centroids(x)[1:])
        mixed = BatchedKMeans(n_clusters=k, max_iter=30, random_state=5)
        mixed.fit(x, centroids=mixed_c0)
        solo = BatchedKMeans(n_clusters=k, max_iter=30, random_state=5)
        solo.fit(x[1:], centroids=mixed_c0[1:])
        # problems 1.. ran exactly as if problem 0 (which froze first)
        # were absent — masks freeze without desynchronizing
        np.testing.assert_array_equal(np.asarray(mixed.cluster_centers_[1:]),
                                      np.asarray(solo.cluster_centers_))
        np.testing.assert_array_equal(np.asarray(mixed.labels_[1:]),
                                      np.asarray(solo.labels_))
        np.testing.assert_array_equal(mixed.n_iter_[1:], solo.n_iter_)

    def test_frozen_problem_stops_updating(self):
        """A problem that converges at iteration t keeps exactly its
        iteration-t state while the batch keeps stepping."""
        b, n, f, k = 3, 256, 8, 3
        x, _ = _stack(b, n, f, k, seed=7)
        short = BatchedKMeans(n_clusters=k, max_iter=60, random_state=1,
                              sync_every=60).fit(x)
        # rerun with a larger budget: already-converged problems unchanged
        longer = BatchedKMeans(n_clusters=k, max_iter=90, random_state=1,
                               sync_every=90).fit(x)
        np.testing.assert_array_equal(short.n_iter_, longer.n_iter_)
        np.testing.assert_array_equal(np.asarray(short.cluster_centers_),
                                      np.asarray(longer.cluster_centers_))

    def test_pallas_backend_matches_xla(self):
        b, n, f, k = 2, 128, 8, 4
        x, _ = _stack(b, n, f, k, seed=2)
        pal = BatchedKMeans(n_clusters=k, max_iter=4, sync_every=4,
                            backend="lloyd_batched", random_state=1).fit(x)
        xla = BatchedKMeans(n_clusters=k, max_iter=4, sync_every=4,
                            backend="lloyd_batched_xla",
                            random_state=1).fit(x)
        np.testing.assert_allclose(np.asarray(pal.cluster_centers_),
                                   np.asarray(xla.cluster_centers_),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(pal.n_iter_, xla.n_iter_)

    def test_predict_score_state_roundtrip(self):
        b, n, f, k = 3, 128, 8, 4
        x, _ = _stack(b, n, f, k, seed=11)
        bkm = BatchedKMeans(n_clusters=k, max_iter=10, random_state=0)
        labels = bkm.fit_predict(x)
        assert labels.shape == (b, n)
        assert bkm.score(x).shape == (b,)
        restored = BatchedKMeans.from_state(bkm.get_state())
        np.testing.assert_array_equal(np.asarray(restored.predict(x)),
                                      np.asarray(bkm.predict(x)))
        np.testing.assert_array_equal(restored.n_iter_, bkm.n_iter_)

    def test_input_validation(self):
        with pytest.raises(ValueError, match="stacked"):
            BatchedKMeans(n_clusters=2).fit(jnp.zeros((16, 4)))
        with pytest.raises(BackendCapabilityError, match="supports_batch"):
            BatchedKMeans(n_clusters=2, backend="lloyd")
        bkm = BatchedKMeans(n_clusters=2, max_iter=3)
        bkm.fit(jnp.asarray(np.random.default_rng(0)
                            .normal(size=(2, 64, 4)).astype(np.float32)))
        with pytest.raises(ValueError, match="B=2"):
            bkm.predict(jnp.zeros((3, 64, 4)))

    def test_backend_capability_flags(self):
        for name in ("lloyd_batched", "lloyd_batched_xla"):
            be = get_backend(name)
            assert be.supports_batch and be.fuses_update
            assert be.kernel_kind == "batched"
        assert not get_backend("lloyd").supports_batch

    def test_fresh_interpreter_can_import_repro_batch_first(self):
        """repro.batch must import standalone: the repro.api re-export is
        lazy, so importing the batch package first cannot re-enter a
        partially initialized repro.api (circular-import regression)."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run(
            [sys.executable, "-c",
             "import repro.batch; from repro.api import BatchedKMeans; "
             "assert BatchedKMeans is repro.batch.BatchedKMeans"],
            capture_output=True, text=True, env=env, timeout=120)
        assert out.returncode == 0, out.stderr[-2000:]

    def test_single_problem_estimator_rejects_batched_backend(self):
        """The registry contract is symmetric: KMeans must refuse a
        supports_batch backend at construction (a typed error, not a
        shape crash deep inside the batched kernel)."""
        from repro.api import KMeans
        with pytest.raises(BackendCapabilityError, match="BatchedKMeans"):
            KMeans(4, backend="lloyd_batched_xla")
        with pytest.raises(BackendCapabilityError, match="BatchedKMeans"):
            KMeans(4, backend="lloyd_batched")

    def test_update_target_on_injectionless_onepass_names_real_reason(self):
        """lloyd_ft_xla is one-pass FT but has no in-kernel injection
        surface; the capability error must say that, not call it
        'two-pass'."""
        from repro.api import InjectionCampaign
        camp = InjectionCampaign(rate=1.0, targets="update")
        with pytest.raises(BackendCapabilityError,
                           match="no in-kernel injection surface"):
            camp.resolved_targets(get_backend("lloyd_ft_xla"))
        with pytest.raises(BackendCapabilityError, match="two-pass"):
            camp.resolved_targets(get_backend("abft_offline"))


class TestBatchedAutotune:
    def test_batched_kind_selects_and_scales_with_batch(self):
        v, p = select_params(256, 8, 32, kind="batched", batch=64)
        assert v == "batched"
        assert feasible(p, kind="batched", shape=(256, 8, 32))
        s1 = model_score(256, 8, 32, p, kind="batched", batch=1)
        s64 = model_score(256, 8, 32, p, kind="batched", batch=64)
        assert s64 == pytest.approx(64 * s1)

    def test_batched_kind_needs_shape(self):
        assert not feasible(KernelParams(), kind="batched", shape=None)

    def test_cache_batch_buckets_are_isolated(self):
        """A B=4 winner must never serve a B=1024 launch (or a
        single-problem kind) — batch-crossing is the v3 lesson."""
        cache = AutotuneCache(None)
        cache.put(256, 8, 32, KernelParams(512, 128, 256), kind="batched",
                  variant="batched", batch=4)
        v, p = cache.lookup(256, 8, 32, kind="batched", batch=4)
        assert (v, p.block_m) == ("batched", 512)
        _, q = cache.lookup(256, 8, 32, kind="batched", batch=1024)
        assert (q.block_m, q.block_k, q.block_f) != (512, 128, 256)
        _, r = cache.lookup(256, 8, 32, kind="lloyd")
        assert (r.block_m, r.block_k, r.block_f) != (512, 128, 256)

    def test_current_schema_roundtrip_with_batch_bucket(self, tmp_path):
        path = str(tmp_path / "current.json")
        cache = AutotuneCache(path)
        cache.put(256, 8, 32, KernelParams(256, 128, 128), kind="batched",
                  variant="batched", batch=64)
        cache.save()
        with open(path) as fh:
            on_disk = json.load(fh)
        assert on_disk["schema"] == SCHEMA_VERSION == 7
        assert batch_bucket(64) == "b6"
        assert on_disk["kinds"]["batched/float32/b6"][
            shape_bucket(256, 8, 32)] == ["batched", 256, 128, 128]
        v, p = AutotuneCache(path).lookup(256, 8, 32, kind="batched",
                                          batch=64)
        assert v == "batched" and p.block_m == 256

    def test_v3_file_upgrades_to_current(self, tmp_path):
        """v3 (kind/dtype keys, no batch axis) -> load -> lookup -> save ->
        current-schema round trip: every v3 winner lands in bucket b0 of
        its kind/dtype and keeps serving single-problem lookups."""
        path = str(tmp_path / "v3.json")
        bucket = shape_bucket(4096, 100, 128)
        with open(path, "w") as fh:
            json.dump({"schema": 3,
                       "kinds": {"lloyd/bfloat16":
                                 {bucket: ["smallk", 512, 128, 128]}}}, fh)
        cache = AutotuneCache(path)
        v, p = cache.lookup(4096, 100, 128, kind="lloyd",
                            dtype=jnp.bfloat16)
        assert v == "smallk"
        assert (p.block_m, p.block_k, p.block_f) == (512, 128, 128)
        # the batched kind never inherits a single-problem winner
        _, q = cache.lookup(4096, 100, 128, kind="batched",
                            dtype=jnp.bfloat16, batch=8)
        assert q is not None
        cache.save()
        with open(path) as fh:
            upgraded = json.load(fh)
        assert upgraded["schema"] == SCHEMA_VERSION
        assert upgraded["kinds"]["lloyd/bfloat16/b0"][bucket] == \
            ["smallk", 512, 128, 128]

    def test_measure_mode_runs_batched_kernel(self):
        from repro.core.autotune import measure_score
        t = measure_score(64, 4, 16, KernelParams(64, 128, 128),
                          iters=1, kind="batched", batch=2)
        assert t > 0.0


class TestProblemAxisSharding:
    def test_sharded_fit_matches_single_device(self):
        """Problem-axis mode: 8 devices, B=16 problems, no psum on the hot
        path — results bit-identical to the single-device batched fit."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["JAX_PLATFORMS"] = "cpu"
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import BatchedKMeans
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.data.blobs import make_blobs

        B, N, F, K = 16, 256, 8, 4
        x = jnp.stack([make_blobs(N, F, K, seed=b)[0] for b in range(B)])
        mesh = jax.make_mesh((8,), ("data",))
        est = BatchedKMeans(n_clusters=K, max_iter=20, random_state=0)
        c0 = est.init_centroids(x)
        dk = DistributedKMeans(est, mesh)
        assert dk.problem_axis
        assert dk._shard_backend().name == "lloyd_batched_xla"
        c, am, inertia, iters, det = dk.fit(dk.shard_data(x), c0)
        ref = BatchedKMeans(n_clusters=K, max_iter=20,
                            random_state=0).fit(x, centroids=c0)
        np.testing.assert_array_equal(np.asarray(c),
                                      np.asarray(ref.cluster_centers_))
        np.testing.assert_array_equal(np.asarray(am),
                                      np.asarray(ref.labels_))
        np.testing.assert_array_equal(iters, ref.n_iter_)
        assert det == 0
        print("PARITY OK")
        """
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=420)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "PARITY OK" in out.stdout
