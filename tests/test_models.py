"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness assertions, decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM
from repro.train.optimizer import TrainConfig, adamw_update, init_opt_state


def _batch(cfg, b=2, s=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    toks = jax.random.randint(k1, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            k2, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["audio_embeds"] = jax.random.normal(
            k2, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        lm = LM(cfg)
        params, axes = lm.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = jax.jit(lm.forward)(params, batch)
        assert logits.shape == (2, 24, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_one_train_step_reduces_loss_direction(self, arch):
        cfg = get_config(arch, smoke=True)
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10)
        opt = init_opt_state(params, tcfg)
        batch = _batch(cfg)

        @jax.jit
        def step(p, o):
            (loss, m), grads = jax.value_and_grad(
                lambda q: lm.loss(q, batch), has_aux=True)(p)
            p2, o2, _ = adamw_update(p, grads, o, tcfg)
            return p2, o2, loss

        p1, o1, loss0 = step(params, opt)
        _, _, loss1 = step(p1, o1)
        assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(loss1))
        assert float(loss1) < float(loss0)  # same batch: must descend


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b",
                                  "mamba2-1.3b", "recurrentgemma-9b",
                                  "whisper-medium", "qwen2-vl-7b",
                                  "olmoe-1b-7b", "llama4-maverick-400b-a17b",
                                  "minicpm-2b", "nemotron-4-15b"])
def test_decode_matches_forward(arch):
    """Prefill + token-by-token decode reproduces the full forward —
    validates KV caches, ring buffers, SSD state, RG-LRU state.

    MoE archs run with a no-drop capacity factor: capacity-based token
    dropping legitimately differs between a 24-token forward and a 1-token
    decode (GShard semantics); the cache mechanics are what's under test.
    """
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    S, pre = 24, 18
    batch = _batch(cfg, s=S, seed=1)
    del batch["labels"]
    full_logits, _ = jax.jit(lm.forward)(params, batch)
    pbatch = dict(batch)
    pbatch["tokens"] = batch["tokens"][:, :pre]
    _, caches = jax.jit(lm.prefill, static_argnames=("max_len",))(
        params, pbatch, max_len=S)
    dstep = jax.jit(lm.decode_step)
    errs = []
    for t in range(pre, S):
        dl, caches = dstep(params, caches, batch["tokens"][:, t:t + 1],
                           jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, t]))))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    assert max(errs) / scale < 2e-4, f"decode drift {max(errs):.3e}"


def test_moe_aux_loss_nonzero():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    _, aux = jax.jit(lm.forward)(params, _batch(cfg))
    assert float(aux) > 0.0


def test_vocab_padding_masked_in_loss():
    cfg = get_config("minicpm-2b", smoke=True)   # vocab 509 -> padded 512
    assert cfg.padded_vocab == 512 and cfg.vocab_size == 509
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    loss, m = jax.jit(lm.loss)(params, _batch(cfg))
    # a uniform model over the REAL vocab has CE ~ ln(509), not ln(512);
    # both are ~6.23 — just assert finiteness + logits masking applied
    assert bool(jnp.isfinite(loss))


def test_param_count_analytic_vs_actual():
    for arch in ("internlm2-1.8b", "mamba2-1.3b", "olmoe-1b-7b"):
        cfg = get_config(arch, smoke=True)
        lm = LM(cfg)
        sds, _ = lm.abstract_params()
        actual = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(sds))
        analytic = cfg.param_count()
        # analytic model ignores small biases/norms differences; 10% band
        assert abs(actual - analytic) / actual < 0.15, (arch, actual, analytic)
