import os

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess; see test_dryrun.py). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402  (imported so the platform pin takes effect early)
