"""Template family (paper §III-B): dtype-specialized kernel paths, the
small-K fast-path variant, variant-aware selection, the v4 cache schema,
and the estimator's ``compute_dtype`` / chunked-inference surface.

Kernels run interpret=True (kernel bodies execute in Python on CPU)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AutotuneCache, KMeans, shape_bucket
from repro.api.cache import SCHEMA_VERSION
from repro.core.autotune import (feasible, iteration_traffic, model_score,
                                 parameter_space, select_params)
from repro.kernels import ops, ref
from repro.kernels.ops import KernelParams, resolve_variant, sublane_align

# Irregular shapes with K inside one centroid tile (smallk-eligible):
# (M, K, F) each off the block grid in at least one dimension.
SMALLK_GRID = [
    (1000, 7, 33),
    (513, 100, 257),
    (300, 77, 130),
    (256, 128, 512),          # exactly one tile in every dimension
    (64, 8, 32),
]


def _data(m, k, f, seed=0, dtype=jnp.float32):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, f), dtype),
            jax.random.normal(kc, (k, f), dtype))


def _int_data(m, k, f, seed=0, dtype=jnp.float32):
    """Small-integer-valued data: exactly representable in bf16/fp16 and
    f32 alike, so cross-dtype distances are identical and assignment parity
    is exact (no tie flakiness)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-4, 5, (m, f)), dtype)
    c = jnp.asarray(rng.integers(-4, 5, (k, f)), dtype)
    return x, c


LOW_PRECISION = [jnp.bfloat16, jnp.float16]


class TestDtypeParity:
    @pytest.mark.parametrize("dtype", LOW_PRECISION)
    @pytest.mark.parametrize("m,k,f", [(513, 100, 257), (300, 77, 130)])
    def test_assign_matches_f32_reference_exactly_on_exact_data(
            self, m, k, f, dtype):
        """On exactly-representable data, a bf16/fp16 assignment is
        identical to the f32 oracle's (not merely close)."""
        x32, c32 = _int_data(m, k, f, seed=1)
        _, ram = ref.distance_argmin(x32, c32)
        am, _ = ops.fused_assign(x32.astype(dtype), c32.astype(dtype),
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(am), np.asarray(ram))

    @pytest.mark.parametrize("dtype", LOW_PRECISION)
    def test_assign_random_data_near_parity(self, dtype):
        """Random data: low-precision rounding may flip near-ties only."""
        x, c = _data(512, 64, 128, seed=2)
        _, ram = ref.distance_argmin(x, c)
        am, _ = ops.fused_assign(x.astype(dtype), c.astype(dtype),
                                 interpret=True)
        assert float(jnp.mean((am == ram).astype(jnp.float32))) > 0.98

    @pytest.mark.parametrize("dtype", LOW_PRECISION)
    def test_lloyd_low_precision_centroids_within_dtype_tolerance(
            self, dtype):
        x32, c32 = _int_data(400, 13, 40, seed=3)
        am32, md32, sums32, counts32 = ops.fused_lloyd(x32, c32,
                                                       interpret=True)
        am, md, sums, counts = ops.fused_lloyd(
            x32.astype(dtype), c32.astype(dtype), interpret=True)
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am32))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(counts32))
        # integer data: sums are exact in every dtype; the f32 accumulator
        # keeps them exact through the one-hot GEMM
        np.testing.assert_allclose(sums, sums32, rtol=1e-6)
        assert sums.dtype == jnp.float32 and counts.dtype == jnp.float32

    @pytest.mark.parametrize("dtype", LOW_PRECISION)
    def test_ft_low_precision_clean_and_injected(self, dtype):
        from repro.kernels.distance_argmin_ft import make_injection
        x, c = _data(512, 256, 512, seed=4, dtype=dtype)
        params = KernelParams(256, 128, 512)
        am, md, det = ops.fused_assign_ft(x, c, params, interpret=True)
        assert int(det) == 0                    # clean run: no false alarm
        inj = make_injection(0, 1, 0, 13, 57, 1e4)
        am_i, _, det_i = ops.fused_assign_ft(x, c, params, inj=inj,
                                             interpret=True)
        assert int(det_i) == 1                  # injected SEU: caught
        np.testing.assert_array_equal(np.asarray(am_i), np.asarray(am))


class TestSmallKVariant:
    @pytest.mark.parametrize("m,k,f", SMALLK_GRID)
    def test_assign_bit_identical_to_generic(self, m, k, f):
        x, c = _data(m, k, f, seed=5)
        p = ops.clamp_params(m, k, f, KernelParams())
        am_g, md_g = ops.fused_assign(x, c, p, variant="generic",
                                      interpret=True)
        am_s, md_s = ops.fused_assign(x, c, p, variant="smallk",
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(am_s), np.asarray(am_g))
        np.testing.assert_array_equal(np.asarray(md_s), np.asarray(md_g))

    @pytest.mark.parametrize("m,k,f", SMALLK_GRID)
    def test_lloyd_bit_identical_to_generic(self, m, k, f):
        x, c = _data(m, k, f, seed=6)
        p = ops.clamp_params(m, k, f, KernelParams())
        for got, want in zip(
                ops.fused_lloyd(x, c, p, variant="smallk", interpret=True),
                ops.fused_lloyd(x, c, p, variant="generic", interpret=True)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_auto_dispatch_rule(self):
        p = KernelParams(256, 128, 512)
        assert resolve_variant(100, p) == "smallk"
        assert resolve_variant(128, p) == "smallk"
        assert resolve_variant(129, p) == "generic"
        assert resolve_variant(129, p, "generic") == "generic"
        with pytest.raises(ValueError, match="smallk"):
            resolve_variant(129, p, "smallk")
        with pytest.raises(ValueError, match="variant"):
            resolve_variant(100, p, "tiny")

    def test_multi_tile_k_rejects_smallk_kernel(self):
        x, c = _data(256, 300, 128, seed=7)
        with pytest.raises(ValueError, match="smallk"):
            ops.fused_assign(x, c, KernelParams(256, 128, 128),
                             variant="smallk", interpret=True)


class TestVariantAwareSelection:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("kind", ["assign", "lloyd"])
    def test_smallk_selected_when_k_fits_one_tile(self, kind, dtype):
        variant, p = select_params(16384, 100, 128, mode="model",
                                   kind=kind, dtype=dtype)
        assert variant == "smallk"
        assert 100 <= p.block_k
        variant, _ = select_params(16384, 1000, 128, mode="model",
                                   kind=kind, dtype=dtype)
        assert variant == "generic"      # K > max block_k candidate

    def test_model_ranks_smallk_strictly_ahead_at_same_tiles(self):
        for kind in ("assign", "lloyd"):
            for dtype in (jnp.float32, jnp.bfloat16):
                p = KernelParams(256, 128, 128)
                s = model_score(4096, 100, 256, p, dtype=dtype, kind=kind,
                                variant="smallk")
                g = model_score(4096, 100, 256, p, dtype=dtype, kind=kind,
                                variant="generic")
                assert s < g

    def test_bf16_model_beats_f32_at_default_shape(self):
        p = KernelParams()
        assert model_score(16384, 128, 128, p, dtype=jnp.bfloat16) \
            < model_score(16384, 128, 128, p, dtype=jnp.float32)

    def test_parameter_space_varies_by_dtype(self):
        f32 = parameter_space(jnp.float32)
        bf16 = parameter_space(jnp.bfloat16)
        assert len(bf16) > len(f32)      # 2-byte dtypes admit wider tiles
        assert any(p.block_f == 2048 for p in bf16)
        assert not any(p.block_f == 2048 for p in f32)
        assert parameter_space(jnp.float16) == bf16

    def test_feasible_is_dtype_and_variant_aware(self):
        # 8-row tiles are legal for f32, not for 2-byte dtypes
        p8 = KernelParams(8, 128, 128)
        assert feasible(p8, jnp.float32)
        assert not feasible(p8, jnp.bfloat16)
        assert sublane_align(jnp.float16) == 16
        # smallk needs the shape, and K must fit one tile
        p = KernelParams(256, 128, 128)
        assert not feasible(p, variant="smallk")                 # no shape
        assert feasible(p, shape=(1024, 100, 128), variant="smallk")
        assert not feasible(p, shape=(1024, 300, 128), variant="smallk")

    def test_vmem_models_scale_with_itemsize(self):
        p = KernelParams(256, 128, 512)
        assert p.vmem_bytes(jnp.bfloat16) < p.vmem_bytes()
        assert ops.lloyd_vmem_bytes(p, 128, 512, jnp.float16) \
            < ops.lloyd_vmem_bytes(p, 128, 512)

    def test_iteration_traffic_dtype_split(self):
        """X/C move in the input dtype; distances, partial sums and argmin
        are fixed-width (f32/i32) regardless."""
        m, k, f = 4096, 128, 128
        p = KernelParams(256, 128, 128)
        t32 = iteration_traffic(m, k, f, p, dtype=jnp.float32)
        tbf = iteration_traffic(m, k, f, p, dtype=jnp.bfloat16)
        assert tbf["x_read"] == t32["x_read"] // 2
        assert tbf["c_read"] == t32["c_read"] // 2
        assert tbf["assign_out"] == t32["assign_out"] == m * 8
        assert tbf["update_out"] == t32["update_out"]   # f32 streams
        assert tbf["total"] < t32["total"]


class TestCacheSchema:
    def test_current_roundtrip_with_variant_and_dtype(self, tmp_path):
        path = str(tmp_path / "current.json")
        cache = AutotuneCache(path)
        cache.put(4096, 100, 128, KernelParams(512, 128, 128),
                  kind="lloyd", dtype=jnp.bfloat16, variant="smallk")
        cache.save()
        with open(path) as fh:
            on_disk = json.load(fh)
        assert on_disk["schema"] == SCHEMA_VERSION == 7
        assert on_disk["kinds"]["lloyd/bfloat16/b0"][
            shape_bucket(4096, 100, 128)] == ["smallk", 512, 128, 128]
        fresh = AutotuneCache(path)
        v, p = fresh.lookup(4096, 100, 128, kind="lloyd",
                            dtype=jnp.bfloat16)
        assert v == "smallk"
        assert (p.block_m, p.block_k, p.block_f) == (512, 128, 128)

    def test_v2_file_loads_as_f32_generic(self, tmp_path):
        path = str(tmp_path / "v2.json")
        bucket = shape_bucket(2048, 64, 64)
        with open(path, "w") as fh:
            json.dump({"schema": 2,
                       "kinds": {"lloyd": {bucket: [128, 128, 256]}}}, fh)
        cache = AutotuneCache(path)
        v, p = cache.lookup(2048, 64, 64, kind="lloyd")
        assert v == "generic"
        assert (p.block_m, p.block_k, p.block_f) == (128, 128, 256)
        # the bf16 template never inherits the f32 winner
        _, q = cache.lookup(2048, 64, 64, kind="lloyd", dtype=jnp.bfloat16)
        assert (q.block_m, q.block_k, q.block_f) != (128, 128, 256)
        # and upgrading on save produces a current-schema file that
        # round-trips
        cache.save()
        with open(path) as fh:
            upgraded = json.load(fh)
        assert upgraded["schema"] == SCHEMA_VERSION
        assert upgraded["kinds"]["lloyd/float32/b0"][bucket] \
            == ["generic", 128, 128, 256]

    def test_v1_chain_upgrades_to_current(self, tmp_path):
        """v1 -> load -> save -> v3 -> load: the winner survives the whole
        schema chain under (assign, generic, float32)."""
        path = str(tmp_path / "v1.json")
        bucket = shape_bucket(1024, 32, 64)
        with open(path, "w") as fh:
            json.dump({bucket: [64, 128, 128]}, fh)
        AutotuneCache(path).save()
        v, p = AutotuneCache(path).lookup(1024, 32, 64)
        assert v == "generic"
        assert (p.block_m, p.block_k, p.block_f) == (64, 128, 128)


@pytest.fixture(scope="module")
def blobs():
    from repro.data.blobs import make_blobs
    return make_blobs(1500, 12, 6, seed=3, spread=0.5)


class TestEstimatorComputeDtype:
    @pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
    def test_fit_predict_low_precision_reaches_f32_solution(self, blobs,
                                                            dtype):
        x, _ = blobs
        lo = KMeans(6, max_iter=15, compute_dtype=dtype,
                    random_state=0).fit(x)
        hi = KMeans(6, max_iter=15, random_state=0).fit(x)
        assert lo.cluster_centers_.dtype == jnp.float32
        # well-separated blobs: low precision lands on the same clustering
        assert abs(lo.inertia_ - hi.inertia_) <= abs(hi.inertia_) * 0.02
        agree = float(jnp.mean((lo.labels_ == hi.labels_)
                               .astype(jnp.float32)))
        assert agree > 0.98
        # predict routes through the compute dtype too, consistently with
        # the labels the fit produced
        np.testing.assert_array_equal(np.asarray(lo.predict(x)),
                                      np.asarray(lo.labels_))

    def test_compute_dtype_on_pallas_backend(self, blobs):
        x, _ = blobs
        km = KMeans(6, max_iter=6, backend="lloyd",
                    compute_dtype="bfloat16", sync_every=3,
                    random_state=0).fit(x[:512])
        ref_km = KMeans(6, max_iter=6, random_state=0).fit(x[:512])
        assert abs(km.inertia_ - ref_km.inertia_) \
            <= abs(ref_km.inertia_) * 0.02

    def test_state_roundtrip_carries_compute_dtype(self, blobs):
        x, _ = blobs
        km = KMeans(6, max_iter=4, compute_dtype="bfloat16",
                    predict_chunk_rows=256, random_state=0).fit(x)
        st = km.get_state()
        back = KMeans.from_state(st)
        assert back.compute_dtype == jnp.dtype("bfloat16")
        assert back.predict_chunk_rows == 256
        np.testing.assert_array_equal(np.asarray(back.predict(x)),
                                      np.asarray(km.predict(x)))

    def test_rejects_unknown_compute_dtype(self):
        with pytest.raises(ValueError, match="compute_dtype"):
            KMeans(4, compute_dtype="int4")
        with pytest.raises(ValueError, match="compute_dtype"):
            KMeans(4, compute_dtype="bf16")   # unparseable spec, not TypeError

    def test_int8_dtype_and_backend_must_agree(self):
        # int8 is a valid compute_dtype, but only on an int8 template —
        # and an int8 template demands the int8 dtype
        with pytest.raises(ValueError, match="supports_int8"):
            KMeans(4, compute_dtype="int8", backend="lloyd_xla")
        with pytest.raises(ValueError, match="compute_dtype='int8'"):
            KMeans(4, backend="int8_xla")
        KMeans(4, compute_dtype="int8")       # auto-picks an int8 backend


class TestChunkedInference:
    def test_predict_chunked_matches_unchunked_offgrid_m(self, blobs):
        """M not a multiple of block_m or of the chunk size: chunked
        one-shot inference must be exact, not approximately equal."""
        x, _ = blobs
        m = 1111                          # off-grid and off-chunk
        km = KMeans(6, max_iter=8, random_state=0).fit(x)
        whole = km.predict(x[:m])
        km.predict_chunk_rows = 256       # 4 full chunks + remainder 87
        chunked = km.predict(x[:m])
        np.testing.assert_array_equal(np.asarray(chunked),
                                      np.asarray(whole))
        assert km.score(x[:m]) == pytest.approx(
            KMeans(6, max_iter=8, random_state=0).fit(x).score(x[:m]))

    def test_transform_chunked_matches_unchunked(self, blobs):
        x, _ = blobs
        km = KMeans(6, max_iter=8, random_state=0).fit(x)
        whole = km.transform(x[:1000])
        km.predict_chunk_rows = 300
        np.testing.assert_allclose(km.transform(x[:1000]), whole,
                                   rtol=1e-6)

    def test_rejects_bad_chunk_size(self):
        with pytest.raises(ValueError, match="predict_chunk_rows"):
            KMeans(4, predict_chunk_rows=0)
