"""Unit + property tests for the dual-checksum ABFT core (paper §IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # deterministic fallback (see _hypothesis_stub)
    from _hypothesis_stub import given, settings, st

from repro.core import checksum
from repro.core.ft_gemm import ft_matmul
from repro.core.fault import FaultConfig, flip_bit, inject


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


class TestChecksumInvariant:
    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 64, 16), (128, 256, 64)])
    def test_expected_matches_observed_clean(self, m, k, n):
        x, y = _rand((m, k), 1), _rand((k, n), 2)
        d = x @ y
        exp = checksum.expected_checksums(x, y)
        obs = checksum.observed_checksums(d)
        np.testing.assert_allclose(exp.col1, obs.col1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(exp.col2, obs.col2, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(exp.row1, obs.row1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(exp.row2, obs.row2, rtol=1e-4, atol=1e-3)

    def test_clean_product_not_flagged(self):
        x, y = _rand((64, 128), 3), _rand((128, 32), 4)
        d = x @ y
        exp = checksum.expected_checksums(x, y)
        thr = checksum.default_threshold(128) * float(jnp.max(jnp.abs(d)))
        v = checksum.verify(d, exp, thr)
        assert not bool(v.detected)


class TestLocateAndCorrect:
    @pytest.mark.parametrize("i,j", [(0, 0), (17, 3), (63, 31), (5, 0)])
    def test_single_error_located_exactly(self, i, j):
        x, y = _rand((64, 128), 5), _rand((128, 32), 6)
        d = x @ y
        exp = checksum.expected_checksums(x, y)
        bad = d.at[i, j].add(37.5)
        thr = checksum.default_threshold(128) * float(jnp.max(jnp.abs(d)))
        v = checksum.verify(bad, exp, thr)
        assert bool(v.detected)
        assert int(v.row) == i and int(v.col) == j
        fixed = checksum.correct(bad, v)
        np.testing.assert_allclose(fixed, d, rtol=1e-4, atol=1e-3)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 31), st.integers(0, 15),
           st.sampled_from([1e3, -1e3, 1e6, -1e-1 * 1e4]))
    def test_property_any_position_any_magnitude(self, i, j, delta):
        x, y = _rand((32, 64), 7), _rand((64, 16), 8)
        d = x @ y
        exp = checksum.expected_checksums(x, y)
        bad = d.at[i, j].add(delta)
        thr = checksum.default_threshold(64) * float(jnp.max(jnp.abs(d)))
        v = checksum.verify(bad, exp, thr)
        assert bool(v.detected)
        fixed = checksum.correct(bad, v)
        # correction recovers delta from f32 checksum sums: the residue is
        # O(eps * |delta| * sqrt(m)) — inherent to fp ABFT (paper §IV).
        atol = 1e-2 + abs(delta) * 2e-5
        np.testing.assert_allclose(fixed, d, rtol=1e-3, atol=atol)


class TestFtMatmul:
    def test_clean(self):
        x, y = _rand((64, 128), 9), _rand((128, 48), 10)
        d, detected = ft_matmul(x, y)
        assert not bool(detected)
        np.testing.assert_allclose(d, x @ y, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(20, 30))
    def test_property_bitflip_corrected(self, seed, bit):
        """SEU model: one bit flip in the product is detected + corrected.

        Correction recovers the delta from f32 checksum sums, so the
        residue after correcting a 2^34-magnitude exponent flip is bounded
        by the delta's ulp (the paper's FP32 scheme shares this): assert
        the corruption is reduced by >= 1e4x, not to zero.
        """
        x, y = _rand((32, 64), 11), _rand((64, 16), 12)
        clean = jnp.matmul(x, y)
        fault = FaultConfig(rate=1.0, bit_low=bit, bit_high=bit, seed=seed)
        key = jax.random.PRNGKey(seed)
        d, detected = ft_matmul(x, y, inject_key=key, fault=fault)
        from repro.core.fault import inject
        before = float(jnp.max(jnp.abs(inject(key, clean, fault) - clean)))
        after = float(jnp.max(jnp.abs(d - clean)))
        assert after <= max(1e-2, before * 1e-4), (before, after)


class TestFaultInjection:
    def test_flip_bit_roundtrip(self):
        x = _rand((4, 4), 13)
        flipped = flip_bit(x, 5, 22)
        assert not np.allclose(flipped, x)
        again = flip_bit(flipped, 5, 22)
        np.testing.assert_array_equal(again, x)

    def test_inject_rate_zero_is_identity(self):
        x = _rand((16,), 14)
        out = inject(jax.random.PRNGKey(0), x, FaultConfig(rate=0.0))
        np.testing.assert_array_equal(out, x)
