"""Seeding determinism + fused k-means++ parity contracts.

What this suite pins (and why the bench rungs may trust the numbers):

* ``init_kmeanspp`` / ``init_random`` are pure functions of (key, x, k):
  the same seed gives bit-identical centroids across direct, re-jitted
  and vmapped invocation — no hidden global PRNG state, no trace-order
  sensitivity.
* ``init_kmeanspp_fused`` is deterministic per seed, and its Pallas round
  kernel (interpret mode off-TPU) chooses the *same sample indices* as
  the tile-mirrored XLA twin at a fixed ``block_n``. Both paths gather
  the final centroids from the same unpadded ``x``, so index parity makes
  the returned (B, K, F) arrays bit-identical — which is what we assert.
* Fused seeding draws real sample rows, K distinct ones per problem, and
  problem b's draws depend only on its own key (batch-size invariance).
* ``BatchedKMeans(init="kmeans++-fused")`` produces identical seeds for
  identical ``random_state`` across estimator instances.

The fused key protocol deliberately differs from ``init_kmeanspp``'s
(block uniform draws vs per-round split — see kernels/kmeanspp_init.py),
so there is NO cross-implementation sample equality to pin; the contract
is per-seed self-reproducibility plus kernel/twin index parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch import BatchedKMeans
from repro.core.kmeans import init_kmeanspp, init_random
from repro.kernels.kmeanspp_init import (clamp_init_block,
                                         init_kmeanspp_fused)


def _x(m, f, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, f), jnp.float32)


def _stack(b, n, f, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, f),
                             jnp.float32)


def _keys(b, base=0):
    return jax.vmap(jax.random.PRNGKey)(base + jnp.arange(b))


def _rows_of(x, c):
    """For each centroid row, True iff it equals some row of x exactly."""
    eq = jnp.all(c[:, None, :] == x[None, :, :], axis=-1)      # (K, N)
    return jnp.any(eq, axis=1)


class TestSingleProblemInits:
    """init_kmeanspp / init_random: same key => same centroids, on every
    invocation path a fit may reach them through."""

    @pytest.mark.parametrize("fn", [init_kmeanspp, init_random],
                             ids=["kmeanspp", "random"])
    def test_direct_vs_rejit_vs_vmap(self, fn):
        x, k = _x(300, 24), 7
        key = jax.random.PRNGKey(42)
        direct = fn(key, x, k)
        again = fn(key, x, k)                       # cached-jit re-call
        rejit = jax.jit(fn, static_argnums=(2,))(key, x, k)
        vmapped = jax.vmap(fn, in_axes=(0, 0, None))(
            key[None], x[None], k)[0]
        assert jnp.array_equal(direct, again)
        assert jnp.array_equal(direct, rejit)
        assert jnp.array_equal(direct, vmapped)

    @pytest.mark.parametrize("fn", [init_kmeanspp, init_random],
                             ids=["kmeanspp", "random"])
    def test_distinct_keys_distinct_draws(self, fn):
        x, k = _x(300, 24), 7
        a = fn(jax.random.PRNGKey(0), x, k)
        b = fn(jax.random.PRNGKey(1), x, k)
        assert not jnp.array_equal(a, b)

    def test_kmeanspp_centroids_are_sample_rows(self):
        x, k = _x(200, 16), 9
        c = init_kmeanspp(jax.random.PRNGKey(3), x, k)
        assert bool(jnp.all(_rows_of(x, c)))


class TestFusedParity:
    """The Pallas round kernel and the XLA twin must choose the same
    sample indices; both gather from the same x, so the outputs are
    required to be bit-identical arrays."""

    @pytest.mark.parametrize("b,n,f,k,block_n", [
        (4, 600, 48, 9, 256),     # multi-tile, ragged N
        (3, 200, 16, 5, 512),     # single tile after clamp (T == 1 path)
        (2, 1024, 128, 8, 128),   # lane-aligned F, many tiles
    ])
    def test_kernel_matches_twin(self, b, n, f, k, block_n):
        x, keys = _stack(b, n, f), _keys(b)
        ck = init_kmeanspp_fused(keys, x, k, block_n=block_n,
                                 use_kernel=True, interpret=True)
        ct = init_kmeanspp_fused(keys, x, k, block_n=block_n,
                                 use_kernel=False)
        assert jnp.array_equal(ck, ct), (
            "fused kernel and XLA twin chose different sample indices")

    def test_twin_deterministic_and_real_rows(self):
        b, n, f, k = 6, 500, 32, 11
        x, keys = _stack(b, n, f), _keys(b)
        c1 = init_kmeanspp_fused(keys, x, k)
        c2 = init_kmeanspp_fused(keys, x, k)
        assert jnp.array_equal(c1, c2)
        for p in range(b):
            assert bool(jnp.all(_rows_of(x[p], c1[p])))
            # K distinct rows: D² mass at a chosen row is zero afterwards
            assert len(np.unique(np.asarray(c1[p]), axis=0)) == k

    def test_block_n_shapes_cdf_not_distribution_support(self):
        """Different tile sizes may pick different samples (the two-level
        CDF walks tiles in different order), but every pick must still be
        a real row — block_n must never leak padded rows into the draw."""
        b, n, f, k = 3, 700, 24, 8
        x, keys = _stack(b, n, f), _keys(b)
        for bn in (128, 256, 1024):
            c = init_kmeanspp_fused(keys, x, k, block_n=bn,
                                    use_kernel=False)
            for p in range(b):
                assert bool(jnp.all(_rows_of(x[p], c[p]))), f"block_n={bn}"

    def test_batch_invariance(self):
        """Problem b's draws depend only on its own key: the first B'
        problems of a size-B batch reproduce the size-B' batch."""
        b, n, f, k = 8, 400, 16, 6
        x, keys = _stack(b, n, f), _keys(b)
        full = init_kmeanspp_fused(keys, x, k)
        half = init_kmeanspp_fused(keys[:3], x[:3], k)
        assert jnp.array_equal(full[:3], half)

    def test_clamp_init_block(self):
        assert clamp_init_block(600, 512) == 512
        assert clamp_init_block(200, 512) == 256     # ceil to 128-grid
        assert clamp_init_block(600, 64) == 128      # floor at 128
        assert clamp_init_block(4096, 100_000) == 4096


class TestBatchedEstimatorSeeding:
    def test_fused_init_reproducible_across_instances(self):
        x = _stack(5, 300, 16, seed=7)
        a = BatchedKMeans(n_clusters=6, random_state=11,
                          init="kmeans++-fused").init_centroids(x)
        b = BatchedKMeans(n_clusters=6, random_state=11,
                          init="kmeans++-fused").init_centroids(x)
        assert jnp.array_equal(a, b)
        c = BatchedKMeans(n_clusters=6, random_state=12,
                          init="kmeans++-fused").init_centroids(x)
        assert not jnp.array_equal(a, c)

    @pytest.mark.parametrize("init", ["kmeans++", "kmeans++-fused",
                                      "random"])
    def test_fit_deterministic_per_random_state(self, init):
        x = _stack(4, 256, 8, seed=3)
        r1 = BatchedKMeans(n_clusters=4, random_state=0, max_iter=5,
                           init=init).fit(x)
        r2 = BatchedKMeans(n_clusters=4, random_state=0, max_iter=5,
                           init=init).fit(x)
        assert jnp.array_equal(r1.cluster_centers_, r2.cluster_centers_)
        assert jnp.array_equal(r1.labels_, r2.labels_)
