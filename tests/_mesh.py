"""Shared multi-device subprocess rig.

jax locks the device count at first backend init, so the main pytest
session — which other suites need single-device — can never see the
8 virtual CPUs. Every multi-device test instead ships its body to a
fresh interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
and asserts on the child's stdout. Import as ``from _mesh import
run_with_devices`` (pytest puts ``tests/`` on ``sys.path``) and mark the
test ``@pytest.mark.multidevice`` so CI can schedule the slow subprocess
suite separately (``pytest -m multidevice``).
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    """Run ``code`` in a fresh interpreter with ``n`` virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # force CPU: without the pin, jax probes the TPU plugin, which retries
    # cloud metadata fetches for minutes on non-TPU hosts. The virtual
    # devices come from xla_force_host_platform_device_count either way.
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
