"""Dry-run launcher tests: the production-mesh AOT path compiles for a
representative cell subset in-process-isolated subprocesses (512 fake
devices), exactly as deliverable (e) requires. The FULL 40-cell x 2-mesh
sweep runs via `python -m repro.launch.dryrun --all --both-meshes`
(results/dryrun_sweep.log); here we pin the machinery + one cell per
step-kind so CI stays fast."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # pin CPU: the TPU plugin probe retries cloud metadata for minutes here
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)   # dryrun.py sets its own
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, timeout=timeout)


@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b", "train_4k"),     # train step
    ("internlm2-1.8b", "decode_32k"),   # decode step + kv cache shardings
    ("mamba2-1.3b", "long_500k"),       # ssm state decode
])
def test_cell_compiles_single_pod(arch, shape):
    out = run_dryrun(["--arch", arch, "--shape", shape])
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert " OK " in out.stdout


def test_cell_compiles_multi_pod():
    out = run_dryrun(["--arch", "internlm2-1.8b", "--shape", "prefill_32k",
                      "--multi-pod"])
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "2x16x16" in out.stdout and " OK " in out.stdout


def test_long_500k_skips_full_attention_archs():
    out = run_dryrun(["--arch", "nemotron-4-15b", "--shape", "long_500k"])
    assert out.returncode == 0
    assert "SKIP" in out.stdout


def test_records_have_roofline_inputs():
    path = os.path.join(REPO, "results", "dryrun",
                        "internlm2-1.8b__train_4k__pod16x16.json")
    if not os.path.exists(path):
        pytest.skip("cell not yet run")
    with open(path) as fh:
        rec = json.load(fh)
    assert rec["status"] == "ok"
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["temp_bytes"] > 0
    assert rec["collective_bytes"] > 0
    assert rec["collectives"]           # census present


def test_mesh_factory_does_not_touch_devices_on_import():
    # make_production_mesh is a function; importing launch.mesh must not
    # initialize jax devices (the dry-run relies on this ordering).
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.mesh, jax\n"
         "assert not jax._src.xla_bridge._backends, 'devices initialized'"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert out.returncode == 0, out.stderr[-1500:]
