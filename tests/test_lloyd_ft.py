"""One-pass FT Lloyd (kernels/lloyd_step_ft.py + the unified protection
stack): clean parity with the unprotected one-pass kernel, in-kernel SEU
correction in both verification intervals (distance GEMM + update
epilogue), dtype-aware detection thresholds, campaign rate semantics, the
lloyd_ft autotune kind, and policy/estimator wiring.

Kernels run interpret=True (kernel bodies execute in Python on CPU)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AutotuneCache, BackendCapabilityError, FaultPolicy,
                       InjectionCampaign, KMeans, get_backend, list_backends)
from repro.core import checksum
from repro.core.autotune import feasible, model_score, select_params
from repro.core.fault import (draw_step_injection, no_step_injection,
                              planned_injections)
from repro.data.blobs import make_blobs
from repro.kernels import ops
from repro.kernels.lloyd_step_ft import INJ_LEN, make_injection, no_injection
from repro.kernels.ops import KernelParams

# smallk-shaped (K fits one centroid tile) and generic-shaped (it doesn't);
# the FT template always runs the generic grid, but both regimes must hold
SHAPES = [
    (64, 8, 32),              # smallk-shaped, tiny: block clamping
    (300, 7, 33),             # smallk-shaped, ragged
    (256, 128, 512),          # exactly one tile
    (513, 129, 257),          # generic-shaped: one past a block boundary
]


def _data(m, k, f, seed=0, dtype=jnp.float32):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, f), dtype),
            jax.random.normal(kc, (k, f), dtype))


class TestFusedLloydFtParity:
    @pytest.mark.parametrize("m,k,f", SHAPES)
    def test_clean_matches_unprotected_bit_identical(self, m, k, f):
        x, c = _data(m, k, f)
        am0, md0, sums0, cnt0 = ops.fused_lloyd(x, c, interpret=True)
        am, md, sums, cnt, det = ops.fused_lloyd_ft(x, c, interpret=True)
        assert int(det) == 0
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am0))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(sums0))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt0))
        np.testing.assert_allclose(md, md0, rtol=1e-6)

    def test_plan_reuse_matches_unplanned_call(self):
        x, c = _data(300, 77, 130, seed=5)
        params = ops.clamp_params(300, 77, 130, KernelParams())
        plan = ops.plan_data(x, params)
        a1 = ops.fused_lloyd_ft(plan, c, interpret=True)
        a2 = ops.fused_lloyd_ft(x, c, params, interpret=True)
        for got, want in zip(a1, a2):
            np.testing.assert_allclose(got, want, rtol=1e-6)


class TestInjectionCorrection:
    # injections address tile coordinates -> pin the tile parameters
    PARAMS = KernelParams(block_m=256, block_k=128, block_f=512)

    @pytest.fixture(scope="class")
    def clean(self):
        x, c = _data(512, 256, 1024, seed=6)
        return (x, c) + ops.fused_lloyd_ft(x, c, self.PARAMS, interpret=True)

    @pytest.mark.parametrize("tile", [(0, 0, 0), (1, 1, 0), (0, 1, 1)])
    @pytest.mark.parametrize("delta", [1e4, -1e4])
    def test_distance_seu_corrected(self, clean, tile, delta):
        x, c, am0, md0, sums0, cnt0, det0 = clean
        inj = make_injection(distance=(*tile, 13, 57, delta))
        am, md, sums, cnt, det = ops.fused_lloyd_ft(
            x, c, self.PARAMS, inj=inj, interpret=True)
        assert int(det) == 1
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am0))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(sums0))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt0))

    @pytest.mark.parametrize("m_tile,row,col", [(0, 0, 0), (1, 5, 100),
                                                (1, 250, 1023)])
    @pytest.mark.parametrize("delta", [1e6, -1e6])
    def test_update_seu_recomputed_bit_identical(self, clean, m_tile, row,
                                                 col, delta):
        """An SEU in the one-hot update product is detected by the e1/e2
        epilogue checksums and the tile recomputed in the tree-reduction
        — replaying the kernel's own arithmetic, so the recovered sums
        are bit-identical to a clean run."""
        x, c, am0, md0, sums0, cnt0, det0 = clean
        inj = make_injection(update=(m_tile, row, col, delta))
        am, md, sums, cnt, det = ops.fused_lloyd_ft(
            x, c, self.PARAMS, inj=inj, interpret=True)
        assert int(det) == 1
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am0))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(sums0))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt0))

    def test_dual_seu_both_intervals_corrected(self, clean):
        """One step exposes two independently verified intervals; a draw
        in each is corrected independently (det counts both)."""
        x, c, am0, md0, sums0, cnt0, det0 = clean
        inj = make_injection(distance=(0, 0, 1, 3, 7, -2e4),
                             update=(0, 2, 33, 5e5))
        am, md, sums, cnt, det = ops.fused_lloyd_ft(
            x, c, self.PARAMS, inj=inj, interpret=True)
        assert int(det) == 2
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am0))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(sums0))

    def test_descriptor_layout(self):
        assert no_injection().shape == (INJ_LEN,)
        both = make_injection(distance=(0, 0, 0, 1, 2, 3.0),
                              update=(1, 4, 5, 6.0))
        assert int(both[0]) == 1 and int(both[7]) == 1
        only_u = make_injection(update=(1, 4, 5, 6.0))
        assert int(only_u[0]) == 0 and int(only_u[7]) == 1


class TestDtypeThresholds:
    def test_threshold_factor_tracks_input_dtype(self):
        f32 = checksum.threshold_factor(1024)
        bf16 = checksum.threshold_factor(1024, jnp.bfloat16)
        fp16 = checksum.threshold_factor(1024, jnp.float16)
        assert f32 == pytest.approx(checksum.default_threshold(1024))
        assert bf16 > fp16 > f32     # eps(bf16) > eps(fp16) > eps(f32)
        assert checksum.default_threshold(
            1024, jnp.float32, input_dtype=jnp.bfloat16) \
            == pytest.approx(bf16)
        # accumulator dtype is the floor
        assert checksum.rounding_eps(jnp.bfloat16) \
            == float(jnp.finfo(jnp.bfloat16).eps)
        assert checksum.rounding_eps(jnp.float32) \
            == float(jnp.finfo(jnp.float32).eps)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_clean_low_precision_zero_detections(self, dtype, seed):
        """False-positive regression (the dtype-threshold footgun): clean
        bf16/fp16 data must never trip the detector, in either the
        distance ABFT or the update-epilogue checksums, over a seeded
        grid of shapes."""
        for m, k, f in [(256, 16, 64), (300, 7, 33), (513, 129, 257)]:
            x, c = _data(m, k, f, seed=seed, dtype=dtype)
            _, _, det = ops.fused_assign_ft(x, c, interpret=True)
            assert int(det) == 0, (m, k, f, "assign_ft")
            _, _, _, _, det = ops.fused_lloyd_ft(x, c, interpret=True)
            assert int(det) == 0, (m, k, f, "lloyd_ft")

    def test_update_thresholds_are_per_checksum_pair(self):
        """Each e1/e2 pair thresholds against its own clean-side
        magnitude: the e2 row runs ~K x larger than e1, and a shared
        scale would raise the e1 detection floor by that factor —
        masking mid-scale deltas at 2-byte dtypes."""
        kx, kc = jax.random.split(jax.random.PRNGKey(4))
        x = jax.random.normal(kx, (512, 512), jnp.bfloat16)
        c = jax.random.normal(kc, (128, 512), jnp.bfloat16)
        p = KernelParams(256, 128, 512)
        _, _, sums0, _, det0 = ops.fused_lloyd_ft(x, c, p, interpret=True)
        assert int(det0) == 0
        for delta in (2.0 ** 13, 2.0 ** 15, -2.0 ** 15):
            inj = make_injection(update=(0, 2, 100, delta))
            _, _, sums, _, det = ops.fused_lloyd_ft(x, c, p, inj=inj,
                                                    interpret=True)
            assert int(det) == 1, delta
            np.testing.assert_array_equal(np.asarray(sums),
                                          np.asarray(sums0))

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
    def test_low_precision_injection_still_detected(self, dtype):
        x, c = _data(512, 256, 512, seed=4, dtype=dtype)
        p = KernelParams(256, 128, 512)
        inj = make_injection(distance=(0, 1, 0, 13, 57, 1e4),
                             update=(1, 3, 40, 1e6))
        am0, _, sums0, cnt0, _ = ops.fused_lloyd_ft(x, c, p, interpret=True)
        am, _, sums, cnt, det = ops.fused_lloyd_ft(x, c, p, inj=inj,
                                                   interpret=True)
        assert int(det) == 2
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am0))
        np.testing.assert_array_equal(np.asarray(sums), np.asarray(sums0))


class TestCampaignRateSemantics:
    def test_rate_boundaries(self):
        rng = np.random.default_rng(0)
        assert planned_injections(rng, 0.0, 2) == 0
        assert all(planned_injections(rng, 1.0, 2) == 1 for _ in range(50))
        # 1 < rate < 2: floor + Bernoulli(frac), both outcomes occur
        draws = {planned_injections(rng, 1.5, 2) for _ in range(200)}
        assert draws == {1, 2}
        # expected count caps at the backend's verified-interval count
        assert all(planned_injections(rng, 3.0, 2) == 2 for _ in range(50))
        assert all(planned_injections(rng, 2.0, 1) == 1 for _ in range(50))

    def test_campaign_validation(self):
        with pytest.raises(ValueError, match="rate"):
            InjectionCampaign(rate=-0.5)
        with pytest.raises(ValueError, match="targets"):
            InjectionCampaign(targets="epilogue")
        lloyd_ft = get_backend("lloyd_ft")
        fused_ft = get_backend("fused_ft")
        camp = InjectionCampaign(targets="both")
        assert camp.resolved_targets(lloyd_ft) == ("distance", "update")
        with pytest.raises(BackendCapabilityError, match="update epilogue"):
            camp.resolved_targets(fused_ft)
        # auto narrows to what the backend protects
        auto = InjectionCampaign()
        assert auto.resolved_targets(lloyd_ft) == ("distance", "update")
        assert auto.resolved_targets(fused_ft) == ("distance",)

    def test_draw_formats_and_dual_slot(self):
        p = KernelParams(256, 128, 512)
        rng = np.random.default_rng(1)
        legacy = draw_step_injection(rng, 512, 8, 64, p, rate=1.0,
                                     kind="assign")
        assert legacy.shape == (8,)
        assert no_step_injection("assign").shape == (8,)
        assert no_step_injection("lloyd_ft").shape == (INJ_LEN,)
        # rate=2 on the dual-interval kernel arms both slots every step
        desc = draw_step_injection(rng, 512, 8, 64, p, rate=2.0,
                                   targets=("distance", "update"),
                                   kind="lloyd_ft")
        assert desc.shape == (INJ_LEN,)
        assert int(desc[0]) == 1 and int(desc[7]) == 1
        # update coordinates address the true (K, F) block
        assert 0 <= int(desc[9]) < 8 and 0 <= int(desc[10]) < 64

    def test_estimator_caps_assign_kind_at_one_per_step(self):
        x, _ = make_blobs(256, 8, 4, seed=0)
        pol = FaultPolicy.correct(
            update_dmr=False,
            injection=InjectionCampaign(rate=2.0, targets="distance"))
        km = KMeans(4, max_iter=3, tol=0.0, fault=pol, backend="fused_ft",
                    sync_every=3, random_state=0).fit(x)
        assert km.detected_errors_ == 3     # one interval -> one per step


class TestAutotuneLloydFtKind:
    def test_select_params_pins_generic(self):
        variant, p = select_params(4096, 64, 256, mode="model",
                                   kind="lloyd_ft")
        assert variant == "generic"       # FT templates keep the full grid
        assert feasible(p, kind="lloyd_ft", shape=(4096, 64, 256))
        assert not feasible(p, kind="lloyd_ft", shape=(4096, 64, 256),
                            variant="smallk")

    def test_model_charges_checksum_overhead(self):
        p = KernelParams(256, 128, 512)
        shape = (16_384, 128, 512)
        assert model_score(*shape, p, kind="lloyd_ft") \
            > model_score(*shape, p, kind="lloyd")
        assert ops.lloyd_ft_vmem_bytes(p, 128, 512) \
            > ops.lloyd_vmem_bytes(p, 128, 512)

    def test_cache_kind_isolation(self):
        """A lloyd winner must not leak into the lloyd_ft lookup (the same
        lesson as assign-vs-lloyd in schema v2)."""
        cache = AutotuneCache()
        distinctive = KernelParams(64, 128, 128)
        cache.put(512, 8, 16, distinctive, kind="lloyd")
        km = KMeans(8, backend="lloyd_ft", autotune=cache,
                    fault=FaultPolicy.correct(update_dmr=False))
        p = km._resolve_params(512, 16)
        assert p.block_m != 64            # fell through to the model

    def test_estimator_resolves_lloyd_ft_kind(self):
        km = KMeans(8, backend="lloyd_ft",
                    fault=FaultPolicy.correct(update_dmr=False))
        assert km._backend.kernel_kind == "lloyd_ft"
        assert get_backend("lloyd").kernel_kind == "lloyd"
        assert get_backend("fused_ft").kernel_kind == "assign"


class TestEstimatorOnePassFt:
    def test_fit_reaches_reference_solution(self):
        x, _ = make_blobs(512, 16, 8, seed=1, spread=0.5)
        km = KMeans(8, max_iter=8, backend="lloyd_ft", sync_every=4,
                    fault=FaultPolicy.correct(update_dmr=False),
                    random_state=0).fit(x)
        ref = KMeans(8, max_iter=8, random_state=0).fit(x)
        assert km.detected_errors_ == 0
        assert abs(km.inertia_ - ref.inertia_) <= abs(ref.inertia_) * 1e-3

    def test_predict_routes_through_protected_assign_kernel(self):
        km = KMeans(8, backend="lloyd_ft",
                    fault=FaultPolicy.correct(update_dmr=False))
        pb = km._predict_backend()
        assert pb.name == "fused_ft"      # same protection level, two-pass
        assert not pb.fuses_update
        km_xla = KMeans(8, backend="lloyd_ft_xla",
                        fault=FaultPolicy.correct(update_dmr=False))
        assert km_xla._predict_backend().name == "abft_offline"

    def test_registry_capabilities(self):
        b = list_backends()
        assert b["lloyd_ft"].supports_ft and b["lloyd_ft"].fuses_update
        assert b["lloyd_ft"].takes_params and b["lloyd_ft"].takes_injection
        assert b["lloyd_ft"].protected_intervals == 2
        assert b["fused_ft"].protected_intervals == 1
        assert b["lloyd_ft_xla"].supports_ft \
            and b["lloyd_ft_xla"].fuses_update
        assert not b["lloyd_ft_xla"].takes_injection

    def test_state_round_trip_preserves_targets(self):
        x, _ = make_blobs(256, 8, 4, seed=2)
        pol = FaultPolicy.correct(
            update_dmr=False,
            injection=InjectionCampaign(rate=1.0, targets="update"))
        km = KMeans(4, max_iter=3, fault=pol, sync_every=3,
                    random_state=0).fit(x)
        km2 = KMeans.from_state(km.get_state())
        assert km2.fault.injection.targets == "update"
        assert km2.fault == km.fault

    def test_update_dmr_subsumed_not_fatal(self):
        # the default (update_dmr=None, auto) is silent on the one-pass
        # FT backend; an *explicit* True draws the deprecation note
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            km = KMeans(4, fault=FaultPolicy.correct())
        assert km._backend.fuses_update
        assert not km._use_dmr
        assert not any(issubclass(i.category, DeprecationWarning)
                       for i in w)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            KMeans(4, fault=FaultPolicy.correct(update_dmr=True))
        assert any(issubclass(i.category, DeprecationWarning) and
                   "subsumes DMR" in str(i.message) for i in w)
        # auto keeps DMR on for two-pass backends (the legacy default)
        km_two = KMeans(4, fault=FaultPolicy.detect(),
                        backend="abft_offline")
        assert km_two._use_dmr
