"""One-pass Lloyd: kernel parity over irregular shapes, the device-resident
chunked fit loop (host-sync accounting), kind-keyed autotune, traffic model.

Kernels run interpret=True (kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AutotuneCache, FaultPolicy, InjectionCampaign,
                       KMeans, get_backend, list_backends)
from repro.core.autotune import (feasible, iteration_traffic, measure_score,
                                 select_params)
from repro.data.blobs import make_blobs
from repro.kernels import ops, ref
from repro.kernels.ops import KernelParams

IRREGULAR = [
    (1000, 7, 33),            # every dim off-grid, K far below a tile
    (513, 129, 257),          # one past a block boundary in every dim
    (256, 128, 512),          # exactly one tile
    (300, 77, 130),           # ragged
    (64, 8, 32),              # tiny: block clamping
]


def _data(m, k, f, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, f), jnp.float32),
            jax.random.normal(kc, (k, f), jnp.float32))


def _int_data(m, k, f, seed=0):
    """Integer-valued f32 data: distances are exactly representable, so
    argmin ties are real ties and tie-break order is observable."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-4, 5, (m, f)), jnp.float32)
    c = jnp.asarray(rng.integers(-4, 5, (k, f)), jnp.float32)
    return x, c


class TestOnePassKernelParity:
    @pytest.mark.parametrize("m,k,f", IRREGULAR)
    def test_matches_two_pass_reference(self, m, k, f):
        x, c = _data(m, k, f)
        am, md, sums, counts = ops.fused_lloyd(x, c, interpret=True)
        assert am.shape == (m,) and md.shape == (m,)
        assert sums.shape == (k, f) and counts.shape == (k,)
        # padded centroid slots never win
        assert int(jnp.max(am)) < k
        rmd, ram = ref.distance_argmin(x, c)
        match = float(jnp.mean((am == ram).astype(jnp.float32)))
        assert match > 0.999, f"argmin mismatch rate {1 - match:.4f}"
        # true squared distance (plan row norms folded in)
        np.testing.assert_allclose(
            md, rmd + jnp.sum(x * x, axis=1), rtol=1e-4, atol=1e-3)
        # the fused update accumulation == the second-pass oracle, given
        # the kernel's own assignment
        rsums, rcounts = ref.centroid_update(x, am, k)
        np.testing.assert_allclose(sums, rsums, rtol=1e-4, atol=1e-3)
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(rcounts))
        # counts cover exactly the true rows — padding contributes nothing
        assert float(jnp.sum(counts)) == m

    @pytest.mark.parametrize("m,k,f", [(1000, 7, 33), (513, 129, 257)])
    def test_exact_tiebreak_agreement_vs_fused(self, m, k, f):
        """Exact-arithmetic data with duplicated centroids: both kernels
        must resolve ties to the first (lowest) index, like the oracle."""
        x, c = _int_data(m, k, f, seed=3)
        c = c.at[k - 1].set(c[k // 2])     # guaranteed exact tie pair
        _, ram = ref.distance_argmin(x, c)
        am_f, _ = ops.fused_assign(x, c, interpret=True)
        am_l, _, _, _ = ops.fused_lloyd(x, c, interpret=True)
        np.testing.assert_array_equal(np.asarray(am_f), np.asarray(ram))
        np.testing.assert_array_equal(np.asarray(am_l), np.asarray(ram))
        assert not bool(jnp.any(am_l == k - 1))   # loser of every tie

    def test_plan_reuse_matches_unplanned_call(self):
        x, c = _data(300, 77, 130, seed=5)
        params = ops.clamp_params(300, 77, 130, KernelParams())
        plan = ops.plan_data(x, params)
        a1 = ops.fused_lloyd(plan, c, interpret=True)
        a2 = ops.fused_lloyd(x, c, params, interpret=True)
        for got, want in zip(a1, a2):
            np.testing.assert_allclose(got, want, rtol=1e-6)
        # the plan's norms feed the fused assignment path too
        am_p, md_p, _ = get_backend("fused")(plan, c, params=params)
        am_r, md_r, _ = get_backend("fused")(x, c, params=params)
        np.testing.assert_array_equal(np.asarray(am_p), np.asarray(am_r))
        np.testing.assert_allclose(md_p, md_r, rtol=1e-6)

    def test_lloyd_xla_matches_lloyd_pallas(self):
        x, c = _data(256, 16, 64, seed=6)
        am_x, md_x, _, sums_x, counts_x = get_backend("lloyd_xla")(x, c)
        am_p, md_p, _, sums_p, counts_p = get_backend("lloyd")(
            x, c, params=KernelParams(128, 128, 128))
        np.testing.assert_array_equal(np.asarray(am_x), np.asarray(am_p))
        np.testing.assert_allclose(md_x, md_p, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(sums_x, sums_p, rtol=1e-4, atol=1e-3)
        np.testing.assert_array_equal(np.asarray(counts_x),
                                      np.asarray(counts_p))


@pytest.fixture(scope="module")
def blobs():
    return make_blobs(2000, 16, 8, seed=1, spread=0.5)


class TestDeviceResidentFit:
    def test_no_host_sync_inside_window(self, blobs, monkeypatch):
        """The whole point of the device loop: with sync_every=3 and 9
        iterations, fit performs 3 chunk syncs + 1 final counter read —
        never one per iteration — and compiles a single chunk trace."""
        from repro.api import estimator as est_mod
        x, _ = blobs
        reads = []
        real = est_mod._host_read
        monkeypatch.setattr(est_mod, "_host_read",
                            lambda v: reads.append(1) or real(v))
        km = KMeans(8, max_iter=9, tol=0.0, sync_every=3, random_state=0)
        km.fit(x)
        assert km.n_iter_ == 9
        assert len(reads) == 9 // 3 + 1
        assert len(reads) < km.n_iter_          # strictly sub-iteration
        chunk_traces = [k for k in km._step_cache if k[0] == "chunk"]
        assert len(chunk_traces) == 1           # one trace for all chunks

    def test_on_iteration_replay_is_per_iteration(self, blobs):
        x, _ = blobs
        seen = []
        km = KMeans(8, max_iter=20, tol=1e-5, sync_every=6, random_state=0)
        km.fit(x, on_iteration=lambda it, c, inertia, shift:
               seen.append((it, inertia, shift)))
        its = [s[0] for s in seen]
        assert its == list(range(km.n_iter_))   # contiguous, per-iteration
        inertias = np.asarray([s[1] for s in seen])
        assert np.all(np.diff(inertias) <= np.abs(inertias[:-1]) * 1e-5)

    def test_sync_every_invariance(self, blobs):
        """Chunking is an observation schedule, not a numeric change."""
        x, _ = blobs
        a = KMeans(8, max_iter=12, sync_every=1, random_state=0).fit(x)
        b = KMeans(8, max_iter=12, sync_every=5, random_state=0).fit(x)
        assert a.n_iter_ == b.n_iter_
        assert a.inertia_ == pytest.approx(b.inertia_, rel=1e-6)
        np.testing.assert_array_equal(np.asarray(a.labels_),
                                      np.asarray(b.labels_))

    def test_onepass_backend_reaches_reference_solution(self, blobs):
        x, _ = blobs
        one = KMeans(8, max_iter=30, backend="lloyd_xla",
                     random_state=0).fit(x)
        two = KMeans(8, max_iter=30, random_state=0).fit(x)
        assert abs(one.inertia_ - two.inertia_) <= abs(two.inertia_) * 1e-3
        np.testing.assert_array_equal(np.asarray(one.labels_),
                                      np.asarray(two.labels_))
        # prediction routes through an assignment-only kernel (never the
        # fused-update epilogue) and still matches the fitted labels
        assert not one._predict_backend().fuses_update
        np.testing.assert_array_equal(np.asarray(one.predict(x)),
                                      np.asarray(one.labels_))

    def test_onepass_pallas_backend_fits(self, blobs):
        x, _ = blobs
        km = KMeans(8, max_iter=8, backend="lloyd", sync_every=4,
                    random_state=0).fit(x[:512])
        ref_km = KMeans(8, max_iter=8, random_state=0).fit(x[:512])
        assert abs(km.inertia_ - ref_km.inertia_) \
            <= abs(ref_km.inertia_) * 1e-3

    def test_injection_campaign_schedule_survives_chunking(self, blobs):
        x, _ = blobs
        policy = FaultPolicy.correct(update_dmr=False,
                                     injection=InjectionCampaign(rate=1.0))
        noisy = KMeans(8, max_iter=12, fault=policy, sync_every=4,
                       random_state=0).fit(x[:512])
        clean = KMeans(8, max_iter=12, random_state=0).fit(x[:512])
        assert noisy.detected_errors_ > 0
        assert abs(noisy.inertia_ - clean.inertia_) \
            <= abs(clean.inertia_) * 1e-3

    def test_update_dmr_ignored_with_note_on_fused_update_backend(self):
        """The former hard error is gone: one-pass backends compute the
        update in the kernel epilogue, where the lloyd_ft checksum scheme
        subsumes DMR — the flag is ignored with a deprecation note."""
        with pytest.warns(DeprecationWarning, match="two-pass"):
            km = KMeans(4, backend="lloyd_xla",
                        fault=FaultPolicy(mode="off", update_dmr=True))
        assert km._backend.name == "lloyd_xla"
        with pytest.warns(DeprecationWarning, match="subsumes DMR"):
            KMeans(4, backend="lloyd_ft",
                   fault=FaultPolicy(mode="correct", update_dmr=True))

    def test_registry_declares_fuses_update(self):
        backends = list_backends()
        assert backends["lloyd"].fuses_update
        assert backends["lloyd"].takes_params
        assert backends["lloyd_xla"].fuses_update
        assert not backends["fused"].fuses_update


class TestAutotuneOnePass:
    def test_estimator_resolves_lloyd_kind(self, blobs):
        """An assignment-only winner in the cache must not leak into the
        one-pass kernel's tile selection (the v1 cache bug)."""
        x, _ = blobs
        cache = AutotuneCache()
        distinctive = KernelParams(64, 128, 128)
        cache.put(512, 8, 16, distinctive)          # kind="assign"
        km_a = KMeans(8, backend="fused", autotune=cache)
        pa = km_a._resolve_params(512, 16)
        assert pa.block_m == 64
        km_l = KMeans(8, backend="lloyd", autotune=cache)
        pl = km_l._resolve_params(512, 16)
        assert pl.block_m != 64                     # fell through to model

    def test_lloyd_vmem_model_is_shape_aware(self):
        p = KernelParams(256, 128, 512)
        assert ops.lloyd_vmem_bytes(p, 128, 512) > p.vmem_bytes()
        assert feasible(p, kind="lloyd", shape=(4096, 128, 512))
        # a feature axis too wide for the stashed row tile is infeasible
        assert not feasible(KernelParams(1024, 128, 1024), kind="lloyd",
                            shape=(65536, 128, 200_000))

    def test_select_params_lloyd_kind(self):
        variant, p = select_params(4096, 128, 256, mode="model", kind="lloyd")
        assert feasible(p, kind="lloyd", shape=(4096, 128, 256),
                        variant=variant)
        with pytest.raises(ValueError, match="kind"):
            select_params(4096, 128, 256, kind="one_pass")  # pipeline word

    def test_select_params_infeasible_lloyd_shape_is_a_clear_error(self):
        """When K*F makes the resident partial-sum block exceed VMEM for
        every tile candidate, the selector explains itself instead of
        dying on a bare assert."""
        with pytest.raises(ValueError, match="two-pass"):
            select_params(65536, 8192, 65536, mode="model", kind="lloyd")

    def test_measure_mode_ranks_real_kernels(self):
        """The fixed measure path: seeded-random inputs, precompiled
        callee, per-call sync — returns sane positive wall-times and a
        feasible winner on a tiny shape."""
        s = measure_score(64, 8, 32, KernelParams(64, 128, 128), iters=2)
        assert s > 0.0
        space = [KernelParams(64, 128, 128), KernelParams(128, 128, 128)]
        variant, p = select_params(64, 8, 32, mode="measure", space=space)
        assert p in space and variant in ("generic", "smallk")


class TestTrafficModel:
    def test_one_pass_reads_x_once(self):
        """Acceptance: with K inside one centroid tile (the benchmark's
        default shape), the one-pass model charges exactly one HBM read
        of padded X per iteration; two-pass re-reads it for the update."""
        m, k, f = 16_384, 128, 128
        p = ops.clamp_params(m, k, f, KernelParams())
        one = iteration_traffic(m, k, f, p, pipeline="one_pass")
        two = iteration_traffic(m, k, f, p, pipeline="two_pass")
        mp = -(-m // p.block_m) * p.block_m
        fp = -(-f // p.block_f) * p.block_f
        assert one["x_read"] == mp * fp * 4       # exactly one pass over X
        assert one["update_x_reread"] == 0 and one["prep"] == 0
        assert two["update_x_reread"] > 0 and two["prep"] > 0
        assert one["total"] < two["total"]

    def test_multi_tile_k_charges_per_centroid_tile(self):
        m, k, f = 4096, 512, 128
        p = KernelParams(256, 128, 128)
        one = iteration_traffic(m, k, f, p, pipeline="one_pass")
        assert one["x_read"] == 4096 * 128 * 4 * (512 // 128)
        with pytest.raises(ValueError):
            iteration_traffic(m, k, f, p, pipeline="lloyd")  # kind != pipeline

    def test_bench_model_rows_expose_the_table(self):
        from benchmarks.bench_stepwise import _traffic_rows
        rows, traffic = _traffic_rows(16_384, 128, 128)
        assert any(r.startswith("model_onepass_hbm") for r in rows)
        assert traffic["one_pass"]["total"] < traffic["two_pass"]["total"]
