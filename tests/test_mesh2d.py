"""2D-mesh (rows x problems) distribution tests: bit-identity of the
hierarchical reduce, compressed-hop tolerance, error-feedback convergence,
and the combined row-sharded batched mode.

Bit-identity methodology: integer-valued f32 data keeps every partial sum
exact (well below 2^24), so psum order — flat vs two-hop, 1 vs 8 shards —
cannot perturb a single bit and ``==`` comparisons are meaningful.
"""
import textwrap

import pytest

from _mesh import run_with_devices

pytestmark = pytest.mark.multidevice


def _run(body: str, **kw) -> str:
    """Prefix the shared prelude (already column-0) onto a dedented test
    body — run_with_devices' own dedent would otherwise see the mixed
    indentation as having no common prefix."""
    return run_with_devices(_PRELUDE + textwrap.dedent(body), **kw)


_PRELUDE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.api import BatchedKMeans, KMeans
from repro.dist.kmeans_dist import DistributedKMeans
from repro.dist.reduce import ReducePlan
from repro.dist.sharding import mesh2d

def int_blobs(seed, m, f, lo=-20, hi=20):
    rng = np.random.default_rng(seed)
    x = rng.integers(lo, hi, size=(m, f)).astype(np.float32)
    c0 = x[rng.choice(m, size=8, replace=False)].copy()
    return x, c0

def fit(mesh, x, c0, plan=None, **kw):
    est = KMeans(8, max_iter=15, tol=1e-4, random_state=0, **kw)
    d = DistributedKMeans(est, mesh, reduce=plan)
    c, am, inertia, iters, det = d.fit(d.shard_data(x), c0)
    return np.asarray(c), np.asarray(am), float(inertia), int(iters), int(det)
"""


class TestMesh2DBitIdentity:
    def test_2d_exact_matches_single_device_bitwise(self):
        """The tentpole identity: an 8-device (2 hosts x 4 rows) fit with
        the exact hierarchical reduce is bit-identical to the same fit on
        one device — centroids, assignments, iteration count — and the
        flat-plan fit matches both (psum order is invisible on integer
        data)."""
        out = _run("""
        x, c0 = int_blobs(3, 1680, 16)
        c1, am1, in1, it1, det1 = fit(mesh2d(1), x, c0)
        c8, am8, in8, it8, det8 = fit(mesh2d(8, hosts=2), x, c0)
        cf, amf, inf_, itf, detf = fit(mesh2d(8, hosts=2), x, c0,
                                       plan=ReducePlan.flat())
        print("CENTS", bool((c1 == c8).all()), bool((c1 == cf).all()))
        print("ASSIGN", bool((am1 == am8).all()))
        print("ITERS", it1, it8, itf)
        # inertia psums f32 squared distances (not integers): sum order
        # is visible in the last ulps, so closeness — not equality
        print("INERTIA", abs(in8 - in1) <= 1e-6 * abs(in1), det8)
        """)
        assert "CENTS True True" in out
        assert "ASSIGN True" in out
        its = out.split("ITERS ")[1].split()[:3]
        assert its[0] == its[1] == its[2]
        assert "INERTIA True 0" in out

    def test_2d_exact_matches_api_estimator(self):
        """Cross-driver sanity: the 2D-mesh solution agrees with the
        single-device ``repro.api.KMeans`` fit on the same seeds (inertia
        within float tolerance — the api driver is a different code
        path, so this is a closeness check, not bit-identity)."""
        out = _run("""
        x, c0 = int_blobs(5, 1680, 16)
        c8, am8, in8, it8, det8 = fit(mesh2d(8, hosts=2), x, c0)
        ref = KMeans(8, max_iter=15, tol=1e-4, random_state=0).fit(
            x, centroids=c0)
        rel = abs(in8 - float(ref.inertia_)) / abs(float(ref.inertia_))
        print("REL", rel)
        """)
        assert float(out.split("REL ")[1].split()[0]) < 1e-3

    def test_ft_backend_hierarchical_checksums_clean(self):
        """The protected one-pass path composes with the two-hop reduce:
        checksums re-verify after each hop, a clean run reports zero
        detections, and the result stays bit-identical to flat."""
        out = _run("""
        from repro.api import FaultPolicy
        x, c0 = int_blobs(7, 1680, 16)
        kw = dict(fault=FaultPolicy.correct(update_dmr=False))
        ch, amh, inh, ith, deth = fit(mesh2d(8, hosts=2), x, c0, **kw)
        cf, amf, inf_, itf, detf = fit(mesh2d(8, hosts=2), x, c0,
                                       plan=ReducePlan.flat(), **kw)
        print("SAME", bool((ch == cf).all()), deth, detf)
        """)
        assert "SAME True 0 0" in out


class TestCompressedHop:
    def test_compressed_fit_within_tolerance_and_exact_hatch(self):
        """Routing the cross-host hop through int8+EF keeps the fit close
        to the exact solution (same iteration count, small relative
        centroid error), while ``exact=True`` — the escape hatch — stays
        bit-identical to the default plan."""
        out = _run("""
        x, c0 = int_blobs(11, 1680, 16)
        ce, ame, ine, ite, dete = fit(mesh2d(8, hosts=2), x, c0)
        cc, amc, inc, itc, detc = fit(mesh2d(8, hosts=2), x, c0,
                                      plan=ReducePlan.compressed())
        ch, amh, inh, ith, deth = fit(mesh2d(8, hosts=2), x, c0,
                                      plan=ReducePlan.compressed(exact=True))
        scale = float(np.abs(ce).max())
        rel_c = float(np.abs(cc - ce).max()) / scale
        rel_in = abs(inc - ine) / abs(ine)
        print("HATCH", bool((ch == ce).all()))
        print("RELC", rel_c, "RELIN", rel_in, "DET", detc)
        """)
        assert "HATCH True" in out
        assert float(out.split("RELC ")[1].split()[0]) < 0.15
        assert float(out.split("RELIN ")[1].split()[0]) < 0.02
        # quantization error must never trip the hop checksums
        assert int(out.split("DET ")[1].split()[0]) == 0

    def test_error_feedback_converges_to_exact_fixed_point(self):
        """EF telescoping across the real cross-host hop: repeatedly
        reducing a FIXED per-host contribution with the residual carry,
        the time-averaged reduction converges to the exact psum (err at
        T=32 is an order of magnitude under err at T=1)."""
        out = _run("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import compressed_psum

        mesh = mesh2d(8, hosts=8)   # 8 "hosts", pure cross-host reduce
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 512)).astype(np.float32))

        def hop(gl, res):
            red, res_n = compressed_psum(gl[0] + res[0], "host")
            return red[None], res_n[None]

        step = jax.jit(shard_map(
            hop, mesh=mesh,
            in_specs=(P("host", None), P("host", None)),
            out_specs=(P("host", None), P("host", None)),
            check_rep=False))
        exact = jnp.sum(g, axis=0)
        res = jnp.zeros_like(g)
        total = jnp.zeros_like(exact)
        errs = {}
        for t in range(1, 33):
            red, res = step(g, res)
            total = total + red[0]
            if t in (1, 32):
                errs[t] = float(jnp.max(jnp.abs(total / t - exact))
                                / jnp.max(jnp.abs(exact)))
        print("ERR1", errs[1], "ERR32", errs[32])
        """)
        e1 = float(out.split("ERR1 ")[1].split()[0])
        e32 = float(out.split("ERR32 ")[1].split()[0])
        assert e32 < e1 / 8 + 1e-7


class TestCombinedMode:
    def test_row_sharded_problems_bit_identical(self):
        """rows x problems: a (2 hosts x 1 row) x 4-problem mesh runs each
        problem row-sharded with a hierarchical per-problem reduce and
        reproduces the single-device BatchedKMeans fit bit-for-bit
        (integer data, no empty clusters)."""
        out = _run("""
        rng = np.random.default_rng(2)
        B, N, K, F = 4, 480, 5, 12
        x = rng.integers(-15, 15, size=(B, N, F)).astype(np.float32)
        c0 = np.stack([xb[rng.choice(N, K, replace=False)] for xb in x])

        ref = BatchedKMeans(n_clusters=K, max_iter=10, tol=1e-4,
                            random_state=0)
        ref.fit(x, centroids=jnp.asarray(c0))
        cref = np.asarray(ref.cluster_centers_)

        mesh = mesh2d(2, problems=4, hosts=2)
        d = DistributedKMeans(BatchedKMeans(n_clusters=K, max_iter=10,
                                            tol=1e-4, random_state=0), mesh)
        c, am, inertia, iters, det = d.fit(d.shard_data(x),
                                           jnp.asarray(c0))
        print("SAME", bool((np.asarray(c) == cref).all()))
        print("ITERS", list(np.asarray(iters)))
        """)
        assert "SAME True" in out

    def test_combined_rejects_int8_hop(self):
        """The int8 transport carries one residual per host group — a
        single-problem contract; the combined mode must refuse it loudly
        rather than silently biasing per-problem updates."""
        out = _run("""
        mesh = mesh2d(2, problems=4, hosts=2)
        d = DistributedKMeans(BatchedKMeans(n_clusters=4, max_iter=3,
                                            random_state=0), mesh,
                              reduce=ReducePlan.compressed())
        x = np.zeros((4, 64, 8), np.float32)
        c0 = jnp.zeros((4, 4, 8), jnp.float32)
        try:
            d.fit(d.shard_data(x), c0)
            print("RAISED False")
        except NotImplementedError:
            print("RAISED True")
        """)
        assert "RAISED True" in out


class TestShardShapeKeys:
    def test_autotune_shard_shape(self):
        """Per-shard autotune keys: winners resolve at (m/shards, k, f);
        non-divisible row counts are a hard error (padding would bias the
        update sums)."""
        from repro.core.autotune import shard_shape
        assert shard_shape(4096, 16, 256, 8) == (512, 16, 256)
        assert shard_shape(4096, 16, 256, 1) == (4096, 16, 256)
        with pytest.raises(ValueError):
            shard_shape(4097, 16, 256, 8)
        with pytest.raises(ValueError):
            shard_shape(4096, 16, 256, 0)

    def test_mesh2d_validation(self):
        """mesh2d is host-count aware and refuses ragged host groups."""
        from repro.dist import sharding as sh
        with pytest.raises(ValueError):
            sh.mesh2d(3, hosts=2)
        with pytest.raises(ValueError):
            sh.mesh2d(0)
