"""The static-analysis gates: each pass fires on a deliberately broken
fixture and stays quiet on the current tree.

Fixture injection goes through each pass's public seams (``vmem_models=``,
``backends=``, ``lint_source``, ``scenarios=``) — no global registry or
module mutation, so these tests compose with the rest of the suite.
"""
import io
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import EXIT_OK, EXIT_VIOLATIONS, report
from repro.analysis import contracts, lint, recompile
from repro.analysis.__main__ import main as analysis_main
from repro.api.registry import AssignmentBackend, main as registry_main
from repro.kernels import ops

SMALL_SHAPES = ((256, 16, 128),)
ONE_DTYPE = ("float32",)


def _rules(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# contracts — vmem models
# ---------------------------------------------------------------------------

class TestContractsVmem:
    def test_current_models_pass(self):
        assert contracts.check_vmem_models(SMALL_SHAPES, ONE_DTYPE) == []

    def test_undercounting_model_is_caught(self):
        """A model that forgets the double-buffered input tiles (a >=30%
        undercount) must trip the vmem-model rule."""
        models = contracts._default_vmem_models()
        models["lloyd"] = lambda p, k, f, dt: 1024   # absurd undercount
        found = contracts.check_vmem_models(SMALL_SHAPES, ONE_DTYPE,
                                            vmem_models=models)
        assert [v for v in found if v.rule == "vmem-model"]
        assert all(v.pass_name == "contracts" for v in found)

    def test_missing_model_is_caught(self):
        models = contracts._default_vmem_models()
        del models["batched"]
        found = contracts.check_vmem_models(SMALL_SHAPES, ONE_DTYPE,
                                            vmem_models=models)
        assert any(v.rule == "vmem-model" and "batched" in v.message
                   for v in found)

    def test_budget_overflow_is_caught(self):
        """A model declaring more than the per-core VMEM budget fires even
        when the (lying) declared number matches nothing else."""
        models = contracts._default_vmem_models()
        models["assign"] = lambda p, k, f, dt: 10 * 2**30
        found = contracts.check_vmem_models(SMALL_SHAPES, ONE_DTYPE,
                                            vmem_models=models)
        assert any("VMEM_BUDGET" in v.message for v in found)


# ---------------------------------------------------------------------------
# contracts — backend flags / intervals / dtypes
# ---------------------------------------------------------------------------

def _honest_fn(x, c, params=None):
    am = jnp.zeros((x.shape[0],), jnp.int32)
    md = jnp.zeros((x.shape[0],), jnp.float32)
    return am, md, jnp.int32(0)


class TestContractsBackends:
    def test_current_registry_passes(self):
        assert contracts.check_backend_contracts(dtypes=ONE_DTYPE) == []

    def test_lying_takes_injection_flag_is_caught(self):
        """Declared takes_injection with no ``inj`` parameter on the real
        callable — the class of drift the PR-5 registry audit was for."""
        liar = AssignmentBackend("liar", _honest_fn, takes_params=True,
                                 takes_injection=True)
        found = contracts.check_backend_contracts({"liar": liar},
                                                  dtypes=ONE_DTYPE)
        assert any(v.rule == "flags" and "inj" in v.message for v in found)

    def test_wrong_arity_is_caught(self):
        """fuses_update promises the extended 5-tuple; a 3-tuple callable
        must trip the arity check."""
        liar = AssignmentBackend("liar3", _honest_fn, takes_params=True,
                                 fuses_update=True)
        found = contracts.check_backend_contracts({"liar3": liar},
                                                  dtypes=ONE_DTYPE)
        assert any(v.rule == "flags" and "returns 3 values" in v.message
                   for v in found)

    def test_16bit_accumulator_dtype_is_caught(self):
        """A kernel leaking bf16 distances under a 16-bit compute dtype
        violates the f32-accumulate contract."""
        def leaky(x, c, params=None):
            am = jnp.zeros((x.shape[0],), jnp.int32)
            md = jnp.zeros((x.shape[0],), x.dtype)   # <- input dtype leak
            return am, md, jnp.int32(0)
        b = AssignmentBackend("leaky", leaky, takes_params=True)
        found = contracts.check_backend_contracts({"leaky": b},
                                                  dtypes=("bfloat16",))
        assert any(v.rule == "f32-accumulate" for v in found)

    def test_wrong_interval_count_is_caught(self, monkeypatch):
        """protected_intervals is derived from the flags; the checker
        cross-checks it against the kernels' INJ_SLOTS. Shrinking the
        slot table simulates a kernel that dropped an interval."""
        def ft_fn(x, c, params=None, inj=None):
            return _honest_fn(x, c, params)
        b = AssignmentBackend("ftb", ft_fn, supports_ft=True,
                              takes_params=True, takes_injection=True)
        found = contracts.check_backend_contracts(
            {"ftb": b}, descriptor_slots={"assign": 2}, dtypes=ONE_DTYPE)
        assert any(v.rule == "intervals" for v in found)


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

class TestLint:
    def test_current_tree_passes(self):
        assert lint.run() == []

    def _lint(self, body, relpath="src/repro/api/fixture.py"):
        return lint.lint_source(textwrap.dedent(body), relpath)

    def test_hidden_item_is_caught(self):
        found = self._lint("""
            def fit(x):
                return x.sum().item()
        """)
        assert _rules(found) == {"host-sync"}

    def test_item_inside_funnel_is_allowed(self):
        found = self._lint("""
            def _host_read(value):
                return value.item()
        """)
        assert found == []

    def test_device_get_outside_funnel_is_caught(self):
        found = self._lint("""
            import jax
            def fit(x):
                return jax.device_get(x)
        """, relpath="src/repro/kernels/fixture.py")   # flagged everywhere
        assert _rules(found) == {"host-sync"}

    def test_float_on_bare_name_in_hot_path(self):
        found = self._lint("""
            def fit(shift):
                if float(shift) < 1e-4:
                    return True
        """)
        assert _rules(found) == {"host-sync"}

    def test_funnel_suffix_naming_is_exempt(self):
        found = self._lint("""
            def fit(x):
                shift_h = _host_read(x)
                return float(shift_h)
        """)
        assert found == []

    def test_scalar_rules_scoped_to_hot_paths(self):
        """float() on a bare name outside the hot-path packages is fine
        (benchmarks, launch tooling, roofline)."""
        found = self._lint("""
            def report(t):
                return float(t)
        """, relpath="src/repro/roofline/fixture.py")
        assert found == []

    def test_jit_in_loop_is_caught(self):
        found = self._lint("""
            import jax
            def sweep(fns, x):
                for fn in fns:
                    jax.jit(fn)(x)
        """)
        assert _rules(found) == {"jit-in-loop"}

    def test_module_state_is_caught(self):
        found = self._lint("""
            _cached_table = {}
        """)
        assert _rules(found) == {"module-state"}

    def test_all_caps_constant_is_exempt(self):
        found = self._lint("""
            SHAPES = [(1, 2), (3, 4)]
            _DTYPE_BYTES = {"float32": 4}
        """)
        assert found == []

    def test_interpret_true_is_caught(self):
        found = self._lint("""
            def call(k):
                return k(interpret=True)
        """, relpath="src/repro/kernels/fixture.py")
        assert _rules(found) == {"interpret-mode"}

    def test_pragma_suppresses(self):
        found = self._lint("""
            _registry = {}  # analysis: allow=module-state
        """)
        assert found == []

    def test_unregistered_state_dataclass_is_caught(self):
        found = self._lint("""
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class BoundsState:
                ub: object
        """, relpath="src/repro/kernels/fixture.py")
        assert _rules(found) == {"pytree-state"}

    def test_registered_state_dataclass_passes(self):
        found = self._lint("""
            import dataclasses
            import jax

            @dataclasses.dataclass(frozen=True)
            class BoundsState:
                ub: object

            jax.tree_util.register_pytree_node(
                BoundsState, lambda b: ((b.ub,), None),
                lambda _, ch: BoundsState(*ch))
        """, relpath="src/repro/kernels/fixture.py")
        assert found == []

    def test_non_state_dataclass_is_exempt(self):
        """Static descriptors (KernelPlan, BufferPlan) never cross a jit
        boundary — only the ``*State`` naming convention is held to the
        registration requirement."""
        found = self._lint("""
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class BufferPlan:
                nbytes: int
        """, relpath="src/repro/kernels/fixture.py")
        assert found == []

    def test_pytree_state_pragma_suppresses(self):
        found = self._lint("""
            import dataclasses

            @dataclasses.dataclass
            class HostOnlyState:  # analysis: allow=pytree-state
                log: list
        """, relpath="src/repro/kernels/fixture.py")
        assert found == []

    def test_syntax_error_reports_parse_rule(self):
        found = lint.lint_source("def broken(:\n", "src/repro/api/x.py")
        assert _rules(found) == {"parse"}


# ---------------------------------------------------------------------------
# recompile
# ---------------------------------------------------------------------------

def _stable_scenario():
    fn = jax.jit(lambda x: x * 2.0)
    x = jnp.ones((8,), jnp.float32)

    def step():
        fn(x).block_until_ready()
    return step


def _retracing_scenario():
    x = np.ones((8,), np.float32)
    def step():
        # a fresh jit wrapper per call: compiles on every pass
        jax.jit(lambda v: v * 3.0)(x).block_until_ready()  # analysis: allow=jit-in-loop
    return step


class TestRecompileGate:
    def test_cached_scenario_is_clean(self):
        found = recompile.run(scenarios=[
            recompile.Scenario("stable", _stable_scenario)])
        assert found == []

    def test_jit_per_call_is_caught(self):
        found = recompile.run(scenarios=[
            recompile.Scenario("retrace", _retracing_scenario,
                               file="tests/test_analysis.py")])
        assert len(found) == 1
        v = found[0]
        assert v.rule == "shape-stable-retrace"
        assert "retrace" in v.message
        assert v.file == "tests/test_analysis.py"

    def test_warm_budget_is_honoured(self):
        found = recompile.run(scenarios=[
            recompile.Scenario("budgeted", _retracing_scenario,
                               warm_budget=5)])
        assert found == []

    def test_counter_counts_real_compiles(self):
        ctr = recompile.CompileCounter()
        with ctr.counting() as c:
            jax.jit(lambda v: v + jnp.float32(41.5))(
                jnp.float32(0.5)).block_until_ready()
        assert c.compiles >= 1
        before = ctr.count
        jax.jit(lambda v: v - jnp.float32(17.0))(
            jnp.float32(1.0)).block_until_ready()   # counter disabled
        assert ctr.count == before


# ---------------------------------------------------------------------------
# shared reporting / drivers
# ---------------------------------------------------------------------------

class TestReporting:
    def test_render_text(self):
        v = report.Violation("lint", "host-sync", file="src/a.py", line=3,
                             message="boom")
        assert v.render("text") == "[lint/host-sync] src/a.py:3: boom"

    def test_render_github(self):
        v = report.Violation("contracts", "vmem-model", file="src/b.py",
                             message="off by 2x")
        assert v.render("github") == \
            "::error file=src/b.py,title=contracts/vmem-model::off by 2x"

    def test_render_github_with_line(self):
        v = report.Violation("lint", "host-sync", file="src/c.py", line=7,
                             message="sync")
        assert v.render("github") == \
            "::error file=src/c.py,line=7,title=lint/host-sync::sync"

    def test_emit_exit_codes(self):
        buf = io.StringIO()
        assert report.emit([], stream=buf) == EXIT_OK
        v = report.Violation("lint", "r", message="m")
        assert report.emit([v], stream=buf) == EXIT_VIOLATIONS
        assert "[lint/r]" in buf.getvalue()

    def test_driver_lint_pass_clean(self, capsys):
        assert analysis_main(["--pass", "lint"]) == EXIT_OK
        out = capsys.readouterr().out
        assert "lint: no violation(s)" in out
        assert "1 pass(es) clean" in out

    def test_driver_rejects_unknown_pass(self):
        with pytest.raises(SystemExit) as e:
            analysis_main(["--pass", "nonsense"])
        assert e.value.code == report.EXIT_USAGE

    def test_registry_check_shares_exit_codes(self, tmp_path, capsys):
        stale = tmp_path / "backends.md"
        stale.write_text("out of date\n")
        assert registry_main(["--check", str(stale)]) == EXIT_VIOLATIONS
        err = capsys.readouterr().err
        assert "[docs/stale-matrix]" in err

    def test_registry_check_github_format(self, tmp_path, capsys):
        stale = tmp_path / "backends.md"
        stale.write_text("out of date\n")
        assert registry_main(["--check", str(stale),
                              "--format=github"]) == EXIT_VIOLATIONS
        err = capsys.readouterr().err
        assert err.startswith("::error file=")
        assert "title=docs/stale-matrix" in err

    def test_registry_check_fresh_is_clean(self, capsys):
        assert registry_main(["--check", "docs/backends.md"]) == EXIT_OK
        assert "up to date" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# kernel_plan — the introspection the contracts pass is built on
# ---------------------------------------------------------------------------

class TestKernelPlan:
    def test_plan_shapes_assign(self):
        p = ops.clamp_params(256, 16, 128, ops.DEFAULT_PARAMS)
        plan = ops.kernel_plan("assign", 256, 16, 128, p)
        assert plan.kind == "assign"
        assert plan.grid
        assert plan.inputs and plan.outputs
        assert plan.vmem_bytes() > 0

    def test_plan_matches_declared_model_exactly_for_assign(self):
        p = ops.clamp_params(1024, 16, 256, ops.DEFAULT_PARAMS)
        plan = ops.kernel_plan("assign", 1024, 16, 256, p)
        assert plan.vmem_bytes() == p.vmem_bytes(jnp.float32)

    def test_plan_rejects_unknown_kind(self):
        with pytest.raises(ValueError):
            ops.kernel_plan("nonsense", 256, 16, 128, ops.DEFAULT_PARAMS)

    def test_smem_buffers_excluded_from_vmem(self):
        p = ops.clamp_params(256, 16, 128, ops.DEFAULT_PARAMS)
        plan = ops.kernel_plan("lloyd", 256, 16, 128, p)
        smem = [b for b in plan.inputs if b.memory == "smem"]
        assert smem, "lloyd kernel threads its meta scalar through SMEM"
        assert plan.vmem_bytes() == sum(
            2 * b.nbytes for b in plan.inputs if b.memory == "vmem") + sum(
            b.nbytes for b in plan.outputs + plan.scratch)
