"""Property-based tests for the int8 error-feedback transport
(``repro.dist.compression``) — the numerics contract the compressed
cross-host hop rests on.

Runs under real ``hypothesis`` when installed (CI), else the deterministic
parametrize stub in ``tests/_hypothesis_stub.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline escape hatch
    from _hypothesis_stub import given, settings, st

from repro.dist.compression import (BLOCK, compressed_psum, dequantize,
                                    quantize, quantize_rows)


def _values(seed: int, rows: int, n: int, scale: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((rows, n)) * scale).astype(np.float32)


class TestQuantizeRoundTrip:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.integers(1, 400), st.sampled_from([64, 128]))
    def test_roundtrip_error_bounded_by_half_step(self, seed, rows, n,
                                                  block):
        """|x - deq(q(x))| <= scale/2 elementwise: symmetric rounding to
        the block's 127-level grid never misses by more than half a step
        (the clip at +-127 is exact at the block max by construction)."""
        x = _values(seed, rows, n, scale=10.0)
        q, scale = quantize(jnp.asarray(x), block)
        deq = np.asarray(dequantize(q, scale, n))
        # broadcast each block's scale back over its elements
        step = np.broadcast_to(np.asarray(scale),
                               scale.shape[:-1] + (block,))
        step = step.reshape(scale.shape[:-2] + (-1,))[..., :n]
        assert np.all(np.abs(x - deq) <= 0.5 * step + 1e-6 * np.abs(x))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.integers(1, 400), st.sampled_from([64, 128]))
    def test_shape_dtype_invariants(self, seed, rows, n, block):
        """q is int8 over ceil(n/block) blocks, one f32 scale per block,
        and dequantize restores exactly the input shape — ragged tails
        (n % block != 0) round-trip through the zero padding."""
        x = _values(seed, rows, n, scale=1.0)
        q, scale = quantize(jnp.asarray(x), block)
        blocks = -(-n // block)
        assert q.dtype == jnp.int8 and q.shape == (rows, blocks, block)
        assert scale.dtype == jnp.float32
        assert scale.shape == (rows, blocks, 1)
        deq = dequantize(q, scale, n)
        assert deq.dtype == jnp.float32 and deq.shape == (rows, n)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 8),
           st.integers(2, 256))
    def test_rowwise_integer_identity(self, seed, rows, n):
        """Integer rows that pin a +-127 entry quantize losslessly (scale
        is exactly 1.0) — the int8 kernel template's bit-exactness
        contract."""
        rng = np.random.default_rng(seed)
        x = rng.integers(-126, 127, size=(rows, n)).astype(np.float32)
        x[:, 0] = 127.0        # pin the row max so scale == 1.0 exactly
        q, scale = quantize_rows(jnp.asarray(x))
        assert np.all(np.asarray(scale) == 1.0)
        assert np.array_equal(np.asarray(q, dtype=np.float32)
                              * np.asarray(scale), x)


class TestErrorFeedback:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
    def test_residual_telescopes(self, seed, magnitude):
        """EF-SGD identity: with a fixed value g, iterating
        ``carried = g + res; res = carried - deq(q(carried))`` telescopes —
        ``sum_t deq_t = T*g - res_T`` — so the time-averaged transported
        value converges to g at rate O(1/T) instead of a constant bias."""
        g = jnp.asarray(_values(seed, 1, 300, magnitude)[0])
        res = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        errs = {}
        for t in range(1, 33):
            carried = g + res
            q, scale = quantize(carried)
            deq = dequantize(q, scale, g.shape[-1])
            res = carried - deq
            total = total + deq
            if t in (1, 32):
                errs[t] = float(jnp.max(jnp.abs(total / t - g)))
        # exact telescoping: the accumulated transport differs from T*g
        # by exactly the final residual (up to f32 rounding)
        gap = jnp.max(jnp.abs(total - 32.0 * g + res))
        assert float(gap) <= 1e-3 * 32 * magnitude + 1e-5
        # and the residual is bounded (one quantization step), so the
        # time-average tightens ~linearly in T
        assert errs[32] <= errs[1] / 8 + 1e-7


class TestCompressedPsum:
    def test_shape_dtype_and_residual_bound(self):
        """compressed_psum keeps the operand's shape/dtype and returns a
        residual bounded by half a quantization step. A size-1 axis makes
        the reduce an identity transport: red == deq(q(g))."""
        mesh = jax.make_mesh((1,), ("data",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        g = jnp.asarray(_values(7, 1, 200, 5.0))

        def f(gl):
            red, res = compressed_psum(gl[0], "data")
            return red[None], res[None]

        red, res = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P("data", None)),
            check_rep=False))(g)
        assert red.shape == g.shape and red.dtype == jnp.float32
        assert res.shape == g.shape and res.dtype == jnp.float32
        q, scale = quantize(g[0])
        assert np.allclose(np.asarray(red[0]),
                           np.asarray(dequantize(q, scale, 200)))
        step = float(jnp.max(scale))
        assert float(jnp.max(jnp.abs(res))) <= 0.5 * step + 1e-7
        # residual is exactly the transport error
        assert np.allclose(np.asarray(g[0] - red[0]), np.asarray(res[0]),
                           atol=1e-6)
