"""Executable-docs gates: the documentation cannot silently rot.

Three mechanisms, mirroring the CI doc-check steps:

  * the auto-generated backend capability matrix (docs/backends.md) must
    match a fresh render of the registry — regenerating is one command
    (``make docs``), so staleness is always a one-line fix;
  * every fenced ``python`` block in README.md and docs/*.md must at least
    *compile*; blocks written as doctests (``>>>``) are additionally
    *executed* and their outputs checked;
  * the docs index (DESIGN.md) and cross-links must point at files that
    exist.
"""
import doctest
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\]\(((?:docs/)?[\w./-]+\.md)\)")


def _doc_files():
    files = [os.path.join(REPO, "README.md"),
             os.path.join(REPO, "DESIGN.md")]
    files += sorted(os.path.join(DOCS, f) for f in os.listdir(DOCS)
                    if f.endswith(".md"))
    return files


def _blocks(path):
    with open(path, encoding="utf-8") as fh:
        return _FENCE.findall(fh.read())


class TestCapabilityMatrixFreshness:
    def test_backends_md_matches_registry(self):
        from repro.api.registry import render_markdown
        path = os.path.join(DOCS, "backends.md")
        assert os.path.exists(path), "docs/backends.md missing — run " \
            "`python -m repro.api.registry --markdown docs/backends.md`"
        with open(path, encoding="utf-8") as fh:
            committed = fh.read()
        assert committed == render_markdown(), (
            "docs/backends.md is stale; regenerate with `make docs` (a "
            "backend or capability flag changed without re-rendering the "
            "matrix)")

    def test_check_cli_agrees(self, capsys):
        from repro.api.registry import main
        assert main(["--check", os.path.join(DOCS, "backends.md")]) == 0

    def test_check_cli_flags_stale_file(self, tmp_path):
        from repro.api.registry import main
        stale = tmp_path / "backends.md"
        stale.write_text("# not the matrix\n")
        assert main(["--check", str(stale)]) == 1


class TestCodeBlocks:
    @pytest.mark.parametrize("path", _doc_files(),
                             ids=[os.path.basename(p) for p in _doc_files()])
    def test_python_blocks_compile(self, path):
        for i, block in enumerate(_blocks(path)):
            src = block
            if ">>>" in block:      # doctest blocks are executed below
                continue
            try:
                compile(src, f"{os.path.basename(path)}[block {i}]", "exec")
            except SyntaxError as e:
                pytest.fail(f"{os.path.basename(path)} code block {i} does "
                            f"not compile: {e}")

    @pytest.mark.parametrize("path", _doc_files(),
                             ids=[os.path.basename(p) for p in _doc_files()])
    def test_doctest_blocks_execute(self, path):
        parser = doctest.DocTestParser()
        runner = doctest.DocTestRunner(verbose=False,
                                       optionflags=doctest.ELLIPSIS)
        ran = 0
        for i, block in enumerate(_blocks(path)):
            if ">>>" not in block:
                continue
            test = parser.get_doctest(
                block, {}, f"{os.path.basename(path)}[block {i}]",
                path, 0)
            result = runner.run(test, clear_globs=False)
            ran += result.attempted
            assert result.failed == 0, (
                f"doctest block {i} in {os.path.basename(path)} failed "
                f"({result.failed}/{result.attempted} examples)")
        if os.path.basename(path) == "autotune.md":
            assert ran > 0, "autotune.md lost its executable example"

    def test_estimator_docstring_examples_execute(self):
        """The BatchedKMeans docstring example is part of the public docs
        surface — run it like the .md doctests."""
        from repro.batch import estimator as mod
        runner = doctest.DocTestRunner(verbose=False,
                                       optionflags=doctest.ELLIPSIS)
        finder = doctest.DocTestFinder(exclude_empty=True)
        ran = 0
        for test in finder.find(mod.BatchedKMeans, "BatchedKMeans"):
            result = runner.run(test)
            ran += result.attempted
            assert result.failed == 0
        assert ran > 0, "BatchedKMeans lost its docstring example"


class TestDocLinks:
    def test_design_md_is_an_index_and_links_resolve(self):
        with open(os.path.join(REPO, "DESIGN.md"), encoding="utf-8") as fh:
            design = fh.read()
        # the index stays one page and defers to docs/
        assert design.count("\n") < 60, "DESIGN.md grew past an index again"
        links = _LINK.findall(design)
        assert any("architecture" in l for l in links)
        for link in links:
            assert os.path.exists(os.path.join(REPO, link)), \
                f"DESIGN.md links to missing file {link}"

    @pytest.mark.parametrize("path", _doc_files(),
                             ids=[os.path.basename(p) for p in _doc_files()])
    def test_cross_links_resolve(self, path):
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        base = os.path.dirname(path)
        for link in _LINK.findall(text):
            target = os.path.join(REPO, link) if link.startswith("docs/") \
                else os.path.join(base, link)
            assert os.path.exists(target), \
                f"{os.path.basename(path)} links to missing file {link}"

    def test_docs_suite_complete(self):
        for name in ("architecture.md", "kernels.md", "fault_tolerance.md",
                     "autotune.md", "backends.md", "analysis.md",
                     "serving.md", "distributed.md"):
            assert os.path.exists(os.path.join(DOCS, name)), \
                f"docs/{name} missing from the suite"
