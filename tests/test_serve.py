"""repro.serve: AOT bucketed predict, micro-batching, versioned hot-swap,
ladder autotuning (cache schema v7), and the chunked-predict edge cases."""
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AutotuneCache, KMeans, get_backend
from repro.api.cache import SCHEMA_VERSION, shape_bucket
from repro.data.blobs import make_blobs
from repro.serve import (CodebookStore, KMeansService, MicroBatcher,
                         ServeCompiler, plan_ladder)

K, F = 8, 24
BUCKETS = (8, 32)


@pytest.fixture(scope="module")
def blobs():
    return make_blobs(512, F, K, seed=3, spread=0.5)


@pytest.fixture(scope="module")
def fitted(blobs):
    x, _ = blobs
    return KMeans(K, max_iter=10, random_state=0, backend="lloyd_xla").fit(x)


@pytest.fixture(scope="module")
def compiler():
    return ServeCompiler(get_backend("gemm_fused"), K, F, buckets=BUCKETS)


def _oracle(x, c):
    d = ((np.asarray(x)[:, None, :] - np.asarray(c)[None, :, :]) ** 2).sum(-1)
    return d.argmin(1), d.min(1)


class TestServeCompiler:
    @pytest.mark.parametrize("m", [0, 1, 5, 8, 9, 32, 33, 100])
    def test_dispatch_exact_at_every_edge(self, compiler, m):
        """0 rows, 1 row, exactly-a-bucket, bucket+1 and beyond the top
        bucket all return the oracle answer at the true row count."""
        rng = np.random.default_rng(m)
        x = np.asarray(rng.normal(size=(m, F)), np.float32)
        c = np.asarray(rng.normal(size=(K, F)), np.float32)
        am, md, det = compiler.dispatch(x, c)
        ref_am, ref_md = _oracle(x, c)
        assert am.shape == (m,) and md.shape == (m,)
        assert np.array_equal(np.asarray(am), ref_am)
        assert np.allclose(np.asarray(md), ref_md, rtol=1e-4, atol=1e-4)
        assert int(det) == 0

    def test_zero_rows_never_touch_a_cell(self, compiler):
        am, md, det = compiler.dispatch(np.zeros((0, F), np.float32),
                                        jnp.zeros((K, F), jnp.float32))
        assert am.shape == (0,) and am.dtype == jnp.int32
        assert md.shape == (0,) and md.dtype == jnp.float32
        assert int(det) == 0

    def test_oversize_requests_are_allocation_bounded(self, compiler):
        """Requests beyond the top bucket chunk through it: only the
        registered cells exist, whatever the request size."""
        assert tuple(compiler._cells) == BUCKETS
        rng = np.random.default_rng(0)
        x = np.asarray(rng.normal(size=(5 * BUCKETS[-1] + 3, F)), np.float32)
        c = np.asarray(rng.normal(size=(K, F)), np.float32)
        am, _, _ = compiler.dispatch(x, c)
        assert np.array_equal(np.asarray(am), _oracle(x, c)[0])
        assert tuple(compiler._cells) == BUCKETS   # no new cells appeared

    def test_bucket_routing(self, compiler):
        assert compiler.bucket_for(1) == 8
        assert compiler.bucket_for(8) == 8
        assert compiler.bucket_for(9) == 32
        assert compiler.bucket_for(10_000) == 32   # callers chunk above top

    def test_feature_mismatch_raises(self, compiler):
        with pytest.raises(ValueError, match="features"):
            compiler.dispatch(np.zeros((4, F + 1), np.float32),
                              jnp.zeros((K, F), jnp.float32))

    def test_takes_params_backend_compiles_and_matches(self):
        """The Pallas template path (takes_params=True) resolves its tile
        winner from the ``serve`` autotune kind and stays exact."""
        comp = ServeCompiler(get_backend("fused"), K, F, buckets=(8,),
                             autotune=AutotuneCache())
        rng = np.random.default_rng(1)
        x = np.asarray(rng.normal(size=(6, F)), np.float32)
        c = np.asarray(rng.normal(size=(K, F)), np.float32)
        am, _, _ = comp.dispatch(x, c)
        assert np.array_equal(np.asarray(am), _oracle(x, c)[0])


class TestMicroBatcher:
    def _echo_dispatch(self, batch):
        # row-shaped output scatters; scalar + python outputs fan out
        return np.asarray(batch) * 2.0, np.float32(7.0), 42

    def test_scatter_matches_per_request(self):
        mb = MicroBatcher(self._echo_dispatch)
        rng = np.random.default_rng(0)
        reqs = [np.asarray(rng.normal(size=(n, 3)), np.float32)
                for n in (1, 4, 2, 8)]
        tickets = [mb.submit(q) for q in reqs]
        assert mb.flush() == len(reqs)
        for q, tk in zip(reqs, tickets):
            rows, scalar, tag = tk.result(timeout=5)
            assert np.array_equal(rows, q * 2.0)     # this request's rows
            assert scalar == np.float32(7.0) and tag == 42
        assert mb.flush() == 0                       # queue drained

    def test_failed_batch_rejects_every_ticket(self):
        def boom(batch):
            raise RuntimeError("kernel exploded")
        mb = MicroBatcher(boom)
        tickets = [mb.submit(np.zeros((2, 3), np.float32))
                   for _ in range(3)]
        with pytest.raises(RuntimeError, match="exploded"):
            mb.flush()
        for tk in tickets:
            assert tk.done()
            with pytest.raises(RuntimeError, match="exploded"):
                tk.result(timeout=1)

    def test_background_window_loop_serves_and_stops(self):
        mb = MicroBatcher(self._echo_dispatch, window_s=0.005)
        mb.start()
        try:
            assert mb.running
            q = np.ones((3, 2), np.float32)
            out = [mb.submit(q).result(timeout=10) for _ in range(4)]
            assert all(np.array_equal(rows, q * 2.0) for rows, _, _ in out)
        finally:
            mb.stop()
        assert not mb.running

    def test_submit_rejects_non_batches(self):
        mb = MicroBatcher(self._echo_dispatch)
        with pytest.raises(ValueError, match="rows, features"):
            mb.submit(np.zeros((3,), np.float32))


class TestCodebookStore:
    def test_publish_versions_monotonic_and_retained(self):
        store = CodebookStore(np.zeros((2, 3), np.float32), keep=2)
        assert store.current().version == 1
        cb2 = store.publish(np.ones((2, 3), np.float32))
        assert cb2.version == 2 and store.current().version == 2
        store.publish(np.full((2, 3), 2.0, np.float32))
        assert store.versions == (2, 3)              # v1 evicted (keep=2)
        with pytest.raises(KeyError, match="not retained"):
            store.get(1)
        assert np.all(np.asarray(store.get(2).centroids) == 1.0)

    def test_publish_shape_change_refused(self):
        store = CodebookStore(np.zeros((2, 3), np.float32))
        with pytest.raises(ValueError, match="hot-swap"):
            store.publish(np.zeros((4, 3), np.float32))

    def test_state_round_trip_bit_identical_all_versions(self):
        rng = np.random.default_rng(5)
        store = CodebookStore(rng.normal(size=(K, F)).astype(np.float32))
        for _ in range(3):
            store.publish(rng.normal(size=(K, F)).astype(np.float32))
        clone = CodebookStore.from_state(store.get_state())
        assert clone.versions == store.versions
        assert clone.current().version == store.current().version
        for v in store.versions:
            assert np.array_equal(np.asarray(store.get(v).centroids),
                                  np.asarray(clone.get(v).centroids))


class TestKMeansService:
    @pytest.fixture(scope="class")
    def svc(self, fitted):
        return fitted.to_service(buckets=BUCKETS, window_s=0.0)

    @pytest.mark.parametrize("m", [0, 1, 16, 100])
    def test_predict_matches_estimator(self, fitted, blobs, svc, m):
        x, _ = blobs
        q = np.asarray(x[:m], np.float32)
        res = svc.predict(q)
        assert np.array_equal(res.labels, np.asarray(fitted.predict(q)))
        assert res.version == svc.store.current().version

    def test_inflight_batch_keeps_its_version(self, fitted, blobs):
        """A publish landing after a batch pinned its codebook must not
        leak into that batch; the next batch serves the new version."""
        x, _ = blobs
        moved = np.asarray(fitted.cluster_centers_, np.float32) + 0.25
        state = {"svc": None, "published": False}

        def hook(cb):   # runs after the flush pinned cb, before launch
            if not state["published"]:
                state["published"] = True
                state["svc"].publish(moved)

        svc = KMeansService.from_estimator(fitted, buckets=BUCKETS,
                                           window_s=0.0, on_dispatch=hook)
        state["svc"] = svc
        q = np.asarray(x[:16], np.float32)
        r1 = svc.predict(q)
        assert r1.version == 1                       # old codebook honored
        assert np.array_equal(r1.labels, np.asarray(fitted.predict(q)))
        r2 = svc.predict(q)
        assert r2.version == 2                       # swap visible next batch
        assert np.array_equal(
            r2.labels, _oracle(q, svc.store.get(2).centroids)[0])

    def test_refine_publishes_partial_fit_result(self, fitted, blobs):
        x, _ = blobs
        svc = fitted.to_service(buckets=BUCKETS, window_s=0.0)
        v0 = svc.store.current().version
        assert svc.refine(np.asarray(x[:64], np.float32)) == v0 + 1
        assert np.array_equal(
            np.asarray(svc.store.current().centroids),
            np.asarray(fitted.cluster_centers_, np.float32))

    def test_state_round_trip_serves_identically(self, fitted, blobs, svc):
        x, _ = blobs
        q = np.asarray(x[:20], np.float32)
        clone = KMeansService.from_state(svc.get_state())
        assert clone.compiler.buckets == svc.compiler.buckets
        a, b = svc.predict(q), clone.predict(q)
        assert np.array_equal(a.labels, b.labels)
        assert np.array_equal(
            np.asarray(svc.store.current().centroids),
            np.asarray(clone.store.current().centroids))

    def test_to_service_picks_up_tuned_plan(self, fitted):
        """With no explicit buckets, the handoff reads the ladder that
        plan_ladder persisted in the estimator's own autotune cache."""
        plan = plan_ladder(K, F, cache=fitted.autotune,
                           min_rows=8, max_rows=32)
        svc = fitted.to_service()
        assert svc.compiler.buckets == plan.buckets
        assert svc.batcher.window_s == pytest.approx(plan.window_us * 1e-6)


class TestLadderPlanAndCacheV7:
    def test_plan_contains_top_bucket_and_winners(self):
        plan = plan_ladder(K, F, min_rows=8, max_rows=64)
        assert plan.buckets[-1] == 64
        assert set(plan.winners) == set(plan.buckets)
        assert plan.window_us > 0

    def test_ladder_persists_and_reloads(self, tmp_path):
        path = str(tmp_path / "serve.json")
        cache = AutotuneCache(path)
        plan = plan_ladder(K, F, cache=cache, min_rows=8, max_rows=32)
        cache.save()
        fresh = AutotuneCache(path)
        hit = fresh.lookup_ladder(K, F)
        assert hit is not None
        buckets, window_us = hit
        assert buckets == plan.buckets
        assert window_us == pytest.approx(plan.window_us)
        # the per-bucket tile winners landed under the serve kind
        v, p = fresh.lookup(plan.buckets[-1], K, F, kind="serve")
        assert (v, p) == plan.winners[plan.buckets[-1]]
        with open(path) as fh:
            on_disk = json.load(fh)
        assert on_disk["schema"] == SCHEMA_VERSION == 7
        assert "ladder:3-4" in on_disk["kinds"]["serve/float32/b0"]

    def test_lookup_ladder_misses_cleanly(self):
        assert AutotuneCache().lookup_ladder(K, F) is None

    def test_v6_file_passthrough_upgrades_on_save(self, tmp_path):
        """v6 tables (no serve entries) load unchanged and write back as
        v7 with their winners intact."""
        path = str(tmp_path / "v6.json")
        bucket = shape_bucket(1024, 64, 64)
        with open(path, "w") as fh:
            json.dump({"schema": 6, "kinds": {
                "assign/float32/b0": {bucket: ["generic", 64, 128, 128]}}},
                fh)
        cache = AutotuneCache(path)
        v, p = cache.lookup(1024, 64, 64)
        assert v == "generic"
        assert (p.block_m, p.block_k, p.block_f) == (64, 128, 128)
        cache.save()
        with open(path) as fh:
            on_disk = json.load(fh)
        assert on_disk["schema"] == 7
        assert on_disk["kinds"]["assign/float32/b0"][bucket] == \
            ["generic", 64, 128, 128]

    def test_serve_model_score_charges_dispatch(self):
        from repro import hw
        from repro.core import autotune
        _, p = autotune.select_params(128, K, F, kind="serve")
        serve = autotune.model_score(128, K, F, p, kind="serve")
        assign = autotune.model_score(128, K, F, p, kind="assign")
        assert serve == pytest.approx(assign + hw.DISPATCH_OVERHEAD_S)

    def test_zero_row_shapes_do_not_crash_selection(self):
        from repro.core import autotune
        variant, p = autotune.select_params(0, K, F, kind="serve")
        assert p.block_m >= 1


class TestChunkedPredictEdges:
    """The ops- and estimator-level guarantees the serving layer builds
    on: 0 rows, 1 row and beyond-one-chunk requests are exact."""

    def test_ops_fused_assign_zero_rows(self):
        from repro.kernels import ops
        am, md = ops.fused_assign(jnp.zeros((0, F), jnp.float32),
                                  jnp.zeros((K, F), jnp.float32))
        assert am.shape == (0,) and md.shape == (0,)

    @pytest.mark.parametrize("m", [0, 1, 200])
    def test_estimator_chunked_predict(self, blobs, m):
        x, _ = blobs
        km = KMeans(K, max_iter=5, random_state=0, backend="lloyd_xla",
                    predict_chunk_rows=64).fit(x)
        q = np.asarray(x[:m], np.float32)
        labels = np.asarray(km.predict(q))
        assert labels.shape == (m,)
        if m:
            assert np.array_equal(
                labels, _oracle(q, km.cluster_centers_)[0])


class TestAnalysisCoverage:
    def test_serve_recompile_scenario_registered(self):
        from repro.analysis.recompile import default_scenarios
        names = [s.name for s in default_scenarios()]
        assert "serve-aot-predict-warm" in names

    def test_serve_is_a_linted_hot_path(self):
        from repro.analysis import lint
        bad = "def f(v):\n    return v.item()\n"
        assert [x.rule for x in
                lint.lint_source(bad, "src/repro/serve/f.py")] == \
            ["host-sync"]
