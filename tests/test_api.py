"""repro.api surface: estimator round-trips, streaming partial_fit,
FaultPolicy matrix, backend-registry capabilities, injectable autotune."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AssignmentBackend, AutotuneCache,
                       BackendCapabilityError, FaultPolicy, InjectionCampaign,
                       KMeans, NotFittedError, get_backend, list_backends,
                       register_backend)
from repro.data.blobs import make_blobs


@pytest.fixture(scope="module")
def blobs():
    return make_blobs(4000, 24, 8, seed=1, spread=0.5)


def _purity(assign, labels, k):
    assign, labels = np.asarray(assign), np.asarray(labels)
    total = 0
    for j in range(k):
        members = labels[assign == j]
        if len(members):
            total += np.bincount(members).max()
    return total / len(labels)


class TestEstimator:
    def test_fit_predict_equals_fit_then_predict(self, blobs):
        x, _ = blobs
        lab = KMeans(8, max_iter=30, random_state=0).fit_predict(x)
        km = KMeans(8, max_iter=30, random_state=0).fit(x)
        assert np.array_equal(np.asarray(lab), np.asarray(km.predict(x)))

    def test_fit_recovers_clusters(self, blobs):
        x, labels = blobs
        km = KMeans(8, max_iter=50, tol=1e-5, random_state=0).fit(x)
        assert km.n_iter_ < 50
        assert _purity(km.labels_, labels, 8) > 0.95
        assert km.inertia_ == pytest.approx(-km.score(x), rel=1e-5)

    def test_transform_shape_and_consistency(self, blobs):
        x, _ = blobs
        km = KMeans(8, max_iter=20, random_state=0).fit(x)
        d = km.transform(x[:100])
        assert d.shape == (100, 8)
        assert np.array_equal(np.asarray(jnp.argmin(d, axis=1)),
                              np.asarray(km.predict(x[:100])))

    def test_predict_before_fit_raises(self, blobs):
        x, _ = blobs
        with pytest.raises(NotFittedError):
            KMeans(8).predict(x)

    def test_state_round_trip(self, blobs):
        x, _ = blobs
        km = KMeans(8, max_iter=25,
                    fault=FaultPolicy.correct(), random_state=3).fit(x)
        st = km.get_state()
        km2 = KMeans.from_state(st)
        assert km2.fault == km.fault
        assert np.array_equal(np.asarray(km2.predict(x)),
                              np.asarray(km.labels_))
        # the state dict is plain: survives a numpy savez round trip
        import io
        buf = io.BytesIO()
        np.savez(buf, centers=st["cluster_centers"])
        buf.seek(0)
        back = np.load(buf)["centers"]
        assert np.array_equal(back, st["cluster_centers"])


class TestPartialFit:
    def test_streamed_blobs_converge(self, blobs):
        x, labels = blobs
        km = KMeans(8, random_state=0)
        for epoch in range(4):
            for i in range(0, x.shape[0], 500):
                km.partial_fit(x[i:i + 500])
        assert _purity(km.predict(x), labels, 8) > 0.85

    def test_fit_with_batch_size_uses_minibatches(self, blobs):
        x, labels = blobs
        km = KMeans(8, max_iter=30, batch_size=1024, random_state=0).fit(x)
        assert _purity(km.labels_, labels, 8) > 0.85

    def test_streaming_state_survives_round_trip(self, blobs):
        x, _ = blobs
        km = KMeans(8, random_state=0)
        km.partial_fit(x[:1000])
        km.partial_fit(x[1000:2000])
        km2 = KMeans.from_state(km.get_state())
        km2.partial_fit(x[2000:3000])
        km.partial_fit(x[2000:3000])
        np.testing.assert_allclose(np.asarray(km.cluster_centers_),
                                   np.asarray(km2.cluster_centers_),
                                   rtol=1e-6)


class TestFaultPolicyMatrix:
    @pytest.mark.parametrize("mode", ["off", "detect", "correct"])
    @pytest.mark.parametrize("update_dmr", [False, True])
    def test_policy_matrix_reaches_same_solution(self, blobs, mode,
                                                 update_dmr):
        x, _ = blobs
        policy = FaultPolicy(mode=mode, update_dmr=update_dmr)
        km = KMeans(8, max_iter=30, fault=policy, random_state=0).fit(x)
        ref = KMeans(8, max_iter=30, random_state=0).fit(x)
        assert abs(km.inertia_ - ref.inertia_) <= abs(ref.inertia_) * 1e-3
        if mode != "detect":
            # clean run: the fused kernel's threshold never fires. The
            # offline baseline's materialized-product threshold is tighter
            # and may flag fp accumulation noise (it recomputes, so the
            # solution above is still exact) — the paper's argument for
            # fusion, so no zero-detection assert there.
            assert km.detected_errors_ == 0

    def test_policy_resolution_picks_expected_backends(self):
        assert FaultPolicy.off().resolve_backend(on_tpu=False).name \
            == "gemm_fused"
        assert FaultPolicy.off().resolve_backend(on_tpu=True).name == "fused"
        assert FaultPolicy.detect().resolve_backend(on_tpu=False).name \
            == "abft_offline"
        # correct-mode protection composes with the one-pass iteration:
        # enabling FT must not forfeit the fused-update speedup
        assert FaultPolicy.correct().resolve_backend(on_tpu=False).name \
            == "lloyd_ft_xla"
        tpu = FaultPolicy.correct().resolve_backend(on_tpu=True)
        assert tpu.name == "lloyd_ft"
        assert tpu.fuses_update and tpu.supports_ft and tpu.takes_injection
        # campaigns always need the in-kernel injection surface
        camp = FaultPolicy.correct(injection=InjectionCampaign(rate=1.0))
        assert camp.resolve_backend(on_tpu=False).name == "lloyd_ft"

    def test_injection_campaign_detected_and_corrected(self, blobs):
        x, _ = blobs
        clean = KMeans(8, max_iter=30, fault=FaultPolicy.correct(),
                       random_state=0).fit(x)
        noisy = KMeans(8, max_iter=30, fault=FaultPolicy.correct(
            injection=InjectionCampaign(rate=1.0)), random_state=0).fit(x)
        assert noisy.detected_errors_ > 0
        assert abs(noisy.inertia_ - clean.inertia_) \
            <= abs(clean.inertia_) * 1e-3

    def test_injection_requires_correcting_mode(self):
        with pytest.raises(ValueError):
            FaultPolicy(mode="off", injection=InjectionCampaign())

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError):
            FaultPolicy(mode="detect_and_pray")


class TestRegistry:
    def test_builtin_ladder_registered_with_capabilities(self):
        backends = list_backends()
        for name in ("naive", "gemm", "gemm_fused", "fused", "fused_ft",
                     "abft_offline"):
            assert name in backends
        assert backends["fused_ft"].supports_ft
        assert backends["fused_ft"].takes_injection
        assert not backends["gemm_fused"].supports_ft
        assert not backends["abft_offline"].takes_injection

    def test_injection_into_non_ft_backend_rejected(self):
        policy = FaultPolicy.correct(injection=InjectionCampaign(rate=1.0))
        with pytest.raises(BackendCapabilityError):
            KMeans(4, fault=policy, backend="abft_offline")

    def test_protected_policy_rejects_unprotected_backend(self):
        with pytest.raises(BackendCapabilityError):
            KMeans(4, fault=FaultPolicy.correct(), backend="gemm_fused")

    def test_direct_injection_call_rejected(self):
        b = get_backend("gemm_fused")
        x = jnp.ones((16, 8))
        c = jnp.ones((4, 8))
        with pytest.raises(BackendCapabilityError):
            b(x, c, inj=jnp.zeros((8,), jnp.int32))

    def test_custom_backend_registration(self, blobs):
        x, _ = blobs

        def silly(xx, cc):
            d = jnp.sum((xx[:, None, :] - cc[None]) ** 2, axis=-1)
            return (jnp.argmin(d, axis=1).astype(jnp.int32),
                    jnp.min(d, axis=1), jnp.zeros((), jnp.int32))

        register_backend(AssignmentBackend("test_custom", silly))
        try:
            km = KMeans(8, max_iter=10, backend="test_custom",
                        random_state=0).fit(x[:512])
            assert km.cluster_centers_.shape == (8, x.shape[1])
        finally:
            list_backends()   # registry snapshot still sane
            from repro.api.registry import _REGISTRY
            _REGISTRY.pop("test_custom", None)


class TestAutotuneInjection:
    def test_estimator_uses_injected_cache(self, tmp_path, blobs):
        x, _ = blobs
        from repro.kernels.ops import KernelParams
        cache = AutotuneCache(str(tmp_path / "t.json"))
        # seed the exact shape bucket fit() will look up, with a
        # distinctive block_m no model winner would pick for this shape
        cache.put(1024, 8, 16, KernelParams(64, 128, 128))
        km = KMeans(8, max_iter=5, backend="fused", autotune=cache,
                    random_state=0)
        km.fit(x[:1024, :16])
        # the estimator consulted *this* cache, not a module global
        p = km._resolve_params(1024, 16)
        assert p.block_m == 64
        default = KMeans(8, backend="fused")._resolve_params(1024, 16)
        assert default.block_m != 64
