"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
interpret=True (kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # deterministic fallback (see _hypothesis_stub)
    from _hypothesis_stub import given, settings, st

from repro.core.autotune import parameter_space, feasible
from repro.kernels import ops, ref
from repro.kernels.distance_argmin_ft import make_injection
from repro.kernels.ops import KernelParams


def _data(m, k, f, seed=0, dtype=jnp.float32):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, f), dtype)
    c = jax.random.normal(kc, (k, f), dtype)
    return x, c


def _assert_assign_matches(am, md, x, c, atol=1e-3):
    """Robust check: chosen centroid's distance equals the row minimum
    (immune to fp ties), plus exact-index match rate ~1 for random data."""
    d_ref = ref.distance_matrix(x, c)
    chosen = jnp.take_along_axis(d_ref, am[:, None].astype(jnp.int32),
                                 axis=1)[:, 0]
    best = jnp.min(d_ref, axis=1)
    np.testing.assert_allclose(chosen, best, rtol=1e-4, atol=atol)


class TestFusedDistanceArgmin:
    @pytest.mark.parametrize("m,k,f", [
        (256, 128, 512),          # exactly one tile
        (512, 256, 1024),         # multi-tile all dims
        (1024, 128, 512),
        (300, 77, 130),           # ragged: exercises padding
        (64, 8, 32),              # tiny: block clamping
    ])
    def test_matches_oracle(self, m, k, f):
        x, c = _data(m, k, f)
        am, md = ops.fused_assign(x, c, interpret=True)
        rmd, ram = ref.distance_argmin(x, c)
        assert am.shape == (m,) and md.shape == (m,)
        _assert_assign_matches(am, md, x, c)
        match = float(jnp.mean((am == ram).astype(jnp.float32)))
        assert match > 0.999, f"argmin mismatch rate {1-match:.4f}"

    @pytest.mark.parametrize("params", [
        KernelParams(64, 128, 128),
        KernelParams(128, 256, 256),
        KernelParams(512, 128, 512),
    ])
    def test_parameter_sweep(self, params):
        """The code-generation analogue: every feasible parameter set is a
        correct kernel (paper's compile-and-run filter)."""
        x, c = _data(512, 256, 512, seed=3)
        am, md = ops.fused_assign(x, c, params, interpret=True)
        _assert_assign_matches(am, md, x, c)

    def test_bf16_inputs(self):
        x, c = _data(256, 128, 256, seed=4)
        am, _ = ops.fused_assign(x.astype(jnp.bfloat16),
                                 c.astype(jnp.bfloat16), interpret=True)
        rmd, ram = ref.distance_argmin(x, c)
        # bf16 rounding can flip near-ties; demand 99% agreement
        assert float(jnp.mean((am == ram).astype(jnp.float32))) > 0.99


class TestFusedDistanceArgminFT:
    def test_clean_no_detection(self):
        x, c = _data(512, 256, 1024, seed=5)
        am, md, det = ops.fused_assign_ft(x, c, interpret=True)
        assert int(det) == 0
        _assert_assign_matches(am, md, x, c)

    # injections address tile coordinates -> pin the tile parameters
    PARAMS = KernelParams(block_m=256, block_k=128, block_f=512)

    @pytest.mark.parametrize("tile", [(0, 0, 0), (1, 1, 0), (0, 1, 1)])
    @pytest.mark.parametrize("delta", [1e4, -1e4])
    def test_injected_error_corrected(self, tile, delta):
        x, c = _data(512, 256, 1024, seed=6)
        inj = make_injection(tile[0], tile[1], tile[2], 13, 57, delta)
        am, md, det = ops.fused_assign_ft(x, c, self.PARAMS, inj=inj,
                                          interpret=True)
        assert int(det) == 1
        _assert_assign_matches(am, md, x, c)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1), st.integers(0, 1), st.integers(0, 255),
           st.integers(0, 127), st.floats(1e2, 1e6))
    def test_property_any_tile_position(self, mt, ct, row, col, delta):
        x, c = _data(512, 256, 512, seed=7)
        inj = make_injection(mt, ct, 0, row, col, delta)
        am, md, det = ops.fused_assign_ft(x, c, self.PARAMS, inj=inj,
                                          interpret=True)
        assert int(det) == 1
        _assert_assign_matches(am, md, x, c)


class TestMatmulABFT:
    @pytest.mark.parametrize("m,k,n", [(256, 512, 256), (512, 512, 512)])
    def test_clean(self, m, k, n):
        x = jax.random.normal(jax.random.PRNGKey(8), (m, k))
        y = jax.random.normal(jax.random.PRNGKey(9), (k, n))
        d, det = ops.abft_matmul(x, y, interpret=True)
        assert int(det) == 0
        np.testing.assert_allclose(d, ref.matmul(x, y), rtol=2e-4, atol=2e-3)

    def test_injected_corrected(self):
        x = jax.random.normal(jax.random.PRNGKey(10), (256, 512))
        y = jax.random.normal(jax.random.PRNGKey(11), (512, 256))
        inj = make_injection(0, 0, 0, 7, 31, 5e4)
        d, det = ops.abft_matmul(x, y, inj=inj, interpret=True)
        assert int(det) == 1
        np.testing.assert_allclose(d, ref.matmul(x, y), rtol=2e-4, atol=2e-2)

    def test_ragged_shapes(self):
        x = jax.random.normal(jax.random.PRNGKey(12), (100, 300))
        y = jax.random.normal(jax.random.PRNGKey(13), (300, 50))
        d, det = ops.abft_matmul(x, y, interpret=True)
        np.testing.assert_allclose(d, ref.matmul(x, y), rtol=2e-4, atol=2e-3)


class TestAutotuneSpace:
    def test_paper_pruning_rules(self):
        space = [p for p in parameter_space() if feasible(p)]
        assert len(space) >= 20   # paper: ~150 kernels; pruned set is rich
        for p in space:
            assert p.block_m % 8 == 0
            assert p.block_k % 128 == 0
            assert p.block_f % 128 == 0
            assert p.vmem_bytes() <= 96 * 2**20

    def test_model_selection_prefers_balanced_tiles_for_big_problems(self):
        from repro.core.autotune import select_params
        _, p = select_params(131072, 128, 128, mode="model")
        assert p.block_k <= 256   # K=128 padded: huge block_k wastes MXU
