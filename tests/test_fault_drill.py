"""Fault drills: elastic shrink + checkpoint-restart under simulated
worker loss, and crash-injection on the checkpoint write path.

The drill methodology mirrors the paper's fail-stop model (§II-A): a
:class:`~repro.ft.elastic.FailureSchedule` raises
:class:`~repro.ft.elastic.WorkerLossError` at a chosen iteration, the
driver shrinks the mesh and restores the newest snapshot, and the resumed
trajectory must be *deterministic* — on integer data, bit-identical to a
fit that ran uninterrupted on the shrunken mesh (exact psums make the
pre-loss iterations mesh-shape-invariant, and the checkpoint replays the
exact centroids).
"""
import os

import numpy as np
import pytest

from _mesh import run_with_devices


class TestElasticDrill:
    pytestmark = pytest.mark.multidevice

    def test_kill_at_first_mid_and_last_iteration(self):
        """Lose workers 6+7 (the second host's tail) at iteration 0, 5
        (a snapshot boundary) and 11 (the final iteration): every drill
        resumes after plan_rescale + restore and lands bit-identically on
        the uninterrupted 6-device fit. Kill-at-0 exercises the
        no-snapshot path (restart from the initial seeds)."""
        out = run_with_devices("""
        import tempfile
        import numpy as np
        import jax.numpy as jnp
        from repro.api import FaultPolicy, KMeans
        from repro.dist.kmeans_dist import DistributedKMeans, \\
            restore_estimator
        from repro.dist.sharding import mesh2d
        from repro.ft import Checkpointer, FailureSchedule

        rng = np.random.default_rng(1)
        M, K, F = 1680, 8, 16
        x = rng.integers(-20, 20, size=(M, F)).astype(np.float32)
        c0 = x[rng.choice(M, K, replace=False)].copy()

        def make_est():
            return KMeans(n_clusters=K, max_iter=12, tol=1e-4,
                          random_state=0, fault=FaultPolicy.elastic())

        d_ref = DistributedKMeans(make_est(), mesh2d(6))
        c_ref, _, in_ref, it_ref, _ = d_ref.fit(d_ref.shard_data(x), c0)
        c_ref = np.asarray(c_ref)

        for kill_at in (0, 5, 11):
            d = DistributedKMeans(make_est(), mesh2d(8, hosts=2))
            with tempfile.TemporaryDirectory() as td:
                ck = Checkpointer(td, async_write=False)
                sched = FailureSchedule({kill_at: (6, 7)})
                c, am, inertia, completed, det, restarts = d.fit_elastic(
                    x, c0, checkpointer=ck, checkpoint_interval=5,
                    on_iteration=sched)
                same = bool((np.asarray(c) == c_ref).all())
                shape = dict(d.mesh.shape)
                print(f"KILL{kill_at}", restarts, completed, same,
                      float(inertia) == float(in_ref),
                      shape.get("host", 0) * shape.get("row", 0))
                est2, it2 = restore_estimator(ck)
                print(f"RESTORE{kill_at}", est2 is not None
                      and est2.fault.worker_loss, it2,
                      est2 is not None and est2.n_clusters)
        """)
        for kill in (0, 5, 11):
            restarts, completed, same, in_same, devs = \
                out.split(f"KILL{kill} ")[1].split()[:5]
            assert (restarts, completed, same, in_same, devs) == \
                ("1", "12", "True", "True", "6"), (kill, out)
            # the checkpoint carries the full get_state: estimator (with
            # its elastic policy) rebuilds from the snapshot alone
            assert f"RESTORE{kill} shrink 12 {8}" in out

    def test_fail_policy_propagates_loss(self):
        """worker_loss="fail" (the default) is fail-stop: the drill error
        reaches the caller, nothing shrinks."""
        out = run_with_devices("""
        import tempfile
        import numpy as np
        from repro.api import KMeans
        from repro.dist.kmeans_dist import DistributedKMeans
        from repro.dist.sharding import mesh2d
        from repro.ft import Checkpointer, FailureSchedule, WorkerLossError

        rng = np.random.default_rng(1)
        x = rng.integers(-20, 20, size=(1680, 16)).astype(np.float32)
        c0 = x[:8].copy()
        d = DistributedKMeans(KMeans(8, max_iter=6, random_state=0),
                              mesh2d(8, hosts=2))
        with tempfile.TemporaryDirectory() as td:
            try:
                d.fit_elastic(x, c0,
                              checkpointer=Checkpointer(td,
                                                        async_write=False),
                              on_iteration=FailureSchedule({2: (7,)}))
                print("RAISED False")
            except WorkerLossError as e:
                print("RAISED True", list(e.lost))
        """)
        assert "RAISED True [7]" in out


class TestPlanRescaleRows:
    def test_shrinks_rows_keeps_problem_groups(self):
        from repro.ft.elastic import plan_rescale_rows
        plan = plan_rescale_rows(list(range(8)), problems=2, hosts=2)
        assert plan.mesh_shape == (2, 2, 2)     # hosts x rows/host x prob
        assert plan.axis_names == ("host", "row", "problem")
        assert plan.data_shards == 4
        # survivor count not divisible by the host grouping: keeping all
        # devices beats preserving host topology — degrade to one group
        plan = plan_rescale_rows(list(range(6)), problems=2, hosts=2)
        assert plan.mesh_shape == (1, 3, 2)
        assert plan.data_shards == 3
        plan = plan_rescale_rows(list(range(6)), problems=1, hosts=4)
        assert plan.mesh_shape == (1, 6, 1)
        assert plan.data_shards == 6

    def test_drops_remainder_devices(self):
        from repro.ft.elastic import plan_rescale_rows
        plan = plan_rescale_rows(list(range(7)), problems=2, hosts=1)
        assert plan.mesh_shape == (1, 3, 2)
        assert len(plan.dropped_devices) == 1


class TestStragglerAggregate:
    def test_drop_shard_mean_stays_unbiased(self):
        """The unbiasedness claim behind the drop-shard rung: masking a
        straggler out of BOTH the sums and the counts renormalizes the
        mean over the surviving rows — ``psum(sums)/psum(counts)`` over
        live shards IS the exact mean of the live rows. The biased
        alternative (mean of per-shard means) disagrees whenever shard
        cluster counts are skewed; this pins the policy to the unbiased
        form."""
        import jax.numpy as jnp
        from repro.ft.elastic import StragglerPolicy
        rng = np.random.default_rng(0)
        S, K, F = 4, 3, 5
        # skewed per-shard counts so mean-of-means is visibly biased
        counts = jnp.asarray(rng.integers(1, 50, size=(S, K)),
                             jnp.float32)
        sums = jnp.asarray(rng.standard_normal((S, K, F)),
                           jnp.float32) * counts[..., None]
        live = jnp.asarray([True, True, False, True])

        agg_s, agg_c = StragglerPolicy.aggregate(sums, counts, live)
        got = np.asarray(agg_s / agg_c[:, None])
        # ground truth: exact mean over the surviving shards' rows
        live_np = np.asarray(live)
        want = (np.asarray(sums)[live_np].sum(axis=0)
                / np.asarray(counts)[live_np].sum(axis=0)[:, None])
        np.testing.assert_allclose(got, want, rtol=1e-6)

        # the biased form differs on skewed counts — proves the test has
        # teeth (it would catch a mean-of-means regression)
        per_shard_means = np.asarray(sums) / np.asarray(counts)[..., None]
        biased = per_shard_means[live_np].mean(axis=0)
        assert np.abs(biased - want).max() > 1e-3

    def test_all_live_matches_plain_sum(self):
        import jax.numpy as jnp
        from repro.ft.elastic import StragglerPolicy
        rng = np.random.default_rng(1)
        sums = jnp.asarray(rng.standard_normal((3, 4, 2)), jnp.float32)
        counts = jnp.asarray(rng.integers(1, 9, size=(3, 4)), jnp.float32)
        live = jnp.ones((3,), jnp.bool_)
        agg_s, agg_c = StragglerPolicy.aggregate(sums, counts, live)
        np.testing.assert_allclose(np.asarray(agg_s),
                                   np.asarray(sums).sum(axis=0), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(agg_c),
                                   np.asarray(counts).sum(axis=0),
                                   rtol=1e-6)


class TestCheckpointAtomicity:
    def test_crash_mid_write_preserves_previous_snapshot(self, tmp_path,
                                                         monkeypatch):
        """Crash injection between the bytes and the rename: os.replace
        raising mid-save must leave the previous snapshot untouched and
        restorable — the tmp+fsync+rename protocol's whole point."""
        from repro.ft.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, {"c": np.arange(4.0, dtype=np.float32)})

        real_replace = os.replace

        def exploding_replace(src, dst):
            raise OSError("simulated crash mid-replace")

        monkeypatch.setattr(os, "replace", exploding_replace)
        with pytest.raises(OSError):
            ck.save(2, {"c": np.full(4, 9.0, dtype=np.float32)})
        monkeypatch.setattr(os, "replace", real_replace)

        # fresh process semantics: a new Checkpointer over the directory
        ck2 = Checkpointer(str(tmp_path), async_write=False)
        st = ck2.restore()
        assert st is not None and st["_step"] == 1
        np.testing.assert_array_equal(st["c"],
                                      np.arange(4.0, dtype=np.float32))

    def test_corrupt_newest_snapshot_falls_back(self, tmp_path):
        """Torn bytes under the newest name (storage lost the data after
        the rename): restore walks back to the newest loadable snapshot;
        pinning the broken step raises instead of substituting."""
        from repro.ft.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, {"c": np.arange(3.0, dtype=np.float32)})
        ck.save(2, {"c": np.full(3, 2.0, dtype=np.float32)})
        with open(ck._path(2), "wb") as fh:
            fh.write(b"not a zipfile")
        st = ck.restore()
        assert st is not None and st["_step"] == 1
        with pytest.raises(Exception):
            ck.restore(step=2)

    def test_all_snapshots_corrupt_returns_none(self, tmp_path):
        from repro.ft.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, {"c": np.zeros(2, dtype=np.float32)})
        with open(ck._path(1), "wb") as fh:
            fh.write(b"garbage")
        assert ck.restore() is None


class TestFailureSchedule:
    def test_fires_once_per_entry(self):
        from repro.ft.elastic import FailureSchedule, WorkerLossError
        sched = FailureSchedule({3: (1, 2)})
        sched(0)
        sched(2)
        with pytest.raises(WorkerLossError) as ei:
            sched(3)
        assert ei.value.lost == (1, 2)
        sched(3)    # popped: the restarted trajectory passes through
