"""Beyond-paper kernels: DMR-fused centroid update + flash attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.centroid_update_dmr import centroid_update_dmr
from repro.kernels.flash_attention import flash_attention


class TestCentroidUpdateDMR:
    @pytest.mark.parametrize("m,f,k", [(2048, 128, 16), (1024, 256, 8)])
    def test_matches_oracle(self, m, f, k):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, f), jnp.float32)
        a = jax.random.randint(jax.random.PRNGKey(1), (m,), 0, k)
        sums, counts, bad = centroid_update_dmr(x, a, k, interpret=True)
        rs, rc = ref.centroid_update(x, a, k)
        np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-3)
        np.testing.assert_array_equal(counts, rc)
        assert int(bad) == 0   # replicas agree on clean hardware

    def test_padded_rows_ignored(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1024, 64), jnp.float32)
        a = jax.random.randint(jax.random.PRNGKey(3), (1024,), 0, 8)
        rs, rc = ref.centroid_update(x, a, 8)
        xp = jnp.pad(x, ((0, 1024), (0, 0)), constant_values=7.0)
        ap = jnp.concatenate([a, jnp.full((1024,), -1, jnp.int32)])
        sums, counts, bad = centroid_update_dmr(xp, ap, 8, interpret=True)
        np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-3)
        np.testing.assert_array_equal(counts, rc)


def _ref_attention(q, k, v, qpos, kpos, causal, window):
    g = q.shape[1] // k.shape[1]
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk,
                   preferred_element_type=jnp.float32)
    mask = kpos[None, :] >= 0
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vv)


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 128),
                                               (False, 0)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, causal, window, dtype):
        B, H, KV, S, HD = 1, 4, 2, 512, 64
        q = (jax.random.normal(jax.random.PRNGKey(0), (B, H, S, HD))
             * 0.3).astype(dtype)
        k = (jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, HD))
             * 0.3).astype(dtype)
        v = jax.random.normal(jax.random.PRNGKey(2),
                              (B, KV, S, HD)).astype(dtype)
        pos = jnp.arange(S)
        out = flash_attention(q, k, v, pos, pos, causal=causal,
                              window=window, block_q=128, block_k=128,
                              interpret=True)
        r = _ref_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), pos, pos, causal, window)
        atol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(out.astype(jnp.float32), r,
                                   rtol=1e-3, atol=atol)

    def test_padded_keys_masked(self):
        B, H, KV, S, HD = 1, 2, 1, 256, 32
        q = jax.random.normal(jax.random.PRNGKey(4), (B, H, S, HD)) * 0.3
        k = jax.random.normal(jax.random.PRNGKey(5), (B, KV, S, HD)) * 0.3
        v = jax.random.normal(jax.random.PRNGKey(6), (B, KV, S, HD))
        pos = jnp.arange(S)
        # pad keys to 2S with positions = -1 (empty); result must match
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, S), (0, 0)),
                     constant_values=3.0)
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, S), (0, 0)),
                     constant_values=3.0)
        kpos = jnp.concatenate([pos, jnp.full((S,), -1)])
        out = flash_attention(q, kp, vp, pos, kpos, causal=True,
                              block_q=128, block_k=128, interpret=True)
        base = flash_attention(q, k, v, pos, pos, causal=True,
                               block_q=128, block_k=128, interpret=True)
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5)
