"""Launcher + optimizer + autotune-table coverage."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_module(mod, args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-m", mod] + args,
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-2500:]
    return out.stdout


class TestTrainLauncher:
    def test_train_and_restore(self, tmp_path):
        common = ["--arch", "internlm2-1.8b", "--smoke", "--lr", "1e-3",
                  "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"]
        out = _run_module("repro.launch.train", common + ["--steps", "10"])
        assert "done; snapshots:" in out
        # crash/restore: continue to 15 from the step-10 snapshot
        out2 = _run_module("repro.launch.train",
                           common + ["--steps", "15", "--restore"])
        assert "restored checkpoint at step 10" in out2

    def test_serve_launcher(self):
        out = _run_module("repro.launch.serve",
                          ["--arch", "whisper-medium", "--requests", "2",
                           "--batch", "2", "--gen", "4", "--prompt-len", "8"])
        assert "served 2/2" in out


class TestSchedules:
    def test_wsd_shape(self):
        from repro.train.optimizer import TrainConfig, lr_at
        cfg = TrainConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=100, schedule="wsd",
                          wsd_decay_frac=0.2, min_lr_frac=0.1)
        assert float(lr_at(cfg, 0)) == 0.0
        assert abs(float(lr_at(cfg, 10)) - 1.0) < 1e-6       # warm
        assert abs(float(lr_at(cfg, 50)) - 1.0) < 1e-6       # stable
        assert float(lr_at(cfg, 90)) < 1.0                   # decaying
        assert abs(float(lr_at(cfg, 100)) - 0.1) < 1e-6      # floor

    def test_cosine_monotone_after_warmup(self):
        from repro.train.optimizer import TrainConfig, lr_at
        cfg = TrainConfig(learning_rate=1.0, warmup_steps=5,
                          total_steps=50, schedule="cosine")
        vals = [float(lr_at(cfg, s)) for s in range(5, 51)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))

    def test_adamw_descends_quadratic(self):
        from repro.train.optimizer import (TrainConfig, adamw_update,
                                           init_opt_state)
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=50,
                          weight_decay=0.0, schedule="constant")
        params = {"w": jnp.asarray([3.0, -2.0])}
        opt = init_opt_state(params, cfg)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, opt, m = adamw_update(params, grads, opt, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


class TestAutotuneTable:
    def test_build_and_lookup_roundtrip(self, tmp_path):
        from repro.api import AutotuneCache, shape_bucket
        from repro.api.cache import SCHEMA_VERSION
        path = str(tmp_path / "table.json")
        cache = AutotuneCache(path)
        table = cache.build([(16384, 64, 64), (131072, 128, 128)],
                            mode="model")
        assert len(table["assign/float32/b0"]) == 2
        v, p = cache.lookup(16384, 64, 64)
        assert [v, p.block_m, p.block_k, p.block_f] == \
            table["assign/float32/b0"]["14-6-6"]
        # a fresh cache instance reloads the persisted winners
        fresh = AutotuneCache(path)
        w, q = fresh.lookup(131072, 128, 128)
        assert [w, q.block_m, q.block_k, q.block_f] == \
            table["assign/float32/b0"][shape_bucket(131072, 128, 128)]
        with open(path) as fh:
            assert json.load(fh) == {"schema": SCHEMA_VERSION,
                                     "kinds": table}

    def test_legacy_v1_table_loads_as_assign_kind(self, tmp_path):
        """v1 files (flat bucket -> blocks) keep working: their winners
        were tuned for the f32 assignment-only kernel (generic template)
        and must serve it — and only it."""
        from repro.api import AutotuneCache, shape_bucket
        path = str(tmp_path / "v1.json")
        with open(path, "w") as fh:
            json.dump({shape_bucket(1024, 64, 64): [64, 128, 128]}, fh)
        cache = AutotuneCache(path)
        v, p = cache.lookup(1024, 64, 64)               # kind="assign"
        assert v == "generic"
        assert [p.block_m, p.block_k, p.block_f] == [64, 128, 128]
        # the lloyd kernel never inherits an assignment-only winner; it
        # falls through to its own analytical selection
        q = cache.lookup(1024, 64, 64, kind="lloyd")
        assert q is not None
        # upgrading on save leaves the entry under the assign kind, f32
        cache.save()
        with open(path) as fh:
            on_disk = json.load(fh)
        assert on_disk["schema"] >= 3
        assert on_disk["kinds"]["assign/float32/b0"][
            shape_bucket(1024, 64, 64)] == ["generic", 64, 128, 128]

    def test_kinds_are_isolated(self, tmp_path):
        from repro.api import AutotuneCache
        from repro.kernels.ops import KernelParams
        cache = AutotuneCache()
        # a distinctive winner stored for the assignment kernel only
        cache.put(2048, 128, 256, KernelParams(1024, 512, 1024))
        _, pa = cache.lookup(2048, 128, 256)
        _, pl = cache.lookup(2048, 128, 256, kind="lloyd")
        assert [pa.block_m, pa.block_k, pa.block_f] == [1024, 512, 1024]
        assert (pl.block_m, pl.block_k, pl.block_f) != (1024, 512, 1024)

    def test_dtypes_are_isolated(self, tmp_path):
        """A winner tuned for f32 tiles must never serve the bf16/fp16
        templates — byte sizing and sublane alignment differ."""
        import jax.numpy as jnp
        from repro.api import AutotuneCache
        from repro.kernels.ops import KernelParams
        cache = AutotuneCache()
        cache.put(2048, 128, 256, KernelParams(1024, 512, 1024),
                  variant="generic")                     # f32 entry
        _, p32 = cache.lookup(2048, 128, 256)
        _, pbf = cache.lookup(2048, 128, 256, dtype=jnp.bfloat16)
        assert [p32.block_m, p32.block_k, p32.block_f] == [1024, 512, 1024]
        assert (pbf.block_m, pbf.block_k, pbf.block_f) != (1024, 512, 1024)

    def test_caches_are_isolated_per_instance(self, tmp_path):
        from repro.api import AutotuneCache
        from repro.kernels.ops import KernelParams
        a = AutotuneCache(str(tmp_path / "a.json"))
        b = AutotuneCache()               # in-memory only
        a.put(1024, 64, 64, KernelParams(64, 128, 128))
        _, pa = a.lookup(1024, 64, 64)
        _, pb = b.lookup(1024, 64, 64)    # falls back to the model winner
        assert [pa.block_m, pa.block_k, pa.block_f] == [64, 128, 128]
        assert (pb.block_m, pb.block_k, pb.block_f) != (0, 0, 0)
