"""int8 distance template: quantized GEMM + f32 scale-correction epilogue.

The exactness claims under test (see repro.kernels.distance_argmin_int8):

* on *quantization-safe* data — integer entries in [-127, 127] with a
  +-127 entry pinned per row, so every per-row scale is exactly 1.0 and
  quantization is the identity — the argmin is bit-exact against the f32
  kernel, for the Pallas template (int8 carrier, interpret mode) and the
  XLA analogue alike;
* on arbitrary float data the distance error is bounded by the ~1/127
  per-operand quantization step;
* int8 dot products are bit-exact in the f32 carrier for F <= 1040
  (F * 127^2 < 2^24), which is why the off-TPU carrier is f32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FaultPolicy, KMeans
from repro.api.cache import AutotuneCache
from repro.core import assignment
from repro.kernels import ops, ref
from repro.kernels.ops import KernelParams


def _safe_data(m, k, f, seed=0):
    """Quantization-safe (X, C): integers in [-127, 127], a +-127 entry
    pinned in every row so quantize_rows yields scale exactly 1.0 and
    q == x — the int8 path then computes the same cross terms as f32."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, size=(m, f)).astype(np.float32)
    c = rng.integers(-127, 128, size=(k, f)).astype(np.float32)
    x[np.arange(m), rng.integers(0, f, m)] = 127.0
    c[np.arange(k), rng.integers(0, f, k)] = 127.0
    return jnp.asarray(x), jnp.asarray(c)


def _float_data(m, k, f, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (m, f), jnp.float32),
            jax.random.normal(kc, (k, f), jnp.float32))


class TestInt8Kernel:
    @pytest.mark.parametrize("m,k,f", [
        (256, 128, 512),          # exactly one tile
        (512, 256, 256),          # multi-tile M and K
        (300, 77, 130),           # ragged: exercises padding
        (256, 16, 128),           # small-K fast path
    ])
    def test_bitexact_argmin_on_safe_data(self, m, k, f):
        x, c = _safe_data(m, k, f)
        am_f32, _ = ops.fused_assign(x, c, KernelParams(128, 128, 128))
        for carrier in (jnp.int8, jnp.float32):
            plan = ops.plan_data_int8(x, KernelParams(128, 128, 128),
                                      carrier=carrier)
            am, md = ops.fused_assign_int8(plan, c)
            assert bool(jnp.all(am == am_f32)), f"carrier={carrier}"
            # scale 1.0 everywhere -> distances agree exactly too
            _, md_f32 = ops.fused_assign(x, c, KernelParams(128, 128, 128))
            np.testing.assert_array_equal(np.asarray(md),
                                          np.asarray(md_f32))

    def test_bitexact_vs_xla_analogue_on_safe_data(self):
        x, c = _safe_data(384, 64, 256, seed=1)
        am_p, _ = ops.fused_assign_int8(x, c, KernelParams(128, 128, 128))
        am_x, _, _ = assignment.assign_int8_xla(x, c)
        am_f, _ = ops.fused_assign(x, c, KernelParams(128, 128, 128))
        np.testing.assert_array_equal(np.asarray(am_p), np.asarray(am_f))
        np.testing.assert_array_equal(np.asarray(am_x), np.asarray(am_f))

    def test_bounded_error_on_float_data(self):
        x, c = _float_data(512, 64, 128, seed=2)
        _, md8 = ops.fused_assign_int8(x, c, KernelParams(128, 128, 128))
        _, md = ops.fused_assign(x, c, KernelParams(128, 128, 128))
        xn = jnp.sum(x * x, axis=1)
        d8, d = md8 + xn, md + xn   # true squared distances
        # per-operand quantization step is scale ~ max|row|/127; the
        # relative distance error stays well inside 2/127 per operand
        rel = float(jnp.max(jnp.abs(d8 - d) / jnp.maximum(d, 1e-3)))
        assert rel < 4.0 / 127.0, rel
        # and the argmin disagreement is rare (ties within quant noise)
        am8, _ = ops.fused_assign_int8(x, c, KernelParams(128, 128, 128))
        am, _ = ops.fused_assign(x, c, KernelParams(128, 128, 128))
        assert float(jnp.mean((am8 == am).astype(jnp.float32))) > 0.7

    def test_variant_parity(self):
        x, c = _safe_data(256, 16, 128, seed=3)
        p = KernelParams(128, 128, 128)
        am_g, md_g = ops.fused_assign_int8(x, c, p, variant="generic")
        am_s, md_s = ops.fused_assign_int8(x, c, p, variant="smallk")
        np.testing.assert_array_equal(np.asarray(am_g), np.asarray(am_s))
        np.testing.assert_array_equal(np.asarray(md_g), np.asarray(md_s))

    def test_quantplan_reuse_matches_fresh(self):
        x, c = _float_data(256, 32, 128, seed=4)
        p = KernelParams(128, 128, 128)
        plan = ops.plan_data_int8(x, p)
        am_plan, md_plan = ops.fused_assign_int8(plan, c)
        am_raw, md_raw = ops.fused_assign_int8(x, c, p)
        np.testing.assert_array_equal(np.asarray(am_plan),
                                      np.asarray(am_raw))
        np.testing.assert_array_equal(np.asarray(md_plan),
                                      np.asarray(md_raw))

    def test_unpadded_plan_rejected_by_pallas_template(self):
        x, c = _float_data(128, 16, 128, seed=5)
        plan = ops.plan_data_int8(x)           # params=None: XLA layout
        assert plan.params is None
        with pytest.raises(ValueError, match="block-padded"):
            ops.fused_assign_int8(plan, c)
        # the XLA analogue consumes it fine
        am, md, det = assignment.assign_int8_xla(plan, c)
        am_raw, md_raw, _ = assignment.assign_int8_xla(x, c)
        np.testing.assert_array_equal(np.asarray(am), np.asarray(am_raw))

    def test_f32_carrier_dot_exactness_bound(self):
        # F * 127^2 < 2^24 holds at F=1024: int-valued f32 GEMM == int32
        rng = np.random.default_rng(6)
        a = rng.integers(-127, 128, size=(64, 1024)).astype(np.int32)
        b = rng.integers(-127, 128, size=(32, 1024)).astype(np.int32)
        exact = a @ b.T
        viaf32 = (jnp.asarray(a, jnp.float32) @
                  jnp.asarray(b, jnp.float32).T)
        np.testing.assert_array_equal(np.asarray(viaf32, np.int64), exact)


class TestInt8Plumbing:
    def test_vmem_model_is_exact(self):
        plan = ops.kernel_plan("int8", 2048, 256, 512)
        p = ops.clamp_params(2048, 256, 512, ops.DEFAULT_PARAMS,
                             dtype=jnp.int8)
        assert plan.vmem_bytes() == ops.int8_vmem_bytes(p)

    def test_backend_registered_and_flagged(self):
        from repro.api.registry import get_backend
        for name in ("int8", "int8_xla"):
            b = get_backend(name)
            assert b.supports_int8 and b.kernel_kind == "int8"
            assert not b.fuses_update and not b.supports_ft

    def test_cache_keys_int8_kind_under_int8_dtype(self):
        cache = AutotuneCache()
        variant, p = cache.lookup(2048, 64, 256, kind="int8",
                                  dtype=jnp.int8)
        assert p.block_m % 32 == 0           # int8 sublane alignment
        cache.put(2048, 64, 256, p, kind="int8", dtype=jnp.int8,
                  variant=variant)
        v2, p2 = cache.lookup(2048, 64, 256, kind="int8", dtype=jnp.int8)
        assert (v2, p2) == (variant, p)

    def test_row_norms_from_quantplan(self):
        x, _ = _float_data(128, 8, 64, seed=7)
        plan = ops.plan_data_int8(x, KernelParams(32, 128, 128))
        np.testing.assert_array_equal(
            np.asarray(assignment._row_norms(plan)),
            np.asarray(jnp.sum(x * x, axis=1)))


class TestInt8Estimator:
    def _x(self, m=600, f=48, seed=0):
        return np.random.default_rng(seed).normal(
            size=(m, f)).astype(np.float32)

    def test_auto_backend_and_fit_close_to_f32(self):
        x = self._x()
        km8 = KMeans(n_clusters=7, compute_dtype="int8", max_iter=15,
                     autotune=AutotuneCache(), random_state=3)
        assert km8._backend.supports_int8
        km8.fit(x)
        kmf = KMeans(n_clusters=7, max_iter=15, autotune=AutotuneCache(),
                     random_state=3).fit(x)
        assert abs(km8.inertia_ - kmf.inertia_) / kmf.inertia_ < 0.05
        # centroids stay f32 — quantization never leaks into state
        assert km8.cluster_centers_.dtype == jnp.float32

    def test_pinned_pallas_backend_fits(self):
        x = self._x(256, 32, seed=1)
        km = KMeans(n_clusters=5, compute_dtype="int8", backend="int8",
                    max_iter=4, autotune=AutotuneCache())
        km.fit(x)
        assert km.inertia_ is not None and km.n_iter_ >= 1

    def test_predict_partial_fit_minibatch(self):
        x = self._x(512, 32, seed=2)
        km = KMeans(n_clusters=5, compute_dtype="int8", max_iter=8,
                    autotune=AutotuneCache()).fit(x)
        assert km.predict(x).shape == (512,)
        assert km.score(x) <= 0.0
        st = KMeans(n_clusters=5, compute_dtype="int8",
                    autotune=AutotuneCache())
        st.partial_fit(x[:256]).partial_fit(x[256:])
        assert st.n_iter_ == 2
        mb = KMeans(n_clusters=5, compute_dtype="int8", batch_size=128,
                    max_iter=4, autotune=AutotuneCache()).fit(x)
        assert mb.inertia_ is not None

    def test_state_roundtrip_preserves_int8(self):
        x = self._x(256, 16, seed=3)
        km = KMeans(n_clusters=4, compute_dtype="int8", max_iter=5,
                    autotune=AutotuneCache()).fit(x)
        st = km.get_state()
        assert st["config"]["compute_dtype"] == "int8"
        km2 = KMeans.from_state(st, autotune=AutotuneCache())
        assert km2._backend.supports_int8
        np.testing.assert_array_equal(np.asarray(km2.predict(x)),
                                      np.asarray(km.predict(x)))

    def test_mismatched_configs_rejected(self):
        with pytest.raises(ValueError, match="int8-quantized"):
            KMeans(compute_dtype="int8", backend="fused",
                   autotune=AutotuneCache())
        with pytest.raises(ValueError, match="compute_dtype='int8'"):
            KMeans(backend="int8_xla", autotune=AutotuneCache())
        with pytest.raises(Exception, match="fault-tolerant"):
            KMeans(compute_dtype="int8", fault=FaultPolicy.correct(),
                   autotune=AutotuneCache())
