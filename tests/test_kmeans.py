"""K-means system behaviour: convergence, strategy equivalence, FT modes,
baselines, DMR, empty clusters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import get_backend
from repro.core import (KMeans, KMeansConfig, FaultConfig, baselines, dmr)
from repro.core.kmeans import reseed_empty
from repro.data.blobs import make_blobs
from repro.kernels import ref


@pytest.fixture(scope="module")
def blobs():
    x, labels = make_blobs(4000, 24, 8, seed=1, spread=0.5)
    return x, labels


def _purity(assign, labels, k):
    assign = np.asarray(assign)
    labels = np.asarray(labels)
    total = 0
    for j in range(k):
        members = labels[assign == j]
        if len(members):
            total += np.bincount(members).max()
    return total / len(labels)


class TestStrategiesAgree:
    @pytest.mark.parametrize("strategy", ["naive", "gemm", "gemm_fused",
                                          "abft_offline"])
    def test_assignment_matches_reference(self, strategy, blobs):
        x, _ = blobs
        c = x[:16]
        am, md, det = get_backend(strategy)(x, c)
        d_ref = ref.distance_matrix(x, c)
        ram = jnp.argmin(d_ref, axis=1)
        assert float(jnp.mean((am == ram).astype(jnp.float32))) > 0.999

    def test_fused_pallas_matches(self, blobs):
        x, _ = blobs
        c = x[:16]
        am, md, det = get_backend("fused")(x, c)
        ram = jnp.argmin(ref.distance_matrix(x, c), axis=1)
        assert float(jnp.mean((am == ram).astype(jnp.float32))) > 0.999


class TestLloydConvergence:
    def test_converges_and_recovers_clusters(self, blobs):
        x, labels = blobs
        res = KMeans(KMeansConfig(k=8, max_iters=50, tol=1e-5,
                                  assignment="gemm_fused", seed=0)).fit(x)
        assert res.iterations < 50
        assert _purity(res.assign, labels, 8) > 0.95

    def test_inertia_monotonically_nonincreasing(self, blobs):
        x, _ = blobs
        history = []
        KMeans(KMeansConfig(k=8, max_iters=20, tol=0.0,
                            assignment="gemm_fused", seed=0)).fit(
            x, on_iteration=lambda it, c, inertia, shift:
                history.append(inertia))
        diffs = np.diff(history)
        assert np.all(diffs <= np.abs(np.asarray(history[:-1])) * 1e-5)

    def test_minibatch_mode(self, blobs):
        x, labels = blobs
        res = KMeans(KMeansConfig(k=8, max_iters=30, minibatch=1024,
                                  assignment="gemm_fused", seed=0)).fit(x)
        assert _purity(res.assign, labels, 8) > 0.85

    def test_kmeanspp_beats_random_init(self, blobs):
        x, _ = blobs
        r_pp = KMeans(KMeansConfig(k=8, max_iters=30, init="kmeans++",
                                   assignment="gemm_fused", seed=2)).fit(x)
        r_rand = KMeans(KMeansConfig(k=8, max_iters=30, init="random",
                                     assignment="gemm_fused", seed=2)).fit(x)
        assert float(r_pp.inertia) <= float(r_rand.inertia) * 1.5


class TestFaultTolerance:
    def test_ft_kmeans_with_continuous_injection(self, blobs):
        """Paper's claim: correctness maintained under injections."""
        x, labels = blobs
        cfg = KMeansConfig(k=8, max_iters=30, assignment="fused_ft", seed=0)
        clean = KMeans(cfg).fit(x)
        fault = KMeans(cfg).fit(x, fault=FaultConfig(rate=1.0))
        assert int(fault.detected_errors) > 0
        assert abs(float(fault.inertia) - float(clean.inertia)) \
            <= abs(float(clean.inertia)) * 1e-3

    def test_checkpoint_restart_baseline(self, blobs):
        x, _ = blobs
        # tol=0 -> fixed 25 iterations, so the 0.3/iter fault rate fires whp
        cfg = KMeansConfig(k=8, max_iters=25, tol=0.0,
                           assignment="gemm_fused", seed=0)
        km = baselines.CheckpointRestartKMeans(cfg)
        res, stats = km.fit(x, fault=FaultConfig(rate=0.3, seed=5))
        assert stats["rollbacks"] >= 1          # errors happened
        assert stats["wasted_iterations"] >= stats["rollbacks"]
        clean, _ = baselines.CheckpointRestartKMeans(cfg).fit(x)
        assert abs(float(res.inertia) - float(clean.inertia)) \
            <= abs(float(clean.inertia)) * 0.02

    def test_dmr_detects_mismatch(self):
        calls = [0]

        def flaky(x):
            calls[0] += 1
            return x + (1.0 if calls[0] == 2 else 0.0)

        # dmr() cannot be fooled by a pure function; simulate via manual
        # comparison path instead: identical fns -> no mismatch.
        out, bad = dmr.dmr(lambda x: x * 2.0, jnp.ones((8,)))
        assert not bool(bad)


class TestEdgeCases:
    def test_empty_cluster_reseeding(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 4)),
                        jnp.float32)
        centroids = jnp.concatenate([x[:7], jnp.full((1, 4), 1e6)])
        counts = jnp.asarray([8] * 7 + [0], jnp.float32)
        md = jnp.sum(x * x, axis=1)
        new_c = reseed_empty(jax.random.PRNGKey(0), x, centroids, counts, md)
        assert float(jnp.max(jnp.abs(new_c[7]))) < 1e3  # moved onto a point

    def test_k_greater_than_unique_points_does_not_crash(self):
        x = jnp.ones((16, 4))
        res = KMeans(KMeansConfig(k=8, max_iters=3,
                                  assignment="gemm_fused", seed=0,
                                  init="random")).fit(x)
        assert res.centroids.shape == (8, 4)

    def test_single_feature_dim(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(128, 1)),
                        jnp.float32)
        res = KMeans(KMeansConfig(k=4, max_iters=10,
                                  assignment="gemm_fused", seed=0)).fit(x)
        assert res.centroids.shape == (4, 1)
