"""Deterministic fallback for the ``hypothesis`` property-testing API.

When hypothesis is installed (see requirements-dev.txt) the real library is
used; otherwise this stub expands ``@given(...)`` into a seeded
``pytest.mark.parametrize`` sweep — fewer, deterministic examples, but the
suite collects and the properties still get exercised.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np
import pytest

N_EXAMPLES = 10
_SEED = 20240801


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:           # inclusive bounds
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


st = strategies


def settings(**_kw):
    """No-op decorator factory (max_examples etc. are fixed in the stub)."""
    def deco(fn):
        return fn
    return deco


def given(*strats: _Strategy):
    """Expand into N_EXAMPLES deterministic cases via parametrize."""
    def deco(fn):
        names = [p for p in inspect.signature(fn).parameters
                 if p != "self"][-len(strats):]
        rng = np.random.default_rng(_SEED)
        cases = [tuple(s.sample(rng) for s in strats)
                 for _ in range(N_EXAMPLES)]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
