"""Deterministic fallback for the ``hypothesis`` property-testing API.

CI always installs the real library (pinned in requirements-dev.txt); this
stub is the documented *offline escape hatch* for minimal environments.
When hypothesis is absent, ``@given(...)`` expands into a seeded
``pytest.mark.parametrize`` sweep — fewer, deterministic examples, but the
suite collects and the properties still get exercised.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Iterable, Sequence, TypeVar

import numpy as np
import pytest

N_EXAMPLES = 10
_SEED = 20240801

_F = TypeVar("_F", bound=Callable[..., Any])


class _Strategy:
    def __init__(self, sample: Callable[[np.random.Generator], Any]) -> None:
        self.sample = sample


class strategies:
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:           # inclusive bounds
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def sampled_from(seq: Iterable[Any]) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


st = strategies


def settings(**_kw: Any) -> Callable[[_F], _F]:
    """No-op decorator factory (max_examples etc. are fixed in the stub)."""
    def deco(fn: _F) -> _F:
        return fn
    return deco


def given(*strats: _Strategy) -> Callable[[_F], Any]:
    """Expand into N_EXAMPLES deterministic cases via parametrize."""
    def deco(fn: _F) -> Any:
        names: Sequence[str] = [p for p in inspect.signature(fn).parameters
                                if p != "self"][-len(strats):]
        rng = np.random.default_rng(_SEED)
        cases = [tuple(s.sample(rng) for s in strats)
                 for _ in range(N_EXAMPLES)]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
