"""Roofline tooling tests: the HLO analyzer's loop-aware accounting is
validated against exact analytic flop counts, and the documented
cost_analysis limitation (loop bodies counted once) is pinned down."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # pin CPU: the TPU plugin probe retries cloud metadata for minutes here
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


SCAN_PROBE = """
import jax
import jax.numpy as jnp
from repro.roofline.hlo import analyze_hlo

def scanned(w, x):
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    x, _ = jax.lax.scan(body, x, w)
    return x.sum()

w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
c = jax.jit(scanned).lower(w, x).compile()
a = analyze_hlo(c.as_text())
xla = c.cost_analysis()
xla = xla[0] if isinstance(xla, list) else xla   # jax<0.5 returns a list
print("ANALYZED", a["flops"])
print("XLA_ONCE", xla["flops"])
print("EXACT", 2 * 8 * 128 * 256 * 256)
"""


class TestHloAnalyzer:
    def test_loop_aware_flops_exact(self):
        out = run_with_devices(SCAN_PROBE, n=1)
        vals = {l.split()[0]: float(l.split()[1])
                for l in out.strip().splitlines()}
        assert vals["ANALYZED"] == vals["EXACT"]
        # and the raw cost_analysis undercounts by ~the trip count —
        # the documented reason the analyzer exists
        assert vals["XLA_ONCE"] < vals["EXACT"] / 4

    def test_sharded_per_device_flops_and_collectives(self):
        out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.roofline.hlo import analyze_hlo

        def scanned(w, x):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(body, x, w)
            return x.sum()

        kw = {}
        if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5
            kw["axis_types"] = (jax.sharding.AxisType.Auto,) * 2
        mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
        w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32,
            sharding=NamedSharding(mesh, P(None, None, "model")))
        x = jax.ShapeDtypeStruct((128, 256), jnp.float32,
            sharding=NamedSharding(mesh, P("data", None)))
        a = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text())
        print("FLOPS", a["flops"])
        print("AG", a["collectives"].get("all-gather", {}).get("count", 0))
        """)
        flops = float(out.split("FLOPS ")[1].split()[0])
        assert flops == 2 * 8 * 128 * 256 * 256 / 8   # per-device
        ag = float(out.split("AG ")[1].split()[0])
        assert ag >= 8   # one gather per scan iteration (loop-multiplied)

    def test_shape_parsing(self):
        from repro.roofline.hlo import shape_bytes, shape_dims
        assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
        assert shape_bytes("bf16[2,3,4]") == 48
        assert shape_bytes("(f32[2]{0}, s32[4]{0})") == 24
        assert shape_dims("f32[8,16]{1,0}") == [8, 16]


class TestRooflineModel:
    def test_three_terms_and_bottleneck(self):
        from repro.roofline.analysis import analyze
        record = {
            "status": "ok", "arch": "x", "shape": "train_4k",
            "mesh": "16x16", "chips": 256,
            "active_params": 2e9,
            "cost": {"flops": 1e14, "bytes_accessed": 1e11},
            "collective_bytes": 1e9,
        }
        r = analyze(record)
        assert r.bottleneck == "compute"
        assert abs(r.compute_s - 1e14 / 197e12) < 1e-6
        assert 0 < r.roofline_fraction <= 1.0

    def test_dryrun_records_analyzable(self):
        """Every OK record produced by the sweep feeds the roofline."""
        import glob, json
        from repro.roofline.analysis import analyze
        paths = glob.glob(os.path.join(REPO, "results", "dryrun", "*.json"))
        if not paths:
            pytest.skip("dry-run sweep has not been run")
        ok = 0
        for p in paths:
            with open(p) as fh:
                rec = json.load(fh)
            if rec["status"] == "ok":
                r = analyze(rec)
                assert r is not None
                assert r.compute_s >= 0 and r.memory_s >= 0
                ok += 1
        assert ok >= 20
