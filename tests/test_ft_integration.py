"""System-level fault-tolerance integration: end-to-end K-means injection
campaigns on the one-pass FT backend, ABFT-protected projections in the LM
stack, checkpoint atomicity, end-to-end training under injection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FaultPolicy, InjectionCampaign, KMeans
from repro.configs import get_config
from repro.ft import abft_dense
from repro.ft.checkpoint import Checkpointer
from repro.models import LM


def _int_blobs(m, f, k, seed, dtype):
    """Small-integer blob-ish data: exactly representable in bf16, so the
    clean trajectory is deterministic at every compute dtype."""
    rng = np.random.default_rng(seed)
    centers = rng.integers(-8, 9, (k, f))
    x = centers[rng.integers(k, size=m)] + rng.integers(-1, 2, (m, f))
    return jnp.asarray(x, jnp.float32).astype(dtype).astype(jnp.float32)


class TestKMeansInjectionEndToEnd:
    """Satellite of the one-pass FT refactor: an injected SEU in either
    verification interval — the distance GEMM or the update epilogue —
    must be corrected online so the final centroids are *bit-identical*
    to a clean run, across compute dtypes and both the smallk-shaped
    (K in one centroid tile) and generic-shaped regimes."""

    # (m, f, k): smallk-shaped and generic-shaped (padded K > one tile)
    SHAPES = [(256, 16, 8), (192, 24, 130)]

    def _fit(self, x, k, dtype, campaign):
        pol = (FaultPolicy.correct(update_dmr=False, injection=campaign)
               if campaign is not None
               else FaultPolicy.correct(update_dmr=False))
        km = KMeans(k, max_iter=5, backend="lloyd_ft", fault=pol,
                    compute_dtype=dtype, sync_every=5, random_state=0)
        return km.fit(x)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("target", ["distance", "update"])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_injected_seu_recovers_bit_identical_centroids(
            self, dtype, target, shape):
        m, f, k = shape
        x = _int_blobs(m, f, k, seed=3, dtype=jnp.dtype(dtype))
        clean = self._fit(x, k, dtype, None)
        noisy = self._fit(x, k, dtype, InjectionCampaign(
            rate=1.0, targets=target, seed=7))
        assert clean.detected_errors_ == 0
        assert noisy.detected_errors_ >= noisy.n_iter_   # one per step
        np.testing.assert_array_equal(
            np.asarray(noisy.cluster_centers_),
            np.asarray(clean.cluster_centers_))
        np.testing.assert_array_equal(np.asarray(noisy.labels_),
                                      np.asarray(clean.labels_))

    def test_dual_interval_campaign_corrects_both_per_step(self):
        m, f, k = self.SHAPES[0]
        x = _int_blobs(m, f, k, seed=5, dtype=jnp.float32)
        clean = self._fit(x, k, "float32", None)
        noisy = self._fit(x, k, "float32", InjectionCampaign(
            rate=2.0, targets="both", seed=11))
        # rate=2 on the dual-interval kernel: two corrected SEUs per step
        assert noisy.detected_errors_ == 2 * noisy.n_iter_
        np.testing.assert_array_equal(
            np.asarray(noisy.cluster_centers_),
            np.asarray(clean.cluster_centers_))


class TestFtEinsum:
    def test_disabled_is_plain_einsum(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
        abft_dense.configure(False)
        out = abft_dense.ft_einsum("bsd,df->bsf", x, w)
        np.testing.assert_allclose(out, jnp.einsum("bsd,df->bsf", x, w),
                                   rtol=1e-6)

    @pytest.mark.parametrize("spec,xs,ws", [
        ("bsd,df->bsf", (2, 8, 16), (16, 32)),
        ("bsd,dhk->bshk", (2, 8, 16), (16, 4, 8)),
        ("bshk,hkd->bsd", (2, 8, 4, 8), (4, 8, 16)),
        ("bsw,wd->bsd", (2, 8, 16), (16, 12)),
    ])
    def test_enabled_matches_plain(self, spec, xs, ws):
        x = jax.random.normal(jax.random.PRNGKey(2), xs)
        w = jax.random.normal(jax.random.PRNGKey(3), ws)
        out = abft_dense.ft_einsum(spec, x, w, enabled=True)
        np.testing.assert_allclose(out, jnp.einsum(spec, x, w),
                                   rtol=2e-4, atol=2e-4)

    def test_gradients_flow(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(5), (16, 32))

        def f(w):
            return jnp.sum(abft_dense.ft_einsum(
                "bsd,df->bsf", x, w, enabled=True) ** 2)

        g = jax.grad(f)(w)
        g_ref = jax.grad(lambda w: jnp.sum(
            jnp.einsum("bsd,df->bsf", x, w) ** 2))(w)
        np.testing.assert_allclose(g, g_ref, rtol=1e-3, atol=1e-3)


class TestAbftModel:
    def test_abft_model_forward_matches_unprotected(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks}
        abft_dense.configure(False)
        base, _ = jax.jit(lm.forward)(params, batch)
        abft_dense.configure(True)
        try:
            prot, _ = jax.jit(lm.forward)(params, batch)
        finally:
            abft_dense.configure(False)
        np.testing.assert_allclose(prot, base, rtol=5e-3, atol=5e-3)

    def test_abft_train_loss_decreases(self):
        cfg = get_config("internlm2-1.8b", smoke=True)
        lm = LM(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        abft_dense.configure(True)
        try:
            @jax.jit
            def step(p):
                (l, m), g = jax.value_and_grad(
                    lambda q: lm.loss(q, batch), has_aux=True)(p)
                return jax.tree_util.tree_map(
                    lambda a, b: a - 1e-2 * b, p, g), l
            p1, l0 = step(params)
            _, l1 = step(p1)
        finally:
            abft_dense.configure(False)
        assert float(l1) < float(l0)


class TestCheckpointer:
    def test_atomic_write_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
        for step in (1, 2, 3):
            ck.save(step, {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}})
        assert ck.available_steps() == [2, 3]   # keep=2 gc'd step 1
        st = ck.restore()
        assert st["_step"] == 3
        np.testing.assert_array_equal(st["a"], np.arange(4.0))
        np.testing.assert_array_equal(st["b/c"], np.ones((2, 2)))
        assert os.path.exists(os.path.join(str(tmp_path), "manifest.json"))

    def test_async_write_durable_after_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=True)
        ck.save(7, {"x": jnp.zeros((1024, 64))})
        ck.wait()
        assert ck.available_steps() == [7]

    def test_no_partial_files_visible(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, {"x": jnp.zeros((8,))})
        leftovers = [f for f in os.listdir(str(tmp_path))
                     if f.endswith(".tmp")]
        assert not leftovers
