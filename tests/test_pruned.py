"""Tile-granular triangle-inequality pruning (the bounds-carrying Lloyd
kernel family).

The headline guarantee is *exactness*: the bound check only skips
(row tile, centroid tile) cells that provably lose, so the pruned kernel
must be bit-identical to the unpruned one-pass kernel — same assignments,
same min-distances, same fused sums/counts, same final centroids, same
``n_iter_`` — on every dtype/variant cell. Pruning *effectiveness* is
tested separately in the regime it is built for (clustered data, rows
cluster-contiguous, centroid order aligned with row order, warm bounds):
uniform data or unaligned centroid order legitimately prunes nothing, and
the exactness tests cover that too.

Pallas kernels run interpret=True (kernel bodies execute in Python on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KMeans, get_backend
from repro.api.registry import BackendCapabilityError
from repro.core import assignment, autotune
from repro.core.kmeans import means_from_sums
from repro.kernels import ops
from repro.kernels.ops import BoundsState, KernelParams


def _clustered(m, k, f, seed=0, sep=8.0):
    """Well-separated blobs, rows cluster-contiguous (cluster j owns rows
    j*m/k..(j+1)*m/k) and centers in cluster order — the aligned regime
    tile pruning engages in."""
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(kc, (k, f), jnp.float32) * sep
    labels = (jnp.arange(m) * k) // m
    x = centers[labels] + jax.random.normal(kx, (m, f), jnp.float32)
    return x, centers


# (m, k, f, dtype): generic multi-centroid-tile and smallk single-tile
# cells, f32 and bf16 — the seeded grid of the acceptance criterion.
GRID = [
    (512, 256, 32, jnp.float32),     # generic: nkt=2, pruning engages
    (512, 256, 32, jnp.bfloat16),
    (512, 16, 32, jnp.float32),      # smallk: nkt=1, statically unprunable
    (512, 16, 32, jnp.bfloat16),
]


class TestKernelBitIdentity:
    @pytest.mark.parametrize("m,k,f,dtype", GRID)
    def test_pruned_matches_unpruned_over_iterations(self, m, k, f, dtype):
        x32, c32 = _clustered(m, k, f)
        x, c = x32.astype(dtype), c32.astype(dtype)
        p = ops.clamp_params(m, k, f, KernelParams(128, 128, 128),
                             dtype=dtype)
        bounds = ops.init_bounds(m, k, f, p, dtype=dtype)
        pruned_any = False
        for it in range(4):
            am_u, md_u, sums_u, cnt_u = ops.fused_lloyd(
                x, c, p, interpret=True)
            am_p, md_p, sums_p, cnt_p, bounds, frac = ops.fused_lloyd_pruned(
                x, c, p, bounds=bounds, interpret=True)
            assert jnp.array_equal(am_u, am_p), f"iter {it}: assignments"
            assert jnp.array_equal(md_u, md_p), f"iter {it}: min-dists"
            assert jnp.array_equal(sums_u, sums_p), f"iter {it}: sums"
            assert jnp.array_equal(cnt_u, cnt_p), f"iter {it}: counts"
            pruned_any |= float(frac) > 0.0
            c32 = means_from_sums(sums_u, cnt_u, c32)
            c = c32.astype(dtype)
        kp = ops._round_up(k, p.block_k)
        if kp // p.block_k > 1:
            # multi-tile aligned clustered data must actually prune —
            # otherwise this test would pass vacuously on an all-compute
            # fallback
            assert pruned_any
        else:
            # a single centroid tile can never be skipped (it holds every
            # row's assigned centroid)
            assert not pruned_any

    def test_first_iteration_is_unpruned(self):
        m, k, f = 512, 256, 32
        x, c = _clustered(m, k, f)
        p = ops.clamp_params(m, k, f, KernelParams(128, 128, 128))
        bounds = ops.init_bounds(m, k, f, p)
        assert bool(bounds.fresh)
        *_, bounds, frac = ops.fused_lloyd_pruned(x, c, p, bounds=bounds,
                                                  interpret=True)
        assert float(frac) == 0.0          # seed pass computes every tile
        assert not bool(bounds.fresh)

    def test_prune_rate_reaches_half_on_aligned_clusters(self):
        # nkt=4 (k=512 at block_k=128), 4 row tiles, alignment 1 centroid
        # tile per row tile -> steady state skips 3/4 of cells; the
        # acceptance bar is >= 50% by the final third of iterations.
        m, k, f = 512, 512, 32
        x, c0 = _clustered(m, k, f, seed=3)
        p = ops.clamp_params(m, k, f, KernelParams(128, 128, 128))
        bounds = ops.init_bounds(m, k, f, p)
        c, fracs = c0, []
        for _ in range(3):
            _, _, sums, cnt, bounds, frac = ops.fused_lloyd_pruned(
                x, c, p, bounds=bounds, interpret=True)
            fracs.append(float(frac))
            c = means_from_sums(sums, cnt, c)
        assert fracs[0] == 0.0
        assert fracs[-1] >= 0.5, fracs


class TestXlaBackendBitIdentity:
    def test_pruned_xla_matches_plain_xla_over_iterations(self):
        m, k, f = 2048, 128, 32
        x, c = _clustered(m, k, f, seed=1)
        plain = get_backend("lloyd_xla")
        pruned = get_backend("lloyd_pruned_xla")
        bounds = assignment.init_bounds_xla(m, k, f)
        for it in range(6):
            am_u, md_u, _, sums_u, cnt_u = plain(x, c)
            am_p, md_p, _, sums_p, cnt_p, bounds, _ = pruned(
                x, c, bounds=bounds)
            assert jnp.array_equal(am_u, am_p), f"iter {it}"
            assert jnp.array_equal(sums_u, sums_p), f"iter {it}"
            assert jnp.array_equal(cnt_u, cnt_p), f"iter {it}"
            np.testing.assert_allclose(md_p, md_u, rtol=1e-5, atol=1e-5)
            c = means_from_sums(sums_u, cnt_u, c)


class TestEstimatorBitIdentity:
    @pytest.mark.parametrize("pruned,plain", [
        ("lloyd_pruned", "lloyd"),             # Pallas pair (interpret)
        ("lloyd_pruned_xla", "lloyd_xla"),     # XLA analogue pair
    ])
    def test_fit_is_bit_identical(self, pruned, plain):
        m, k, f = (256, 40, 32) if pruned == "lloyd_pruned" else (2048, 64, 32)
        x, _ = _clustered(m, k, f, seed=2)
        kms = []
        for name in (pruned, plain):
            km = KMeans(n_clusters=k, backend=name, max_iter=8,
                        random_state=0)
            km.fit(x)
            kms.append(km)
        a, b = kms
        assert a.n_iter_ == b.n_iter_
        assert jnp.array_equal(a.labels_, b.labels_)
        assert jnp.array_equal(a.cluster_centers_, b.cluster_centers_)
        assert a.inertia_ == b.inertia_
        # the plain backend never reports pruning; the pruned one reports
        # one fraction per executed iteration
        assert b.prune_history_ == []
        assert len(a.prune_history_) == a.n_iter_
        # predict routes both through assignment-only kernels
        assert jnp.array_equal(a.predict(x[:64]), b.predict(x[:64]))

    def test_prune_history_reaches_half_in_final_third(self):
        # The refinement regime (warm start from near-true centers — the
        # checkpoint-restart scenario): drifts collapse after the first
        # step and the aligned tiles stay skippable. 8192 rows / 128
        # clusters -> 4 row chunks x 8 centroid groups, 2 groups live per
        # chunk -> steady state skips 3/4.
        m, k, f = 8192, 128, 32
        x, centers = _clustered(m, k, f, seed=4)
        km = KMeans(n_clusters=k, backend="lloyd_pruned_xla", max_iter=9,
                    tol=0.0, random_state=0)
        km.fit(x, centroids=centers + 0.01)
        hist = km.prune_history_
        assert len(hist) == km.n_iter_ == 9
        assert hist[0] == 0.0                      # unpruned seed pass
        final_third = hist[-3:]
        assert min(final_third) >= 0.5, hist


class TestBoundsLifecycle:
    def test_state_roundtrip_warm_refit_matches_cold_fit(self):
        # from_state must not carry bounds: a warm refit from restored
        # centroids has to be bit-identical to a cold fit seeded with the
        # same centroids (stale bounds after a centroid hot-swap is the
        # classic Hamerly bug).
        m, k, f = 2048, 64, 32
        x, _ = _clustered(m, k, f, seed=5)
        km = KMeans(n_clusters=k, backend="lloyd_pruned_xla", max_iter=4,
                    tol=0.0, random_state=0)
        km.fit(x)
        state = km.get_state()
        seed_c = jnp.asarray(state["cluster_centers"])

        warm = KMeans.from_state(state)
        warm.fit(x, centroids=seed_c)
        cold = KMeans(n_clusters=k, backend="lloyd_pruned_xla", max_iter=4,
                      tol=0.0, random_state=0)
        cold.fit(x, centroids=seed_c)
        assert warm.n_iter_ == cold.n_iter_
        assert jnp.array_equal(warm.labels_, cold.labels_)
        assert jnp.array_equal(warm.cluster_centers_, cold.cluster_centers_)

    def test_partial_fit_runs_unpruned_and_matches_plain(self):
        # partial_fit blocks share no bounds lineage, so every streaming
        # step must run with fresh (all-compute) bounds — its update must
        # match the plain backend's bit for bit.
        m, k, f = 1024, 32, 16
        x, _ = _clustered(m, k, f, seed=6)
        results = []
        for name in ("lloyd_pruned_xla", "lloyd_xla"):
            km = KMeans(n_clusters=k, backend=name, random_state=0)
            km.partial_fit(x[:512]).partial_fit(x[512:])
            results.append(km)
        a, b = results
        assert jnp.array_equal(a.labels_, b.labels_)
        assert jnp.array_equal(a.cluster_centers_, b.cluster_centers_)
        assert a.prune_history_ == []

    def test_partial_fit_after_fit_restarts_streaming(self):
        m, k, f = 1024, 32, 16
        x, _ = _clustered(m, k, f, seed=6)
        km = KMeans(n_clusters=k, backend="lloyd_pruned_xla", max_iter=3,
                    random_state=0)
        km.fit(x)
        c_fit = km.cluster_centers_
        km.partial_fit(x[:256])
        assert not jnp.array_equal(km.cluster_centers_, c_fit)

    def test_bounds_state_is_a_registered_pytree(self):
        b = ops.init_bounds(256, 64, 32)
        leaves, treedef = jax.tree_util.tree_flatten(b)
        assert len(leaves) == 5                 # every field is a leaf
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(rebuilt, BoundsState)
        # survives a scan carry (the whole point of registration)
        out, _ = jax.lax.scan(lambda s, _: (s, None), b, None, length=2)
        assert isinstance(out, BoundsState)


class TestSelectionAndRegistry:
    def test_select_params_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="pruned"):
            autotune.select_params(1024, 64, 128, kind="bogus")

    def test_kinds_vocabulary_is_single_sourced(self):
        # satellite: KINDS extension is one point of change, shared by the
        # cache schema, the contract checker and selection
        assert autotune.KINDS is ops.PLAN_KINDS
        assert "pruned" in autotune.KINDS

    def test_select_params_pruned_kind(self):
        variant, p = autotune.select_params(4096, 256, 128, kind="pruned")
        assert variant in ops.VARIANTS
        assert ops.pruned_vmem_bytes(
            ops.clamp_params(4096, 256, 128, p), 256, 128,
            jnp.float32) <= autotune.VMEM_BUDGET

    def test_model_score_discounts_by_prune_rate(self):
        p = ops.clamp_params(16384, 256, 128, KernelParams(256, 128, 128))
        s_none = autotune.model_score(16384, 256, 128, p, kind="pruned",
                                      prune_rate=0.0)
        s_half = autotune.model_score(16384, 256, 128, p, kind="pruned",
                                      prune_rate=0.5)
        s_lloyd = autotune.model_score(16384, 256, 128, p, kind="lloyd")
        assert s_half < s_none
        assert s_half < s_lloyd

    def test_bounds_refused_by_non_bounds_backend(self):
        x, c = _clustered(256, 16, 32)
        b = assignment.init_bounds_xla(256, 16, 32)
        with pytest.raises(BackendCapabilityError, match="bounds"):
            get_backend("lloyd_xla")(x, c, bounds=b)

    def test_pruned_backends_declare_the_contract(self):
        for name in ("lloyd_pruned", "lloyd_pruned_xla"):
            b = get_backend(name)
            assert b.supports_bounds and b.fuses_update
            assert b.kernel_kind == "pruned"
            assert b.expected_arity == 7
            assert callable(b.bounds_init)

    def test_measure_score_pruned_runs(self):
        # two-iteration protocol on clustered data: seeding pass, then the
        # warmed timed call (tiny shape; interpret mode)
        t = autotune.measure_score(256, 256, 32, KernelParams(128, 128, 128),
                                   iters=1, kind="pruned", variant="generic")
        assert t > 0.0


class TestClusteredBlobsGenerator:
    def test_rows_are_cluster_contiguous_and_separated(self):
        from benchmarks.common import clustered_blobs
        x, centers = clustered_blobs(512, 16, 32, seed=0)
        assert x.shape == (512, 16) and centers.shape == (32, 16)
        d = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        labels = jnp.argmin(d, axis=1)
        # well-separated: every row is nearest its own generator center,
        # and cluster-contiguous: labels are sorted
        assert jnp.array_equal(labels, jnp.sort(labels))
        assert int(labels[0]) == 0 and int(labels[-1]) == 31
        # seeded: same seed, same data
        x2, c2 = clustered_blobs(512, 16, 32, seed=0)
        assert jnp.array_equal(x, x2) and jnp.array_equal(centers, c2)
