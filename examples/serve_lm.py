"""Serve a small model with batched requests: prefill + token-by-token
decode through the production serve step (KV caches / ring buffers / state
caches as the 512-chip dry-run lowers them).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-4b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.frontend == "audio_stub":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.float32)

    prefill = jax.jit(lm.prefill, static_argnames=("max_len",))
    decode = jax.jit(lm.decode_step)

    t0 = time.time()
    logits, caches = prefill(params, batch, max_len=max_len)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tok]
    for t in range(args.prompt_len, args.prompt_len + args.gen - 1):
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prompt={args.prompt_len}  generated={out.shape[1]} tokens")
    print(f"wall {dt:.2f}s  ({args.batch * out.shape[1] / dt:.1f} tok/s "
          f"on CPU, greedy)")
    print("first sequence:", out[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
