"""Quickstart: fault-tolerant K-means in five lines.

Clusters Gaussian blobs with the fused Pallas assignment kernel (ABFT
dual-checksum protection inside), injecting one SEU per iteration to show
online correction.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FaultConfig, KMeans, KMeansConfig
from repro.data.blobs import make_blobs


def main():
    x, true_labels = make_blobs(m=20_000, f=32, k=8, seed=0)

    km = KMeans(KMeansConfig(k=8, max_iters=50, assignment="fused_ft"))
    result = km.fit(x, fault=FaultConfig(rate=1.0))   # 1 SEU / iteration

    assign = np.asarray(result.assign)
    labels = np.asarray(true_labels)
    purity = sum(np.bincount(labels[assign == j]).max()
                 for j in range(8) if np.any(assign == j)) / len(labels)
    print(f"converged in {result.iterations} iterations")
    print(f"inertia: {float(result.inertia):.1f}  purity: {purity:.3f}")
    print(f"SDCs detected & corrected in-kernel: {int(result.detected_errors)}")
    print(f"centroids shape: {result.centroids.shape}")


if __name__ == "__main__":
    main()
