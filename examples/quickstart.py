"""Quickstart: fault-tolerant K-means in five lines.

Clusters Gaussian blobs through the ``repro.api`` estimator with a
``FaultPolicy.correct()`` policy — the paper's fully-fused ABFT kernel
(dual-checksum detect -> locate -> correct, §IV) — while an injection
campaign fires one SEU per iteration to show online correction.

    PYTHONPATH=src python examples/quickstart.py            # full size
    PYTHONPATH=src python examples/quickstart.py --smoke    # CI-sized
"""
import argparse

import numpy as np

from repro.api import FaultPolicy, InjectionCampaign, KMeans
from repro.data.blobs import make_blobs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape + short run (CI executable-docs gate; "
                         "off-TPU the protected kernel runs in interpret "
                         "mode, so full size takes minutes on a host)")
    args = ap.parse_args(argv)
    m, f, k, iters = (2000, 16, 4, 8) if args.smoke else (20_000, 32, 8, 50)
    x, true_labels = make_blobs(m=m, f=f, k=k, seed=0)

    # correct-mode protection rides the one-pass kernel: the update
    # epilogue is checksum-verified in-kernel — see docs/fault_tolerance.md
    km = KMeans(n_clusters=k, max_iter=iters,
                fault=FaultPolicy.correct(
                    injection=InjectionCampaign(rate=1.0)))  # 1 SEU / iter
    labels = km.fit_predict(x)

    assign = np.asarray(labels)
    truth = np.asarray(true_labels)
    purity = sum(np.bincount(truth[assign == j]).max()
                 for j in range(k) if np.any(assign == j)) / len(truth)
    print(f"converged in {km.n_iter_} iterations")
    print(f"inertia: {km.inertia_:.1f}  purity: {purity:.3f}")
    print(f"SDCs detected & corrected in-kernel: {km.detected_errors_}")
    print(f"centroids shape: {km.cluster_centers_.shape}")


if __name__ == "__main__":
    main()
