"""Train a small LM with ABFT-protected projections + checkpoint/restart.

Uses the exact production train step (repro.train.steps — the same code the
512-chip dry-run lowers) on the local mesh with a reduced config, WSD
schedule, and the paper's fault tolerance wired in:

  * every dense projection runs through the dual-checksum ABFT matmul,
  * train state checkpoints asynchronously; the script "crashes" at step 30
    and restarts from the snapshot,
  * loss is printed so the descent is visible.

    PYTHONPATH=src python examples/train_lm_tiny.py [--arch internlm2-1.8b]
"""
import argparse
import dataclasses
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import TokenPipeline
from repro.dist.sharding import shard_params
from repro.ft.checkpoint import Checkpointer
from repro.launch.mesh import make_local_mesh
from repro.train.optimizer import TrainConfig, init_opt_state
from repro.train.steps import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--crash-at", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/ftlm_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = dataclasses.replace(get_config(args.arch, smoke=True), abft=True)
    mesh = make_local_mesh()
    shape = ShapeConfig("tiny", seq_len=64, global_batch=8, kind="train")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                       total_steps=args.steps, schedule="wsd", grad_accum=2)
    bundle = build_train_step(cfg, mesh, shape, tcfg)

    params, axes = bundle.lm.init(jax.random.PRNGKey(0))
    params = shard_params(mesh, params, axes)
    opt = init_opt_state(params, tcfg)
    pipe = TokenPipeline(cfg.vocab_size, shape.seq_len, shape.global_batch)
    ck = Checkpointer(args.ckpt_dir, keep=2, async_write=True)

    def run(params, opt, start, stop):
        for step in range(start, stop):
            batch = pipe.next_batch(step)
            params, opt, m = bundle.step_fn(params, opt, batch)
            if step % 10 == 0 or step == stop - 1:
                print(f"step {step:3d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.2e}")
            if (step + 1) % 10 == 0:
                ck.save(step + 1, {"params": params, "opt": opt})
        return params, opt

    print(f"== phase 1: ABFT-protected training to step {args.crash_at} ==")
    params, opt = run(params, opt, 0, args.crash_at)
    ck.wait()
    print(f"== simulated fail-stop; snapshots: {ck.available_steps()} ==")

    st = ck.restore()
    start = st["_step"]
    flat = {k: v for k, v in st.items() if k != "_step"}

    def reassemble(prefix, template):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in leaves:
            key = prefix + "/" + "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", ""))))
                for p in path)
            out.append(jnp.asarray(flat[key]))
        return jax.tree_util.tree_unflatten(treedef, out)

    params = shard_params(mesh, reassemble("params", params), axes)
    opt = reassemble("opt", opt)
    print(f"== phase 2: restart from step {start} ==")
    run(params, opt, start, args.steps)
    print("done.")


if __name__ == "__main__":
    main()
