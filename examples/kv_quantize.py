"""Vector-quantize a model's KV cache with FT K-means (paper application).

Runs a prefill on a small LM, harvests the per-layer key vectors, learns a
k-means codebook with the fault-tolerant pipeline, and reports the
compression ratio + reconstruction error — the classic VQ use of k-means
the paper cites ([2]), composed end-to-end from this framework's pieces.

    PYTHONPATH=src python examples/kv_quantize.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import FaultPolicy, InjectionCampaign, KMeans
from repro.configs import get_config
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--codebook", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 128), 0,
                              cfg.vocab_size)
    _, caches = jax.jit(lm.prefill, static_argnames=("max_len",))(
        params, {"tokens": toks}, max_len=128)

    # harvest keys: stacked (L, B, S, KV, hd) -> (N, hd)
    keys = caches["periods"][0]["kv"].k
    vecs = keys.reshape(-1, keys.shape[-1]).astype(jnp.float32)
    print(f"KV vectors: {vecs.shape[0]} x {vecs.shape[1]} "
          f"({vecs.size * 2 / 2**20:.1f} MiB bf16)")

    km = KMeans(n_clusters=args.codebook, max_iter=25,
                fault=FaultPolicy.correct(
                    injection=InjectionCampaign(rate=0.5)), random_state=0)
    km.fit(vecs)
    recon = km.cluster_centers_[km.labels_]
    err = float(jnp.linalg.norm(vecs - recon) / jnp.linalg.norm(vecs))
    ratio = vecs.shape[1] * 2 / (
        2 + km.cluster_centers_.size * 2 / vecs.shape[0])
    print(f"codebook {args.codebook}: rel recon err {err:.3f}, "
          f"~{ratio:.0f}x smaller cache, "
          f"SDCs corrected during clustering: {km.detected_errors_}")


if __name__ == "__main__":
    main()
