"""End-to-end driver: distributed FT K-means training at scale.

The full production loop on one host (the same code path the multi-pod
launcher uses): sharded synthetic ingest, distributed Lloyd iterations with
psum centroid reduction, ABFT-protected assignment via
``FaultPolicy.correct()``, asynchronous checkpointing — then a SIMULATED
FAIL-STOP mid-run and a restart from the latest snapshot, finishing to
convergence. Fault tolerance covers both halves of the paper's fault model:
SDCs in-kernel (ABFT), fail-stop via checkpoint/restart.

    PYTHONPATH=src python examples/e2e_kmeans.py [--m 262144] [--f 32] [--k 32]
"""
import argparse
import shutil
import time

import jax
import jax.numpy as jnp

from repro.api import FaultPolicy, KMeans
from repro.data.blobs import make_blobs
from repro.dist.kmeans_dist import DistributedKMeans
from repro.ft.checkpoint import Checkpointer
from repro.launch.mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=262_144)
    ap.add_argument("--f", type=int, default=32)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/ftkmeans_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mesh = make_local_mesh()
    print(f"mesh: {dict(mesh.shape)} ({len(jax.devices())} devices)")

    x, _ = make_blobs(args.m, args.f, args.k, seed=0)
    km = KMeans(n_clusters=args.k, max_iter=args.iters, tol=1e-4,
                fault=FaultPolicy.correct(), random_state=0)
    dk = DistributedKMeans(km, mesh)
    xs = dk.shard_data(x)
    c0 = km.init_centroids(x)
    ck = Checkpointer(args.ckpt_dir, keep=3, async_write=True)

    # ---- phase 1: run, checkpointing every 5 iterations, "crash" at 40 ----
    t0 = time.time()
    dk.fit(xs, c0, max_iters=40, checkpointer=ck, checkpoint_interval=5)
    ck.wait()
    print(f"[phase 1] 40 iterations, then simulated fail-stop "
          f"({time.time() - t0:.1f}s). snapshots: {ck.available_steps()}")

    # ---- phase 2: restart from the latest durable snapshot ----------------
    st = ck.restore()
    print(f"[restart] resuming from iteration {int(st['iteration'])}")
    c, am, inertia, iters, det = dk.fit(
        xs, jnp.asarray(st["centroids"]),
        start_iteration=int(st["iteration"]),
        checkpointer=ck, checkpoint_interval=5)
    ck.wait()
    print(f"[phase 2] converged at iteration {iters}, "
          f"inertia={float(inertia):.4g}, SDCs corrected={int(det)}")
    print(f"total wall time {time.time() - t0:.1f}s; "
          f"snapshots kept: {ck.available_steps()}")


if __name__ == "__main__":
    main()
