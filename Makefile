PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-multidevice bench bench-smoke bench-check bench-ft \
        bench-batched bench-init bench-serve bench-dist quickstart docs \
        docs-check lint typecheck analysis static test-fast

test:            ## tier-1 suite
	$(PY) -m pytest -x -q

test-multidevice: ## 8-virtual-device subprocess suites only (slow)
	$(PY) -m pytest -q -m multidevice

lint:            ## ruff (config in pyproject.toml)
	ruff check src tests benchmarks examples

typecheck:       ## mypy, strict on repro.api / repro.kernels.ops / repro.analysis
	mypy

analysis:        ## repo-specific static passes: contracts, lint, recompile
	$(PY) -m repro.analysis --check

static: lint typecheck analysis  ## every static gate CI runs before the tests

test-fast:       ## API + kmeans + kernels only (quick signal)
	$(PY) -m pytest -q tests/test_api.py tests/test_kmeans.py tests/test_kernels.py

bench:           ## all paper-figure benchmark modules
	$(PY) -m benchmarks.run

bench-smoke:     ## Fig. 7 ladder at tiny shapes (all rungs compiled)
	$(PY) -m benchmarks.bench_stepwise --smoke --model --json BENCH_stepwise.json

bench-check:     ## regen smoke artifacts, gate vs committed baselines (>25% = fail)
	git show HEAD:BENCH_stepwise.json > /tmp/bench_stepwise_baseline.json
	git show HEAD:BENCH_init.json > /tmp/bench_init_baseline.json
	git show HEAD:BENCH_serve.json > /tmp/bench_serve_baseline.json
	git show HEAD:BENCH_dist.json > /tmp/bench_dist_baseline.json
	$(MAKE) bench-smoke
	$(MAKE) bench-init
	$(MAKE) bench-serve
	$(MAKE) bench-dist
	$(PY) -m benchmarks.check_regression /tmp/bench_stepwise_baseline.json \
	    BENCH_stepwise.json --rung fig7_v5_onepass \
	    --rung fig7_v7_ft_onepass --rung fig7_v8_batched \
	    --rung fig7_v9_pruned --rung fig7_v6_smallk \
	    --rung fig7_v10_int8 --rung fig7_v11_dbuf \
	    --rung fig7_v12_aot_predict --max-ratio 1.25
	$(PY) -m benchmarks.check_regression /tmp/bench_init_baseline.json \
	    BENCH_init.json --rung init_fused_vs_vmapped --max-ratio 1.25
	$(PY) -m benchmarks.check_regression /tmp/bench_serve_baseline.json \
	    BENCH_serve.json --rung serve_microbatch_vs_naive --max-ratio 1.25
	$(PY) -m benchmarks.check_regression /tmp/bench_dist_baseline.json \
	    BENCH_dist.json --rung dist_hier_vs_flat --max-ratio 1.25

bench-init:      ## fused k-means++ seeding vs vmapped baseline (B=64 small problems)
	$(PY) -m benchmarks.bench_init --json BENCH_init.json

bench-serve:     ## serving layer: AOT cells, micro-batch vs naive, latency sim
	$(PY) -m benchmarks.bench_serve --json BENCH_serve.json

bench-dist:      ## hierarchical vs flat vs compressed reduce (8 virtual devices)
	$(PY) -m benchmarks.bench_dist --json BENCH_dist.json

bench-ft:        ## Fig. 15/16 FT overhead (incl. one-pass FT vs unprotected)
	$(PY) -m benchmarks.bench_ft_overhead

bench-batched:   ## batched many-problem fit vs vmapped vs loop-of-fits
	$(PY) -m benchmarks.bench_batched

quickstart:
	$(PY) examples/quickstart.py

docs:            ## regenerate the auto-generated docs (backend matrix)
	$(PY) -m repro.api.registry --markdown docs/backends.md

docs-check:      ## CI doc gates: matrix freshness + executable docs
	$(PY) -m repro.api.registry --check docs/backends.md
	$(PY) -m pytest -q tests/test_docs.py
	$(PY) examples/quickstart.py --smoke
