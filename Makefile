PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-smoke quickstart

test:            ## tier-1 suite
	$(PY) -m pytest -x -q

test-fast:       ## API + kmeans + kernels only (quick signal)
	$(PY) -m pytest -q tests/test_api.py tests/test_kmeans.py tests/test_kernels.py

bench:           ## all paper-figure benchmark modules
	$(PY) -m benchmarks.run

bench-smoke:     ## one fast module (Fig. 7 ladder) as a smoke check
	$(PY) -m benchmarks.bench_stepwise

quickstart:
	$(PY) examples/quickstart.py
