PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-smoke quickstart

test:            ## tier-1 suite
	$(PY) -m pytest -x -q

test-fast:       ## API + kmeans + kernels only (quick signal)
	$(PY) -m pytest -q tests/test_api.py tests/test_kmeans.py tests/test_kernels.py

bench:           ## all paper-figure benchmark modules
	$(PY) -m benchmarks.run

bench-smoke:     ## Fig. 7 ladder at tiny shapes (interpret-mode Pallas rung)
	$(PY) -m benchmarks.bench_stepwise --smoke --model --json BENCH_stepwise.json

quickstart:
	$(PY) examples/quickstart.py
