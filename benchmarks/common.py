"""Shared benchmark utilities.

This container is CPU-only; wall-times calibrate *relative* claims (the
paper's stepwise ratios, FT overhead, injection overhead) while the
TPU-absolute story lives in the dry-run roofline (EXPERIMENTS.md §Roofline).
The Pallas kernels are validated in interpret mode (tests/) — interpret
wall-time is Python-loop bound, so kernel-level performance points here use
the XLA-fused path with the kernels' tiling decisions applied analytically.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in seconds (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gflops(flops: float, seconds: float) -> float:
    return flops / seconds / 1e9


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def distance_flops(m: int, k: int, f: int) -> float:
    """Distance-step flop count (paper's metric): the 2*M*K*F GEMM."""
    return 2.0 * m * k * f
