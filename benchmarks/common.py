"""Shared benchmark utilities.

This container is CPU-only; wall-times calibrate *relative* claims (the
paper's stepwise ratios, FT overhead, injection overhead) while the
TPU-absolute story lives in the dry-run roofline (EXPERIMENTS.md §Roofline).
The Pallas kernels are validated in interpret mode (tests/) — interpret
wall-time is Python-loop bound, so kernel-level performance points here use
the XLA-fused path with the kernels' tiling decisions applied analytically.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in seconds (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gflops(flops: float, seconds: float) -> float:
    return flops / seconds / 1e9


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def distance_flops(m: int, k: int, f: int) -> float:
    """Distance-step flop count (paper's metric): the 2*M*K*F GEMM."""
    return 2.0 * m * k * f


def clustered_blobs(m: int, f: int, k: int, *, sep: float = 8.0,
                    noise: float = 1.0, seed: int = 0,
                    dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """Seeded well-separated Gaussian blobs: ``(x, centers)``.

    Rows are **cluster-contiguous** (cluster j owns the slice
    ``j*m/k .. (j+1)*m/k``) and the returned centers are in cluster order,
    so row tiles and centroid tiles align. That alignment is the regime
    tile-granular triangle-inequality pruning is built for — uniform
    random data makes prune rates and late-iteration behavior meaningless
    (every row tile is near every centroid tile, so no tile's group lower
    bound ever beats the tile's upper bound), which is why the pruned
    rungs and ``measure_score(kind="pruned")`` run on this generator
    instead of ``jax.random.normal``.

    ``sep`` scales the center spread relative to unit within-cluster
    ``noise``; the defaults keep clusters well separated at any F (center
    distances grow as ``sep * sqrt(2F)`` vs a noise radius of
    ``sqrt(F)``).
    """
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(kc, (k, f), jnp.float32) * sep
    labels = (jnp.arange(m) * k) // m            # contiguous, balanced
    x = centers[labels] + noise * jax.random.normal(kx, (m, f), jnp.float32)
    return x.astype(dtype), centers.astype(dtype)
