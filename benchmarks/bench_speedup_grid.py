"""Paper Fig. 12 — speedup grid over (N features x K clusters):
shape-adaptive FT K-means vs the fixed-parameter two-pass baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import assignment as assign_mod

M = 8_192
NS = (8, 32, 128)
KS = (8, 32, 128)


def run() -> list[str]:
    out = []
    for f in NS:
        for k in KS:
            x = jax.random.normal(jax.random.PRNGKey(0), (M, f), jnp.float32)
            c = jax.random.normal(jax.random.PRNGKey(1), (k, f), jnp.float32)
            t_b = time_call(jax.jit(
                lambda x, c: assign_mod.assign_gemm(x, c)[0]), x, c)
            t_f = time_call(jax.jit(
                lambda x, c: assign_mod.assign_gemm_fused(x, c)[0]), x, c)
            out.append(row(f"fig12_N{f}_K{k}", t_f,
                           f"speedup={t_b / t_f:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
