"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. See docs/architecture.md for the
figure-to-module index; absolute TPU numbers come from the dry-run
roofline (bench_roofline reads its cache), wall-times here are CPU-host
calibrations of the paper's *relative* claims.
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.bench_stepwise",       # Fig 7
    "benchmarks.bench_batched",        # batched many-problem path (ISSUE 5)
    "benchmarks.bench_init",           # fused k-means++ seeding (ISSUE 8)
    "benchmarks.bench_shapes",         # Fig 8-11 / 19-20
    "benchmarks.bench_speedup_grid",   # Fig 12
    "benchmarks.bench_params",         # Fig 13/14 + Table I
    "benchmarks.bench_ft_overhead",    # Fig 15/16
    "benchmarks.bench_injection",      # Fig 17/18/21
    "benchmarks.bench_roofline",       # EXPERIMENTS §Roofline
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        try:
            mod = __import__(modname, fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
        except Exception:
            failed += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
