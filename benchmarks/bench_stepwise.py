"""Paper Fig. 7 — stepwise optimization ladder for the distance step.

Walks the registered assignment backends in ladder order — naive (per-sample
loop, no GEMM) -> V1 GEMM + separate reduction -> V2/V3 fused reduction
(cuML analogue) -> V4 low-precision — through the ``repro.api`` registry
(uniform ``backend(x, c)`` calls, no magic strings), then times one full
``repro.api.KMeans`` iteration loop with and without a ``FaultPolicy`` to
anchor the ladder in estimator terms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import distance_flops, gflops, row, time_call
from repro.api import FaultPolicy, KMeans, get_backend

M, K, F = 16_384, 128, 128   # paper Fig. 7: M=131072, N=128 (scaled to CPU)

LADDER = [                    # (row label, registered backend)
    ("fig7_naive", "naive"),
    ("fig7_v1_gemm", "gemm"),
    ("fig7_v2_fused", "gemm_fused"),
]


def _bf16_fused(x, c):
    xb, cb = x.astype(jnp.bfloat16), c.astype(jnp.bfloat16)
    d = (jnp.sum(c * c, axis=1)[None, :]
         - 2.0 * jnp.matmul(xb, cb.T).astype(jnp.float32))
    return jnp.argmin(d, axis=1), jnp.min(d, axis=1)


def run() -> list[str]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, F), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (K, F), jnp.float32)
    fl = distance_flops(M, K, F)
    out = []

    base = None
    for label, name in LADDER:
        backend = get_backend(name)
        fn = jax.jit(lambda x, c, b=backend: b(x, c)[0])
        iters, warmup = (3, 1) if name == "naive" else (5, 2)
        t = time_call(fn, x, c, iters=iters, warmup=warmup)
        base = base if base is not None else t
        out.append(row(label, t,
                       f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    v4 = jax.jit(_bf16_fused)
    t = time_call(v4, x, c)
    out.append(row("fig7_v4_lowprec_tuned", t,
                   f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    # estimator-level anchor: 4 Lloyd iterations, unprotected vs FT policy
    for label, policy in (("fig7_e2e_off", FaultPolicy.off()),
                          ("fig7_e2e_detect", FaultPolicy.detect())):
        km = KMeans(n_clusters=K, max_iter=4, tol=0.0, fault=policy,
                    random_state=0)
        c0 = km.init_centroids(x)
        t = time_call(lambda: km.fit(x, centroids=c0), iters=2, warmup=1)
        out.append(row(label, t, f"mode={policy.mode}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
