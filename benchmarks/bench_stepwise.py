"""Paper Fig. 7 — stepwise optimization ladder for the distance step.

naive (per-sample loop, no GEMM) -> V1 GEMM + separate reduction kernel ->
V2/V3 fused reduction (single compiled program; on TPU this is the Pallas
fused kernel, on this CPU host the XLA-fused analogue) -> V4 + tuned
parameters / low-precision matmul units (bf16 = the TF32 analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import distance_flops, gflops, row, time_call
from repro.core import assignment as assign_mod

M, K, F = 16_384, 128, 128   # paper Fig. 7: M=131072, N=128 (scaled to CPU)


def _bf16_fused(x, c):
    xb, cb = x.astype(jnp.bfloat16), c.astype(jnp.bfloat16)
    d = (jnp.sum(c * c, axis=1)[None, :]
         - 2.0 * jnp.matmul(xb, cb.T).astype(jnp.float32))
    return jnp.argmin(d, axis=1), jnp.min(d, axis=1)


def run() -> list[str]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, F), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (K, F), jnp.float32)
    fl = distance_flops(M, K, F)
    out = []

    naive = jax.jit(lambda x, c: assign_mod.assign_naive(x, c)[0])
    t = time_call(naive, x, c, iters=3, warmup=1)
    base = t
    out.append(row("fig7_naive", t, f"GFLOPS={gflops(fl, t):.1f};x1.00"))

    v1 = jax.jit(lambda x, c: assign_mod.assign_gemm(x, c)[0])
    t = time_call(v1, x, c)
    out.append(row("fig7_v1_gemm", t,
                   f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    v2 = jax.jit(lambda x, c: assign_mod.assign_gemm_fused(x, c)[0])
    t = time_call(v2, x, c)
    out.append(row("fig7_v2_fused", t,
                   f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    v4 = jax.jit(_bf16_fused)
    t = time_call(v4, x, c)
    out.append(row("fig7_v4_lowprec_tuned", t,
                   f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
