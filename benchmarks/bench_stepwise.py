"""Paper Fig. 7 — stepwise optimization ladder for the distance step.

Walks the registered assignment backends in ladder order — naive (per-sample
loop, no GEMM) -> V1 GEMM + separate reduction -> V2/V3 fused reduction
(cuML analogue) -> V4 low-precision -> V5 one-pass Lloyd (this repo's
fused-update iteration, docs/kernels.md) -> V6 template family (bf16 compute
path, small-K fast-path variant, irregular-shape rows; docs/autotune.md) ->
V7 one-pass *with* fault tolerance (the Fig. 6 ABFT scheme composed with
the fused-update iteration; docs/fault_tolerance.md) -> V8 batched
many-problem one-pass -> V9 bounds-carrying pruned one-pass (triangle-
inequality tile skipping in the warmed refinement regime on clustered
data; docs/kernels.md) — through the ``repro.api``
registry, then times one full ``repro.api.KMeans`` iteration loop with and
without a ``FaultPolicy`` to anchor the ladder in estimator terms.

The one-pass rung is measured at *iteration* granularity against the
two-pass pipeline (fused assignment, separate centroid update): the paper's
Fig. 4 argument is about per-iteration HBM traffic, so that is what the
pair of rungs compares. ``--model`` additionally emits the analytical
per-iteration HBM byte table (``autotune.iteration_traffic``) that the
docs/kernels.md table is generated from.

The ladder's top rung (``fig7_v12_aot_predict``) is the serving layer's
AOT-compiled predict cell (``repro.serve``): the fused assignment pipeline
behind a precompiled bucket executable — every rung in the artifact is
compiled, so ``check_regression`` can guard all of them.

CLI:
  --smoke        tiny shapes (CI wiring; wall-times are then smoke
                 signals, not data)
  --json PATH    write rows + traffic model to PATH (CI artifact)
  --model        print the HBM traffic model rows
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import (clustered_blobs, distance_flops, gflops, row,
                               time_call)
from repro.api import FaultPolicy, KMeans, get_backend
from repro.core.autotune import iteration_traffic, model_score, select_params
from repro.core.kmeans import centroid_update, means_from_sums
from repro.kernels.ops import KernelParams, clamp_params

M, K, F = 16_384, 128, 128   # paper Fig. 7: M=131072, N=128 (scaled to CPU)
SMOKE_M, SMOKE_K, SMOKE_F = 1024, 16, 32

# Irregular shapes (paper Figs. 8-11 regime: where template selection pays):
# tall-skinny (many samples, few features) and wide-F (feature-heavy).
IRREGULAR = [("fig7_irr_tall", 65_536, 64, 32), ("fig7_irr_wide", 4096, 64, 2048)]
SMOKE_IRREGULAR = [("fig7_irr_tall", 4096, 8, 16), ("fig7_irr_wide", 512, 8, 256)]

LADDER = [                    # (row label, registered backend)
    ("fig7_naive", "naive"),
    ("fig7_v1_gemm", "gemm"),
    ("fig7_v2_fused", "gemm_fused"),
]


def _bf16_fused(x, c):
    xb, cb = x.astype(jnp.bfloat16), c.astype(jnp.bfloat16)
    d = (jnp.sum(c * c, axis=1)[None, :]
         - 2.0 * jnp.matmul(xb, cb.T).astype(jnp.float32))
    return jnp.argmin(d, axis=1), jnp.min(d, axis=1)


def _traffic_rows(m: int, k: int, f: int) -> tuple[list[str], dict]:
    """Model-mode verification of the docs/kernels.md byte table: per-iteration
    HBM traffic of the two-pass pipeline vs the one-pass kernel."""
    p = clamp_params(m, k, f, KernelParams())
    two = iteration_traffic(m, k, f, p, pipeline="two_pass")
    one = iteration_traffic(m, k, f, p, pipeline="one_pass")
    rows = []
    for name, t in (("model_twopass_hbm", two), ("model_onepass_hbm", one)):
        rows.append(row(name, 0.0,
                        f"x_read={t['x_read']};total={t['total']}"))
    rows.append(row("model_onepass_saving", 0.0,
                    f"x{two['total'] / one['total']:.2f}"))
    return rows, {"two_pass": two, "one_pass": one}


def _template_rows(m: int, k: int, f: int) -> tuple[list[str], dict]:
    """Model-mode view of the §III-B template family at this shape: the
    selected (variant, tiles) winner per dtype and the analytical speedups
    of the bf16 template over f32 and of the small-K fast path over the
    generic template at the same tiles."""
    rows, payload = [], {}
    scores = {}
    for dtype in (jnp.float32, jnp.bfloat16):
        variant, p = select_params(m, k, f, mode="model", dtype=dtype)
        s = model_score(m, k, f, p, dtype=dtype, variant=variant)
        name = jnp.dtype(dtype).name
        scores[name] = s
        payload[name] = {"variant": variant, "score_s": s,
                         "block": [p.block_m, p.block_k, p.block_f]}
        rows.append(row(f"model_assign_{name}", s,
                        f"variant={variant};"
                        f"block=({p.block_m},{p.block_k},{p.block_f})"))
    payload["bf16_speedup"] = scores["float32"] / scores["bfloat16"]
    rows.append(row("model_bf16_vs_f32", 0.0,
                    f"x{payload['bf16_speedup']:.2f}"))
    p = clamp_params(m, k, f, KernelParams())
    if k <= p.block_k:
        sk = model_score(m, k, f, p, variant="smallk")
        gen = model_score(m, k, f, p, variant="generic")
        payload["smallk_speedup"] = gen / sk
        rows.append(row("model_smallk_vs_generic", 0.0,
                        f"x{gen / sk:.4f}"))
    return rows, payload


def run(smoke: bool = False, model: bool = False) -> list[str]:
    """run.py contract: the printable CSV rows."""
    return _collect(smoke=smoke, model=model)[0]


def _collect(smoke: bool = False, model: bool = False
             ) -> tuple[list[str], dict]:
    """The ladder rows plus the machine-readable artifact payload (single
    source of truth for the shape and traffic model)."""
    m, k, f = (SMOKE_M, SMOKE_K, SMOKE_F) if smoke else (M, K, F)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, f), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (k, f), jnp.float32)
    fl = distance_flops(m, k, f)
    out = []
    # Rungs timed in Pallas interpret mode: wall-time there is a
    # Python-loop-bound smoke signal, never a perf figure. The payload
    # names them so check_regression refuses to gate on them.
    interpret_rungs = []

    base = None
    ladder_t = {}
    for label, name in LADDER:
        backend = get_backend(name)
        fn = jax.jit(lambda x, c, b=backend: b(x, c)[0])
        iters, warmup = (3, 1) if name == "naive" else (5, 2)
        t = time_call(fn, x, c, iters=iters, warmup=warmup)
        ladder_t[name] = t
        base = base if base is not None else t
        out.append(row(label, t,
                       f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    v4 = jax.jit(_bf16_fused)
    t = time_call(v4, x, c)
    out.append(row("fig7_v4_lowprec_tuned", t,
                   f"GFLOPS={gflops(fl, t):.1f};x{base / t:.2f}"))

    # --- iteration-granularity pair: two-pass vs one-pass Lloyd ----------
    # two-pass (seed pipeline): fused assignment kernel, then a separate
    # update launch that re-reads X — two dispatches, argmin round trip.
    assign = jax.jit(lambda x, c: get_backend("gemm_fused")(x, c)[0])
    update = jax.jit(lambda x, am, c: centroid_update(x, am, k, c,
                                                      use_dmr=False))

    def two_pass_iter():
        am = assign(x, c)
        jax.block_until_ready(am)      # the inter-kernel HBM round trip
        return update(x, am, c)

    t_two = time_call(two_pass_iter)
    out.append(row("fig7_v4_fused_twopass", t_two,
                   f"GFLOPS={gflops(fl, t_two):.1f};x{base / t_two:.2f}"))

    # one-pass: assignment + update in a single fused launch (lloyd_xla is
    # the XLA analogue of kernels/lloyd_step.py; benchmarks/common.py
    # explains why CPU perf points avoid Pallas interpret mode).
    onepass_backend = get_backend("lloyd_xla")

    def onepass(x, c):
        am, md, det, sums, counts = onepass_backend(x, c)
        return means_from_sums(sums, counts, c), am

    one_fn = jax.jit(onepass)
    t_one = time_call(one_fn, x, c)
    out.append(row("fig7_v5_onepass", t_one,
                   f"GFLOPS={gflops(fl, t_one):.1f};x{base / t_one:.2f};"
                   f"vs_twopass=x{t_two / t_one:.2f}"))

    # --- V7: one-pass *with fault tolerance* (lloyd_ft_xla is the XLA
    # analogue of kernels/lloyd_step_ft.py: checksummed distance GEMM +
    # verified one-hot update in the same fused graph). Measured against
    # the unprotected one-pass rung — the paper's ~11% overhead claim,
    # now composed with the fused-update iteration instead of paying the
    # two-pass penalty on top of the checksums.
    ft_backend = get_backend("lloyd_ft_xla")

    def onepass_ft(x, c):
        am, md, det, sums, counts = ft_backend(x, c)
        return means_from_sums(sums, counts, c), am, det

    t_ft = time_call(jax.jit(onepass_ft), x, c)
    out.append(row("fig7_v7_ft_onepass", t_ft,
                   f"GFLOPS={gflops(fl, t_ft):.1f};x{base / t_ft:.2f};"
                   f"vs_onepass=x{t_one / t_ft:.2f};"
                   f"ft_overhead={(t_ft - t_one) / t_one * 100:.1f}%"))

    # --- V6: dtype-templated one-pass (bf16 compute, f32 accumulate) -----
    def onepass_bf16(x, c):
        am, md, det, sums, counts = onepass_backend(
            x.astype(jnp.bfloat16), c.astype(jnp.bfloat16))
        return means_from_sums(sums, counts, c), am

    t_bf16 = time_call(jax.jit(onepass_bf16), x, c)
    out.append(row("fig7_v6_bf16", t_bf16,
                   f"GFLOPS={gflops(fl, t_bf16):.1f};x{base / t_bf16:.2f};"
                   f"vs_f32_onepass=x{t_one / t_bf16:.2f}"))

    # --- V10: int8 distance template (one dtype notch past the paper's
    # fp16 floor; XLA analogue of kernels/distance_argmin_int8.py: per-row
    # symmetric quantization at the plan boundary, the i8 x i8 product
    # carried in f32 off-TPU, scale correction + exact norm terms in the
    # epilogue). The template is a distance/argmin kernel, so the rung
    # times assignment + the separate update launch, against the bf16
    # one-pass rung the ladder already carries.
    from repro.kernels import ops as _ops
    int8_backend = get_backend("int8_xla")
    plan8 = _ops.plan_data_int8(x, None)   # per-fit quantization, reused
    assign8 = jax.jit(lambda c: int8_backend(plan8, c)[0])

    def int8_iter():
        am = assign8(c)
        jax.block_until_ready(am)          # inter-kernel round trip
        return update(x, am, c)

    t_int8 = time_call(int8_iter)
    out.append(row("fig7_v10_int8", t_int8,
                   f"GFLOPS={gflops(fl, t_int8):.1f};x{base / t_int8:.2f};"
                   f"carrier=f32_offtpu;"
                   f"vs_bf16_onepass=x{t_bf16 / t_int8:.2f}"))

    # --- V11: double-buffered one-pass (kernels/lloyd_step.py async-stash
    # emit pipeline). The overlap it buys is TPU DMA latency hiding; the
    # XLA analogue computes the identical iteration (one X read, fused
    # update), so off-TPU this rung re-times that analogue as a separate
    # guard: the dbuf rework of the kernel file must never change the
    # analogue's numerics or cost (vs_onepass should sit at ~x1.0).
    t_dbuf = time_call(one_fn, x, c)
    out.append(row("fig7_v11_dbuf", t_dbuf,
                   f"GFLOPS={gflops(fl, t_dbuf):.1f};x{base / t_dbuf:.2f};"
                   f"overlap=tpu_dma_only;"
                   f"vs_onepass=x{t_one / t_dbuf:.2f}"))

    # --- V6: small-K fast-path template, compiled ------------------------
    # The smallk template's content is "don't burn MXU lanes on padded
    # centroids": K fits one tile, so the kernel computes against the real
    # K rows where the generic template pays a full block_k-padded tile.
    # The compiled XLA analogue of that comparison is the fused assignment
    # at the real K vs the same assignment with K zero-padded to block_k —
    # the padded-lane waste is the quantity the fast path deletes.
    # (Interpret-mode variant parity lives in tests/test_templates.py; this
    # rung is a compiled perf point and check_regression may guard it.)
    skm, skk, skf = (SMOKE_M, SMOKE_K, SMOKE_F) if smoke else (16_384, 16, 128)
    xs = jax.random.normal(jax.random.PRNGKey(2), (skm, skf), jnp.float32)
    cs = jax.random.normal(jax.random.PRNGKey(3), (skk, skf), jnp.float32)
    skp = clamp_params(skm, skk, skf, KernelParams())
    cs_pad = jnp.pad(cs, ((0, skp.block_k - skk), (0, 0)))
    fused_backend = get_backend("gemm_fused")
    sk_fn = jax.jit(lambda x, c: fused_backend(x, c)[0])
    t_sk = time_call(sk_fn, xs, cs)
    t_gen = time_call(sk_fn, xs, cs_pad)
    out.append(row("fig7_v6_smallk", t_sk,
                   f"shape=({skm},{skk},{skf});"
                   f"vs_paddedk_generic=x{t_gen / t_sk:.2f}"))

    # --- V8: batched many-problem one-pass (B small problems, one launch
    # vs a Python loop of B single-problem one-pass iterations — the
    # production "millions of users" regime; docs/kernels.md batched
    # template) ---------------------------------------------------
    from repro.core.kmeans import means_from_sums as _mfs
    bb, bn, bk2, bf2 = (4, 512, 8, 32) if smoke else (32, 2048, 16, 32)
    xb = jax.random.normal(jax.random.PRNGKey(6), (bb, bn, bf2), jnp.float32)
    cb = jax.random.normal(jax.random.PRNGKey(7), (bb, bk2, bf2),
                           jnp.float32)
    bat_backend = get_backend("lloyd_batched_xla")

    def batched_iter(xb, cb):
        am, md, det, sums, counts = bat_backend(xb, cb)
        return jax.vmap(_mfs)(sums, counts, cb), am

    bat_fn = jax.jit(batched_iter)
    t_bat = time_call(bat_fn, xb, cb)

    def loop_iter():
        res = [one_fn(xb[i], cb[i])[0] for i in range(bb)]
        return jax.block_until_ready(res)

    t_bloop = time_call(loop_iter, iters=3, warmup=1)
    out.append(row("fig7_v8_batched", t_bat,
                   f"B={bb};shape=({bn},{bk2},{bf2});"
                   f"vs_loop_of_single=x{t_bloop / t_bat:.2f}"))

    # --- V9: bounds-carrying pruned one-pass (lloyd_pruned_xla is the XLA
    # analogue of kernels/lloyd_step_pruned.py). Timed in the warmed
    # refinement regime — clustered cluster-contiguous data, centroid
    # order aligned with row order, bounds seeded by a few real Lloyd
    # steps — because that is where a long fit spends almost all its
    # iterations and the only regime where tile pruning can engage at
    # all (docs/kernels.md). The per-iteration prune-rate trace of the
    # warmup steps is the derived column; no GFLOPS figure, since the
    # whole point is that the skipped FLOPs never execute.
    from repro.core.assignment import init_bounds_xla
    pm, pk, pf2 = (4096, 64, 32) if smoke else (m, k, f)
    xq, cq = clustered_blobs(pm, pf2, pk, seed=8)
    pr_backend = get_backend("lloyd_pruned_xla")

    def pruned_iter(x, c, bounds):
        am, md, det, sums, counts, nb, frac = pr_backend(x, c, bounds=bounds)
        return means_from_sums(sums, counts, c), am, nb, frac

    pr_fn = jax.jit(pruned_iter)
    bnds = init_bounds_xla(pm, pk, pf2)
    c_cur, fracs = cq, []
    for _ in range(6):
        c_cur, _, bnds, fr = pr_fn(xq, c_cur, bnds)
        fracs.append(float(fr))
    t_v9 = time_call(pr_fn, xq, c_cur, bnds)
    t_ref = time_call(one_fn, xq, c_cur)     # unpruned one-pass, same data
    # Annotation contract (docs/kernels.md): with cluster-contiguous rows
    # the steady state visits only the centroid groups a row chunk's own
    # clusters occupy, pruning 1 - ceil(clusters_per_chunk/group)/groups
    # of the grid. Asserting the measured rate here ties the documented
    # figure to the artifact (the docs once claimed the full-shape 0.875
    # against a committed 0.500 smoke rung).
    from repro.core.assignment import _pruned_xla_grid
    rt9, _, g9, kg9 = _pruned_xla_grid(pm, pk)
    expect_prune = 1.0 - (-(-(rt9 * pk // pm) // g9)) / kg9
    if abs(fracs[-1] - expect_prune) > 0.02:
        raise RuntimeError(
            f"fig7_v9_pruned steady-state prune {fracs[-1]:.3f} != "
            f"modelled {expect_prune:.3f} at shape ({pm},{pk},{pf2}) — "
            f"fix docs/kernels.md before re-committing the artifact")
    out.append(row("fig7_v9_pruned", t_v9,
                   f"shape=({pm},{pk},{pf2});"
                   f"vs_onepass_same_shape=x{t_ref / t_v9:.2f};"
                   f"steady_model={expect_prune:.3f};"
                   f"prune=" + "|".join(f"{v:.3f}" for v in fracs)))

    # --- irregular shapes: tall-skinny and wide-F (one-pass iteration) ---
    for label, im, ik, if_ in (SMOKE_IRREGULAR if smoke else IRREGULAR):
        xi = jax.random.normal(jax.random.PRNGKey(4), (im, if_), jnp.float32)
        ci = jax.random.normal(jax.random.PRNGKey(5), (ik, if_), jnp.float32)
        ti = time_call(one_fn, xi, ci)
        ifl = distance_flops(im, ik, if_)
        out.append(row(label, ti,
                       f"GFLOPS={gflops(ifl, ti):.1f};"
                       f"shape=({im},{ik},{if_})"))

    # --- V12: the serving layer's AOT-compiled predict cell (one bucket
    # launch through repro.serve, compiled — this rung replaces the old
    # interpret-mode smoke rung, which the regression gate refused to
    # guard; a compiled cell it can watch like any other rung) ---
    from repro.serve import ServeCompiler
    comp = ServeCompiler(get_backend("gemm_fused"), k, f, buckets=(m,))
    t_v12 = time_call(
        lambda: jax.block_until_ready(comp.dispatch(x, c)[0]))
    out.append(row("fig7_v12_aot_predict", t_v12,
                   f"bucket={m};"
                   f"vs_v2_fused=x{ladder_t['gemm_fused'] / t_v12:.2f}"))

    # estimator-level anchor: 4 Lloyd iterations, unprotected vs FT policy
    for label, policy in (("fig7_e2e_off", FaultPolicy.off()),
                          ("fig7_e2e_detect", FaultPolicy.detect())):
        km = KMeans(n_clusters=k, max_iter=4, tol=0.0, fault=policy,
                    random_state=0)
        c0 = km.init_centroids(x)
        t = time_call(lambda: km.fit(x, centroids=c0), iters=2, warmup=1)
        out.append(row(label, t, f"mode={policy.mode}"))

    traffic_rows, traffic = _traffic_rows(m, k, f)
    template_rows, template = _template_rows(m, k, f)
    # model-vs-measured drift: the assign-kind analytical score against
    # the compiled fused-assignment rung at the same shape. The model
    # predicts TPU roofline time, so the absolute ratio is an
    # off-hardware constant — what CI watches is the ratio *moving*
    # (model edits or rung regressions change it; honest reruns don't).
    drift = {
        "rung": "fig7_v2_fused",
        "measured_s": ladder_t["gemm_fused"],
        "model_s": template["float32"]["score_s"],
        "ratio": ladder_t["gemm_fused"] / template["float32"]["score_s"],
        "model_basis": "tpu_analytic_roofline",
    }
    if model:
        out.extend(traffic_rows)
        out.extend(template_rows)
        out.append(row("model_vs_measured", 0.0,
                       f"rung={drift['rung']};"
                       f"measured_us={drift['measured_s'] * 1e6:.1f};"
                       f"model_us={drift['model_s'] * 1e6:.1f};"
                       f"drift=x{drift['ratio']:.2f}"))
    payload = {
        "shape": {"m": m, "k": k, "f": f},
        "smoke": smoke,
        "interpret_rungs": interpret_rungs,
        "rows": [r.split(",", 2) for r in out],
        "traffic_model_bytes": traffic,
        "template_model": template,
        "model_vs_measured": drift,
    }
    return out, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI)")
    ap.add_argument("--model", action="store_true",
                    help="emit the analytical HBM traffic rows")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + traffic model to PATH")
    args = ap.parse_args(argv)
    rows, payload = _collect(smoke=args.smoke,
                             model=args.model or bool(args.json))
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
