"""Fused k-means++ D² seeding vs the vmapped per-problem path.

The batched estimator's ``init="kmeans++"`` runs ``jax.vmap(init_kmeanspp)``
— per round and per problem a full elementwise distance recompute plus a
``jax.random.choice`` categorical draw whose cumulative distribution is
re-materialized over all N weights every round.  ``init="kmeans++-fused"``
(kernels/kmeanspp_init.py) replaces the round with one fused distance +
tile-partial-sum pass and finishes the draw with a two-level inverse CDF in
O(B·(T + block_n)) instead of O(B·N).

Both paths are timed exactly as ``BatchedKMeans.init_centroids`` invokes
them during a fit — an eager top-level call per fit (the vmapped baseline
pays its eager vmap-of-jit dispatch, the fused path its cached-jit
dispatch), because the per-fit init cost in the many-small-problems regime
is the thing the fused kernel exists to cut.

Rung: ``init_fused_vs_vmapped`` at B=64 small problems. Off-TPU the round
runs through the tile-mirrored XLA twin (same selection protocol, same
chosen indices as the Pallas kernel — tests/test_seeding.py pins that), so
the rung is a compiled perf point and ``check_regression`` may guard it
against the committed ``BENCH_init.json``.

CLI:
  --smoke        tinier batch (CI wiring)
  --json PATH    write rows + shapes to PATH (CI artifact)
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core.kmeans import init_kmeanspp
from repro.kernels.kmeanspp_init import init_kmeanspp_fused

# B=64 small problems (the acceptance regime): seeding dominated by the
# per-round categorical machinery, not the distance GEMM.
B, N, F, K = 64, 384, 16, 16
SMOKE_B, SMOKE_N, SMOKE_F, SMOKE_K = 8, 256, 16, 8

# a second, larger point so scaling of the win is visible in the artifact
B2, N2, F2, K2 = 64, 2048, 32, 16


def _keys(b: int) -> jax.Array:
    return jax.vmap(jax.random.PRNGKey)(jnp.arange(b, dtype=jnp.uint32))


def _pair(b: int, n: int, f: int, k: int, *, iters: int) -> tuple[float, float]:
    """(vmapped, fused) seconds per init call, production invocation."""
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, f), jnp.float32)
    keys = _keys(b)
    t_vm = time_call(
        lambda: jax.vmap(init_kmeanspp, in_axes=(0, 0, None))(keys, x, k),
        iters=iters, warmup=2)
    t_fu = time_call(lambda: init_kmeanspp_fused(keys, x, k),
                     iters=iters, warmup=2)
    return t_vm, t_fu


def run(smoke: bool = False) -> list[str]:
    """run.py contract: the printable CSV rows."""
    return _collect(smoke=smoke)[0]


def _collect(smoke: bool = False) -> tuple[list[str], dict]:
    b, n, f, k = (SMOKE_B, SMOKE_N, SMOKE_F, SMOKE_K) if smoke \
        else (B, N, F, K)
    iters = 5 if smoke else 15
    out = []
    t_vm, t_fu = _pair(b, n, f, k, iters=iters)
    out.append(row("init_fused_vs_vmapped", t_fu,
                   f"B={b};shape=({n},{f});K={k};"
                   f"vmapped_us={t_vm * 1e6:.1f};"
                   f"speedup=x{t_vm / t_fu:.2f}"))
    shapes = {"small": [b, n, f, k]}
    if not smoke:
        t_vm2, t_fu2 = _pair(B2, N2, F2, K2, iters=7)
        out.append(row("init_fused_vs_vmapped_large", t_fu2,
                       f"B={B2};shape=({N2},{F2});K={K2};"
                       f"vmapped_us={t_vm2 * 1e6:.1f};"
                       f"speedup=x{t_vm2 / t_fu2:.2f}"))
        shapes["large"] = [B2, N2, F2, K2]
    payload = {
        "shapes": shapes,
        "smoke": smoke,
        "interpret_rungs": [],      # both paths run compiled XLA off-TPU
        "rows": [r.split(",", 2) for r in out],
    }
    return out, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny batch (CI)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + shapes to PATH (CI artifact)")
    args = ap.parse_args(argv)
    rows, payload = _collect(smoke=args.smoke)
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
