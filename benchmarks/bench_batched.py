"""Batched many-problem K-means: one launch vs B launches.

Production traffic ("millions of users") is thousands of independent small
clustering problems, not one big one. This benchmark pits three ways of
fitting B such problems against each other at identical shapes and seeds:

  batched      ``BatchedKMeans.fit`` on the stacked (B, N, F) block — the
               batched one-pass path (problem axis outermost in the kernel
               grid / batched XLA contractions off-TPU), per-problem
               convergence masks inside one ``lax.scan``.
  vmapped      ``jax.vmap`` of the single-problem one-pass step inside the
               same scan — what you get "for free" from JAX without a
               batched backend (no per-problem masks, no estimator).
  loop         a Python loop of B single-problem fits — the dispatch-bound
               baseline the batched path exists to kill. The loop reuses
               one estimator instance so compile time is excluded; what
               remains is per-fit dispatch and per-iteration overhead x B.

The acceptance bar (ISSUE 5): batched >= 5x faster than the loop at B=64
small problems, with per-problem results bit-identical to the loop.
Bit-identity is checked here, every run, for every problem.

CLI:
  --smoke     tiny B and shapes (CI wiring)
  --json PATH write rows to PATH
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.api import BatchedKMeans, get_backend

B, N, F, K = 64, 256, 32, 8
SMOKE_B, SMOKE_N, SMOKE_F, SMOKE_K = 8, 128, 16, 4
ITERS = 20          # fixed budget (tol=0) so all three run the same steps
SEED = 3


def _problems(b, n, f, k):
    from repro.data.blobs import make_blobs
    return jnp.stack([make_blobs(n, f, k, seed=SEED + i)[0]
                      for i in range(b)])


def _vmapped_fit(est, x, iters):
    """vmap of the single-problem one-pass step at the same iteration
    budget, seeded like the estimator (vmapped kmeans++): the "free" JAX
    batching a user gets without a batched backend — no per-problem
    convergence masks, no estimator surface."""
    from repro.core.kmeans import means_from_sums
    single = get_backend("lloyd_xla")

    def one_step(xb, cb):
        am, md, det, sums, counts = single(xb, cb)
        return means_from_sums(sums, counts, cb)

    vstep = jax.vmap(one_step)

    def fit(x):
        c0 = est.init_centroids(x)
        def body(c, _):
            return vstep(x, c), None
        c, _ = jax.lax.scan(body, c0, None, length=iters)
        return c

    return jax.jit(fit)(x)


def run(smoke: bool = False) -> list[str]:
    return _collect(smoke=smoke)[0]


def _collect(smoke: bool = False) -> tuple[list[str], dict]:
    b, n, f, k = (SMOKE_B, SMOKE_N, SMOKE_F, SMOKE_K) if smoke \
        else (B, N, F, K)
    x = _problems(b, n, f, k)
    out = []

    # tol=0 pins the iteration count (every problem runs exactly ITERS
    # steps on every path), so the end-to-end rows time identical work:
    # per-problem kmeans++ seeding + ITERS one-pass Lloyd iterations.
    batched = BatchedKMeans(n_clusters=k, max_iter=ITERS, tol=0.0,
                            sync_every=ITERS, random_state=SEED)
    t_batched = time_call(lambda: batched.fit(x), iters=3, warmup=1)
    out.append(row("batched_fit", t_batched,
                   f"B={b};shape=({n},{k},{f});iters={ITERS}"))

    t_vmap = time_call(lambda: jax.block_until_ready(
        _vmapped_fit(batched, x, ITERS)), iters=3, warmup=1)
    out.append(row("vmapped_single_fit", t_vmap,
                   f"x{t_vmap / t_batched:.2f}_vs_batched"))

    # loop of single-problem fits: one reused estimator (seeds swapped per
    # problem, shapes constant) so the loop pays per-fit dispatch and
    # per-problem seeding, not compiles — the honest baseline a user runs
    # today when B problems arrive
    looper = BatchedKMeans(n_clusters=k, max_iter=ITERS, tol=0.0,
                           sync_every=ITERS, random_state=SEED)

    def loop_fit():
        centers = []
        for i in range(b):
            looper.random_state = SEED + i
            looper.fit(x[i:i + 1])
            centers.append(looper.cluster_centers_[0])
        return jnp.stack(centers)

    t_loop = time_call(loop_fit, iters=3, warmup=1)
    speedup = t_loop / t_batched
    out.append(row("loop_of_fits", t_loop,
                   f"B={b};batched_speedup=x{speedup:.2f}"))

    # warm-start pair: the same comparison with the seeding factored out
    # (both sides start from the identical c0), isolating the iteration
    # path itself — the number that survives even when inits are cached
    c0 = batched.init_centroids(x)
    t_bw = time_call(lambda: batched.fit(x, centroids=c0),
                     iters=3, warmup=1)

    def loop_fit_warm():
        centers = []
        for i in range(b):
            looper.random_state = SEED + i
            looper.fit(x[i:i + 1], centroids=c0[i:i + 1])
            centers.append(looper.cluster_centers_[0])
        return jnp.stack(centers)

    t_lw = time_call(loop_fit_warm, iters=3, warmup=1)
    warm_speedup = t_lw / t_bw
    out.append(row("batched_fit_warmstart", t_bw, "seeding excluded"))
    out.append(row("loop_of_fits_warmstart", t_lw,
                   f"batched_speedup=x{warm_speedup:.2f}"))

    # bit-identity: every problem of the batched fit equals its loop fit
    batched.fit(x)
    loop_centers = loop_fit()
    bit_identical = bool(np.array_equal(np.asarray(batched.cluster_centers_),
                                        np.asarray(loop_centers)))
    out.append(row("batched_vs_loop_bit_identical", 0.0,
                   f"identical={bit_identical}"))
    assert bit_identical, (
        "batched fit diverged from the loop of single-problem fits — the "
        "batched path must be a pure performance move")

    payload = {
        "shape": {"b": b, "n": n, "k": k, "f": f, "iters": ITERS},
        "smoke": smoke,
        "batched_speedup_vs_loop": speedup,
        "batched_speedup_vs_loop_warmstart": warm_speedup,
        "batched_speedup_vs_vmap": t_vmap / t_batched,
        "bit_identical": bit_identical,
        "rows": [r.split(",", 2) for r in out],
    }
    return out, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI)")
    ap.add_argument("--json", metavar="PATH", help="write rows to PATH")
    args = ap.parse_args(argv)
    rows, payload = _collect(smoke=args.smoke)
    print("\n".join(rows))
    ok = payload["batched_speedup_vs_loop"] >= 5.0
    print(f"# batched vs loop-of-fits: x{payload['batched_speedup_vs_loop']:.2f} "
          f"({'meets' if ok else 'BELOW'} the >=5x bar), "
          f"bit-identical={payload['bit_identical']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
