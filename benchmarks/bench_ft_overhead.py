"""Paper Fig. 15/16 — FT K-means with fault tolerance vs without.

Two layers of evidence on this host:
  * measured: full Lloyd iterations through ``repro.api.KMeans`` under
    ``FaultPolicy.off()`` vs ``FaultPolicy.detect()`` (the ABFT-checksummed
    jnp path) — wall-clock overhead;
  * analytic: the fused kernel's checksum flop overhead per tile
    (2*(bm+bk)*bf extra vs 2*bm*bk*bf), the quantity the paper's 11%
    average reflects after fusion into memory gaps.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.api import FaultPolicy, KMeans, default_cache
from repro.data.blobs import make_blobs

CASES = [  # (K clusters, F features) — paper's K=8/128, N=8/128 slices
    (8, 64), (128, 64), (32, 8), (32, 128),
]
M = 16_384


def _fit_time(x, policy, k):
    km = KMeans(n_clusters=k, max_iter=8, tol=0.0, fault=policy,
                random_state=0)
    c0 = km.init_centroids(x)
    return time_call(lambda: km.fit(x, centroids=c0), iters=3, warmup=1)


def run() -> list[str]:
    out = []
    cache = default_cache()
    for k, f in CASES:
        x, _ = make_blobs(M, f, k, seed=2)
        t_plain = _fit_time(x, FaultPolicy.off(), k)
        t_ft = _fit_time(x, FaultPolicy.detect(update_dmr=False), k)
        ovh = (t_ft - t_plain) / t_plain * 100
        out.append(row(f"fig15_K{k}_N{f}_noft", t_plain, ""))
        out.append(row(f"fig15_K{k}_N{f}_ft", t_ft,
                       f"overhead={ovh:.1f}%"))
        _, p = cache.lookup(M, k, f)
        kernel_ovh = (2 * (p.block_m + p.block_k) * p.block_f) / \
            (2 * p.block_m * p.block_k * p.block_f) * 100 * 2
        out.append(row(f"fig15_K{k}_N{f}_kernel_flop_ovh", 0.0,
                       f"fused_checksum_flops={kernel_ovh:.2f}%"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
