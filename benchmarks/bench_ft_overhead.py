"""Paper Fig. 15/16 — FT K-means with fault tolerance vs without.

Three layers of evidence on this host:
  * measured (two-pass): full Lloyd iterations through ``repro.api.KMeans``
    under ``FaultPolicy.off()`` vs ``FaultPolicy.detect()`` (the
    ABFT-checksummed jnp path) — wall-clock overhead of the legacy
    pipeline;
  * measured (one-pass): the headline pair — the unprotected one-pass
    backend (``lloyd_xla``) vs the one-pass *FT* backend
    (``lloyd_ft_xla``, the XLA analogue of ``kernels/lloyd_step_ft.py``)
    with an explicit ``overhead %`` row. This is the configuration the
    paper's ~11% average describes: protection fused into the fastest
    iteration, not paid on top of a slower two-pass loop;
  * analytic: the fused kernel's checksum flop overhead per tile
    (2*(bm+bk)*bf extra vs 2*bm*bk*bf), the quantity the measured
    overhead converges to once fused into memory gaps on real hardware.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.api import FaultPolicy, KMeans, default_cache
from repro.data.blobs import make_blobs

CASES = [  # (K clusters, F features) — paper's K=8/128, N=8/128 slices
    (8, 64), (128, 64), (32, 8), (32, 128),
]
M = 16_384


def _fit_time(x, policy, k, backend=None):
    km = KMeans(n_clusters=k, max_iter=8, tol=0.0, fault=policy,
                backend=backend, random_state=0)
    c0 = km.init_centroids(x)
    return time_call(lambda: km.fit(x, centroids=c0), iters=3, warmup=1)


def run() -> list[str]:
    out = []
    onepass_overheads = []
    cache = default_cache()
    for k, f in CASES:
        x, _ = make_blobs(M, f, k, seed=2)
        t_plain = _fit_time(x, FaultPolicy.off(), k)
        t_ft = _fit_time(x, FaultPolicy.detect(update_dmr=False), k)
        ovh = (t_ft - t_plain) / t_plain * 100
        out.append(row(f"fig15_K{k}_N{f}_noft", t_plain, ""))
        out.append(row(f"fig15_K{k}_N{f}_ft", t_ft,
                       f"overhead={ovh:.1f}%"))
        _, p = cache.lookup(M, k, f)
        kernel_ovh = (2 * (p.block_m + p.block_k) * p.block_f) / \
            (2 * p.block_m * p.block_k * p.block_f) * 100 * 2
        out.append(row(f"fig15_K{k}_N{f}_kernel_flop_ovh", 0.0,
                       f"fused_checksum_flops={kernel_ovh:.2f}%"))

        # one-pass pair: protection fused into the fastest iteration
        # (FaultPolicy.correct() resolves to a fuses_update backend, so
        # enabling FT no longer forfeits the one-pass speedup)
        t_one = _fit_time(x, FaultPolicy.off(), k, backend="lloyd_xla")
        t_one_ft = _fit_time(x, FaultPolicy.correct(update_dmr=False), k,
                             backend="lloyd_ft_xla")
        ovh_one = (t_one_ft - t_one) / t_one * 100
        onepass_overheads.append(ovh_one)
        out.append(row(f"fig16_onepass_K{k}_N{f}_noft", t_one, ""))
        out.append(row(f"fig16_onepass_K{k}_N{f}_ft", t_one_ft, ""))
        out.append(row(f"fig16_onepass_K{k}_N{f}_overhead", 0.0,
                       f"onepass_ft_overhead={ovh_one:.1f}%;paper_target=11%"))
    # the paper's 11% figure is an *average* across shapes; the mean is
    # also the noise-robust summary on a shared CPU host
    mean = sum(onepass_overheads) / len(onepass_overheads)
    out.append(row("fig16_onepass_overhead_mean", 0.0,
                   f"onepass_ft_overhead_mean={mean:.1f}%;paper_target=11%"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
