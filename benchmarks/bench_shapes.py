"""Paper Fig. 8-11 (A100) / 19-20 (T4) — distance-step performance across
problem shapes: sweep the feature dim N with K fixed, and sweep the
cluster count K with N fixed, comparing the shape-adaptive path (autotuned
parameters) against the fixed-parameter two-pass baseline (cuML-analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import distance_flops, gflops, row, time_call
from repro.core import assignment as assign_mod

M = 16_384
N_SWEEP = (8, 16, 32, 64, 128, 256)      # feature dims  (K fixed = 128)
K_SWEEP = (8, 16, 32, 64, 128, 256)      # cluster counts (N fixed = 64)


def _bench_pair(m, k, f, out, tag):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, f), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (k, f), jnp.float32)
    fl = distance_flops(m, k, f)

    baseline = jax.jit(lambda x, c: assign_mod.assign_gemm(x, c)[0])
    t_base = time_call(baseline, x, c)

    fused = jax.jit(lambda x, c: assign_mod.assign_gemm_fused(x, c)[0])
    t_fused = time_call(fused, x, c)

    out.append(row(f"{tag}_baseline", t_base,
                   f"GFLOPS={gflops(fl, t_base):.1f}"))
    out.append(row(f"{tag}_ftkmeans", t_fused,
                   f"GFLOPS={gflops(fl, t_fused):.1f};"
                   f"speedup={t_base / t_fused:.2f}"))


def run() -> list[str]:
    out = []
    for f in N_SWEEP:
        _bench_pair(M, 128, f, out, f"fig8_N{f}_K128")
    for k in K_SWEEP:
        _bench_pair(M, k, 64, out, f"fig10_K{k}_N64")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
