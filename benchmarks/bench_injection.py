"""Paper Fig. 17/18/21 — FT K-means under error injection, vs the two
baselines: Wu-style offline ABFT and Taamneh checkpoint/restart.

Metrics: wall-clock overhead vs the unprotected run AND solution quality
(inertia must match the clean solution — silent corruption is the failure
mode checkpointing cannot see). All K-means runs go through
``repro.api.KMeans`` with a ``FaultPolicy``; the checkpoint/restart
baseline keeps its legacy-config surface (it *is* the legacy scheme).
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.api import FaultPolicy, InjectionCampaign, KMeans
from repro.core.baselines import CheckpointRestartKMeans
from repro.data.blobs import make_blobs

M, F, K = 8_192, 64, 16
ITERS = 6
RATES = (0.5, 1.0)   # injections per Lloyd iteration (paper: tens/second)


def run() -> list[str]:
    x, _ = make_blobs(M, F, K, seed=4)
    out = []
    km = KMeans(n_clusters=K, max_iter=ITERS, tol=0.0,
                fault=FaultPolicy.off(), random_state=0)
    c0 = km.init_centroids(x)
    t_clean = time_call(lambda: km.fit(x, centroids=c0), iters=2, warmup=1)
    clean_inertia = km.fit(x, centroids=c0).inertia_
    out.append(row("fig17_clean", t_clean, f"inertia={clean_inertia:.4g}"))

    for rate in RATES:
        ft = KMeans(n_clusters=K, max_iter=ITERS, tol=0.0,
                    fault=FaultPolicy.detect(), random_state=0)
        t_ft = time_call(lambda: ft.fit(x, centroids=c0), iters=2, warmup=1)
        inertia = ft.fit(x, centroids=c0).inertia_
        out.append(row(f"fig17_ftkmeans_rate{rate}", t_ft,
                       f"overhead={(t_ft - t_clean) / t_clean * 100:.1f}%;"
                       f"inertia_ok={abs(inertia - clean_inertia) < abs(clean_inertia) * 1e-3}"))

        campaign = InjectionCampaign(rate=rate, seed=11)
        from repro.core.kmeans import KMeansConfig
        base_cfg = KMeansConfig(k=K, max_iters=ITERS, tol=0.0,
                                assignment="gemm_fused",
                                dmr_update=False, seed=0)
        ckr = CheckpointRestartKMeans(base_cfg)
        fc = campaign.to_fault_config()
        t_ck = time_call(lambda: ckr.fit(x, fault=fc, centroids=c0),
                         iters=2, warmup=1)
        _, stats = ckr.fit(x, fault=fc, centroids=c0)
        out.append(row(f"fig17_ckpt_restart_rate{rate}", t_ck,
                       f"overhead={(t_ck - t_clean) / t_clean * 100:.1f}%;"
                       f"rollbacks={stats['rollbacks']};"
                       f"wasted_iters={stats['wasted_iterations']};"
                       f"gave_up={stats['gave_up']}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
