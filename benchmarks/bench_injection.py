"""Paper Fig. 17/18/21 — FT K-means under error injection, vs the two
baselines: Wu-style offline ABFT and Taamneh checkpoint/restart.

Metrics: wall-clock overhead vs the unprotected run AND solution quality
(inertia must match the clean solution — silent corruption is the failure
mode checkpointing cannot see).
"""
from __future__ import annotations

import jax

from benchmarks.common import row, time_call
from repro.core import FaultConfig, KMeans, KMeansConfig
from repro.core.baselines import CheckpointRestartKMeans
from repro.data.blobs import make_blobs

M, F, K = 8_192, 64, 16
ITERS = 6
RATES = (0.5, 1.0)   # injections per Lloyd iteration (paper: tens/second)


def run() -> list[str]:
    x, _ = make_blobs(M, F, K, seed=4)
    out = []
    base_cfg = KMeansConfig(k=K, max_iters=ITERS, tol=0.0,
                            assignment="gemm_fused", dmr_update=False, seed=0)
    km = KMeans(base_cfg)
    c0 = km.init_centroids(x)
    t_clean = time_call(lambda: km.fit(x, centroids=c0), iters=2, warmup=1)
    clean_inertia = float(km.fit(x, centroids=c0).inertia)
    out.append(row("fig17_clean", t_clean, f"inertia={clean_inertia:.4g}"))

    for rate in RATES:
        fc = FaultConfig(rate=rate, seed=11)
        ft_cfg = KMeansConfig(k=K, max_iters=ITERS, tol=0.0,
                              assignment="abft_offline", dmr_update=True,
                              seed=0)
        ft = KMeans(ft_cfg)
        t_ft = time_call(lambda: ft.fit(x, centroids=c0), iters=2, warmup=1)
        res = ft.fit(x, centroids=c0)
        out.append(row(f"fig17_ftkmeans_rate{rate}", t_ft,
                       f"overhead={(t_ft - t_clean) / t_clean * 100:.1f}%;"
                       f"inertia_ok={abs(float(res.inertia) - clean_inertia) < abs(clean_inertia) * 1e-3}"))

        ckr = CheckpointRestartKMeans(base_cfg)
        t_ck = time_call(lambda: ckr.fit(x, fault=fc, centroids=c0),
                         iters=2, warmup=1)
        _, stats = ckr.fit(x, fault=fc, centroids=c0)
        out.append(row(f"fig17_ckpt_restart_rate{rate}", t_ck,
                       f"overhead={(t_ck - t_clean) / t_clean * 100:.1f}%;"
                       f"rollbacks={stats['rollbacks']};"
                       f"wasted_iters={stats['wasted_iterations']};"
                       f"gave_up={stats['gave_up']}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
