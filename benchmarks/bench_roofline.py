"""§Roofline summary benchmark: reads the dry-run result cache and prints
per-cell roofline terms (compute/memory/collective seconds + bottleneck).
Run `python -m repro.launch.dryrun --all --both-meshes` first to populate.
"""
from __future__ import annotations

import os

from benchmarks.common import row
from repro.roofline.analysis import analyze, load_records

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run() -> list[str]:
    out = []
    for mesh_tag in ("pod16x16", "pod2x16x16"):
        for rec in load_records(os.path.abspath(RESULTS), mesh_tag):
            r = analyze(rec)
            name = f"roofline_{rec['arch']}_{rec['shape']}_{mesh_tag}"
            if r is None:
                out.append(row(name, 0.0, f"status={rec['status']}"))
                continue
            out.append(row(
                name, max(r.compute_s, r.memory_s, r.collective_s),
                f"bottleneck={r.bottleneck};compute={r.compute_s:.2e};"
                f"memory={r.memory_s:.2e};collective={r.collective_s:.2e};"
                f"useful={r.useful_ratio:.2f};frac={r.roofline_fraction:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
