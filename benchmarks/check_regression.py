"""CI gate: compare a fresh ``bench_stepwise`` artifact against the
committed ``BENCH_stepwise.json`` baseline and fail on wall-time regression
of the guarded rungs.

Usage::

    python -m benchmarks.check_regression BASELINE NEW \
        [--rung fig7_v5_onepass] [--max-ratio 1.25]

``--rung`` may repeat; default guards the one-pass rung, the one-pass FT
rung (``fig7_v7_ft_onepass`` — the protected path must not quietly drift
back toward two-pass cost), the batched many-problem rung
(``fig7_v8_batched`` — one launch for B problems must not quietly decay
toward loop-of-launches cost), the pruned rung (``fig7_v9_pruned`` —
the bounds bookkeeping must not eat the skipped-GEMM win), the compiled
small-K rung (``fig7_v6_smallk``), the int8 template rung
(``fig7_v10_int8`` — the quantize/scale-correct epilogue must not eat the
low-precision win) and the double-buffered one-pass rung
(``fig7_v11_dbuf`` — the stash pipelining rework must not change the
analogue's cost) and the serving layer's AOT predict cell
(``fig7_v12_aot_predict`` — the compiled rung that replaced the old
interpret-mode smoke rung; bucketed dispatch must not grow hidden
per-request cost). The fused-seeding rung (``init_fused_vs_vmapped``)
lives in ``BENCH_init.json`` and the micro-batching rung
(``serve_microbatch_vs_naive``) in ``BENCH_serve.json``; each is guarded
by its own invocation against that artifact (see the Makefile
``bench-check`` target). A rung missing
from the *baseline* is skipped (it was just added); a rung missing from the
*new* artifact is an error (a ladder rung silently disappeared). Rows whose
recorded time is 0 (model rows) are rejected as guards.

Interpret-mode rungs are *refused* as guards: the artifact names them in
``interpret_rungs`` (and marks each row's derived column with
``interpret=True``), and asking this gate to guard one is an error —
interpret wall-time is a Python-loop-bound smoke signal that must never
enter the regression baseline, silently or otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_RUNGS = ["fig7_v5_onepass", "fig7_v7_ft_onepass", "fig7_v8_batched",
                 "fig7_v9_pruned", "fig7_v6_smallk", "fig7_v10_int8",
                 "fig7_v11_dbuf", "fig7_v12_aot_predict"]


def _times(payload: dict) -> dict[str, float]:
    return {name: float(t) for name, t, _ in payload["rows"]}


def _interpret_rungs(payload: dict) -> set[str]:
    """Rungs the artifact marks as interpret-mode: the explicit
    ``interpret_rungs`` list, plus any row whose derived column carries
    the ``interpret=True`` marker (older artifacts have only the rows)."""
    marked = set(payload.get("interpret_rungs", []))
    for name, _, derived in payload["rows"]:
        if "interpret=True" in str(derived):
            marked.add(name)
    return marked


def check(baseline: dict, new: dict, rungs: list[str],
          max_ratio: float) -> list[str]:
    """Return a list of failure messages (empty = gate passes)."""
    base_t, new_t = _times(baseline), _times(new)
    refused = _interpret_rungs(baseline) | _interpret_rungs(new)
    failures = []
    for rung in rungs:
        if rung in refused:
            failures.append(
                f"{rung}: interpret-mode rung — its wall-time is a smoke "
                f"signal, not a perf baseline; this gate refuses to guard "
                f"it (drop it from --rung)")
            continue
        if rung not in new_t:
            failures.append(f"{rung}: missing from the new artifact")
            continue
        if rung not in base_t:
            print(f"check_regression: {rung} not in baseline yet — skipped")
            continue
        old, cur = base_t[rung], new_t[rung]
        if old <= 0.0:
            failures.append(f"{rung}: baseline time is {old} — not a "
                            f"measurable rung")
            continue
        ratio = cur / old
        verdict = "FAIL" if ratio > max_ratio else "ok"
        print(f"check_regression: {rung}: {old:.1f} -> {cur:.1f} us "
              f"(x{ratio:.2f}, limit x{max_ratio:.2f}) {verdict}")
        if ratio > max_ratio:
            failures.append(f"{rung}: {old:.1f} -> {cur:.1f} us is a "
                            f"x{ratio:.2f} regression (limit "
                            f"x{max_ratio:.2f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_stepwise.json")
    ap.add_argument("new", help="freshly produced BENCH_stepwise.json")
    ap.add_argument("--rung", action="append", default=None,
                    help="rung name to guard (repeatable); default "
                         f"{' + '.join(DEFAULT_RUNGS)}")
    ap.add_argument("--max-ratio", type=float, default=1.25,
                    help="fail when new/baseline exceeds this (default "
                         "1.25 = >25%% slower)")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.new) as fh:
        new = json.load(fh)
    failures = check(baseline, new, args.rung or DEFAULT_RUNGS,
                     args.max_ratio)
    for msg in failures:
        print(f"check_regression: FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
