"""Hierarchical vs flat vs compressed centroid reduce on a pod-shaped mesh.

Times one reduced Lloyd step of ``DistributedKMeans`` on an 8-virtual-
device CPU mesh (``mesh2d(8, hosts=2)`` — 2 simulated hosts x 4 rows)
under each :class:`~repro.dist.reduce.ReducePlan`:

  * ``flat``          one psum over every data axis (the PR-1 reduce)
  * ``hierarchical``  exact intra-host psum + exact cross-host hop
  * ``compressed``    exact intra-host psum + int8 error-feedback hop

On virtual CPU devices every "link" is the same memcpy, so the wall-clock
deltas here calibrate the *software* cost of the two-hop structure (extra
collective launches, quantize/dequantize arithmetic), not the cross-pod
bandwidth win the hierarchy exists for — the derived column carries the
ratios so ``check_regression`` can gate the hierarchical rung
(``dist_hier_vs_flat``) against the committed ``BENCH_dist.json``.

Standalone module (like bench_serve): it must own process start-up —
the 8 virtual devices exist only if ``XLA_FLAGS`` is set before jax
initializes, so it is NOT in ``benchmarks.run``'s in-process module list.

CLI:
  --smoke        tiny shapes (CI wiring)
  --json PATH    write rows + shapes to PATH (CI artifact)
"""
from __future__ import annotations

import argparse
import json
import os

# must precede the first jax import: device count locks at backend init
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from benchmarks.common import row, time_call            # noqa: E402

M, K, F = 8192, 64, 128
SMOKE_M, SMOKE_K, SMOKE_F = 2048, 16, 64


def _step_seconds(plan, x, c0, mesh, *, k, iters):
    """Seconds per reduced Lloyd step under ``plan`` (jitted, warmed)."""
    from repro.api import KMeans
    from repro.core.fault import no_step_injection
    from repro.dist.kmeans_dist import DistributedKMeans
    est = KMeans(k, max_iter=5, random_state=0)
    d = DistributedKMeans(est, mesh, reduce=plan)
    xs = d.shard_data(x)
    f = x.shape[1]
    step = d._build_step(x.shape[0] // d._rp, f)
    inj = no_step_injection(d._shard_backend().kernel_kind)
    if d._compress:
        res = jax.device_put(
            jnp.zeros((mesh.shape["host"], k, f), jnp.float32),
            NamedSharding(mesh, P("host", None, None)))
    else:
        res = jnp.zeros((1, k, f), jnp.float32)
    c = jnp.asarray(c0)
    return time_call(lambda: step(xs, c, inj, res), iters=iters)


def run(smoke: bool = False) -> list[str]:
    return _collect(smoke=smoke)[0]


def _collect(smoke: bool = False) -> tuple[list[str], dict]:
    from repro.dist.reduce import ReducePlan
    from repro.dist.sharding import mesh2d
    if len(jax.devices()) < 8:    # env was pinned before we loaded
        raise SystemExit("bench_dist needs 8 virtual devices; run as "
                         "`python -m benchmarks.bench_dist` in a fresh "
                         "process")
    m, k, f = (SMOKE_M, SMOKE_K, SMOKE_F) if smoke else (M, K, F)
    iters = 5 if smoke else 11
    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, f)).astype(np.float32)
    c0 = x[rng.choice(m, size=k, replace=False)].copy()
    mesh = mesh2d(8, hosts=2)

    t_flat = _step_seconds(ReducePlan.flat(), x, c0, mesh, k=k,
                           iters=iters)
    t_hier = _step_seconds(ReducePlan(), x, c0, mesh, k=k, iters=iters)
    t_comp = _step_seconds(ReducePlan.compressed(), x, c0, mesh, k=k,
                           iters=iters)
    shape = f"M={m};K={k};F={f};mesh=2x4"
    out = [
        row("dist_hier_vs_flat", t_hier,
            f"{shape};flat_us={t_flat * 1e6:.1f};"
            f"ratio=x{t_hier / t_flat:.2f}"),
        row("dist_compressed_hop", t_comp,
            f"{shape};hier_us={t_hier * 1e6:.1f};"
            f"ratio=x{t_comp / t_hier:.2f}"),
    ]
    payload = {
        "shapes": {"grid": [m, k, f], "mesh": [2, 4, 1]},
        "smoke": smoke,
        "interpret_rungs": [],      # every plan runs compiled XLA off-TPU
        "rows": [r.split(",", 2) for r in out],
    }
    return out, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny shapes (CI)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + shapes to PATH (CI artifact)")
    args = ap.parse_args(argv)
    rows, payload = _collect(smoke=args.smoke)
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
