"""Paper Fig. 13/14 + Table I — the code-generation / parameter-selection
pipeline: candidate generation under the pruning rules, feasibility
filtering, and per-shape winner selection (analytical TPU model — the
measured selection runs on device; §Perf records the CPU-measured variant).
"""
from __future__ import annotations

from benchmarks.common import row
from repro.core.autotune import (feasible, model_score, parameter_space,
                                 select_params)

SHAPES = [
    (131_072, 8, 128), (131_072, 128, 128),      # paper's fixed-M slices
    (131_072, 128, 8), (131_072, 128, 2048),
    (16_384, 64, 64), (1_048_576, 16, 256),
]


def run() -> list[str]:
    out = []
    space = parameter_space()
    ok = [p for p in space if feasible(p)]
    out.append(row("fig13_candidates", 0.0,
                   f"total={len(space)};feasible={len(ok)}"))
    for m, k, f in SHAPES:
        variant, p = select_params(m, k, f, mode="model")
        t_model = model_score(m, k, f, p, variant=variant)
        out.append(row(f"fig14_winner_M{m}_K{k}_N{f}", t_model,
                       f"block=({p.block_m},{p.block_k},{p.block_f});"
                       f"variant={variant}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
