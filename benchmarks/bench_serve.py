"""Serving-layer benchmark: AOT predict cells, micro-batching, latency.

Three measurements, in increasing assembly order:

1. ``serve_cell_b{bucket}`` — warm launch time of each AOT-compiled
   predict cell in the default bucket ladder (the floor any request
   pays once it reaches the device).
2. ``serve_microbatch_vs_naive`` — the tentpole rung, gated in CI: a
   wave of concurrent small requests served through the
   :class:`~repro.serve.MicroBatcher` (coalesced into one padded-bucket
   launch) vs naive per-request dispatch of the same wave. The derived
   field carries the throughput ratio; the run *fails* if micro-batching
   is under 2x — that regression means the dispatch-amortization story
   is broken, not merely slower.
3. ``serve_lat_r*`` — p50/p99 latency at varying offered request rates
   from a deterministic discrete-event queue simulation fed by the
   *measured* cell times (methodology in docs/serving.md: off-TPU
   wall-clocks are noisy, so the latency table is derived from the
   measured launch floor; arrivals are seeded Poisson).

All rungs are compiled — ``interpret_rungs`` is empty by construction.

CLI:
  --json PATH    write rows + config to PATH (CI artifact BENCH_serve.json)
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import clustered_blobs, row, time_call
from repro.api import KMeans
from repro.serve import DEFAULT_BUCKETS, plan_ladder

K, F = 64, 64
FIT_ROWS = 4096
REQUESTS, REQ_ROWS = 32, 16        # the default load point: 32 x 16-row
RATES = (0.25, 0.5, 1.0, 2.0)      # offered load, x one-cell capacity
SIM_REQUESTS = 2000


def _percentile(lat: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat), q))


def _queue_sim(arr: np.ndarray, rows: np.ndarray, cost_of, *,
               batched: bool) -> list[float]:
    """Single-server queue over Poisson arrivals: the server takes either
    everything that has arrived when it frees up (micro-batched) or one
    request at a time (naive); returns per-request latencies."""
    lat: list[float] = []
    i, n, t = 0, len(arr), 0.0
    while i < n:
        start = max(t, float(arr[i]))
        j = i + 1
        if batched:
            while j < n and arr[j] <= start:
                j += 1
        total = int(np.sum(rows[i:j]))
        done = start + cost_of(total)
        lat.extend(done - float(arr[r]) for r in range(i, j))
        t = done
        i = j
    return lat


def _collect() -> tuple[list[str], dict]:
    rng = np.random.default_rng(0)
    x, _ = clustered_blobs(FIT_ROWS, F, K)
    km = KMeans(n_clusters=K, max_iter=3, tol=0.0, random_state=0).fit(x)
    svc = km.to_service(buckets=DEFAULT_BUCKETS, window_s=0.0)
    comp, store = svc.compiler, svc.store
    cb = store.current()
    out: list[str] = []

    # --- per-bucket compiled-cell launch floor ---
    cell_t: dict[int, float] = {}
    for bucket in comp.buckets:
        q = np.asarray(rng.normal(size=(bucket, F)), np.float32)
        t = time_call(lambda q=q: jax.block_until_ready(
            comp.dispatch(q, cb.centroids)[0]))
        cell_t[bucket] = t
        out.append(row(f"serve_cell_b{bucket}", t, f"rows={bucket}"))

    # --- micro-batched vs naive per-request dispatch (the gated rung) ---
    reqs = [np.asarray(rng.normal(size=(REQ_ROWS, F)), np.float32)
            for _ in range(REQUESTS)]

    def micro_wave() -> None:
        tickets = [svc.batcher.submit(q) for q in reqs]
        svc.batcher.flush()
        jax.block_until_ready([tk.result()[0] for tk in tickets])

    def naive_wave() -> None:
        jax.block_until_ready(
            [comp.dispatch(q, cb.centroids)[0] for q in reqs])

    t_micro = time_call(micro_wave)
    t_naive = time_call(naive_wave)
    ratio = t_naive / t_micro
    if ratio < 2.0:
        raise RuntimeError(
            f"micro-batching is only x{ratio:.2f} over naive per-request "
            f"dispatch at the default load point ({REQUESTS} x {REQ_ROWS} "
            f"rows) — the dispatch-amortization contract (>=2x) is "
            f"broken; fix before re-committing the artifact")
    out.append(row("serve_microbatch_vs_naive", t_micro,
                   f"naive_us={t_naive * 1e6:.1f};x{ratio:.2f};"
                   f"load={REQUESTS}x{REQ_ROWS}rows"))

    # --- p50/p99 latency vs offered rate (sim over measured cell times) ---
    def cost_of(total_rows: int) -> float:
        top = comp.buckets[-1]
        full, rem = divmod(total_rows, top)
        c = full * cell_t[top]
        if rem:
            c += cell_t[comp.bucket_for(rem)]
        return c if c else cell_t[comp.buckets[0]]

    base_rate = 1.0 / cell_t[comp.bucket_for(REQ_ROWS)]   # one-cell capacity
    lat_rows = []
    for mult in RATES:
        rate = base_rate * mult
        arr = np.cumsum(rng.exponential(1.0 / rate, SIM_REQUESTS))
        sizes = np.full(SIM_REQUESTS, REQ_ROWS)
        lat_b = _queue_sim(arr, sizes, cost_of, batched=True)
        lat_n = _queue_sim(arr, sizes, cost_of, batched=False)
        name = f"serve_lat_r{mult:g}x"
        p50, p99 = _percentile(lat_b, 50), _percentile(lat_b, 99)
        out.append(row(name, p50,
                       f"p99_us={p99 * 1e6:.1f};"
                       f"naive_p50_us={_percentile(lat_n, 50) * 1e6:.1f};"
                       f"naive_p99_us={_percentile(lat_n, 99) * 1e6:.1f};"
                       f"rate={rate:.0f}req/s"))
        lat_rows.append({"rate_mult": mult, "rate_req_s": rate,
                         "p50_s": p50, "p99_s": p99})

    # --- the tuned plan for this model shape (model-mode, deterministic) ---
    plan = plan_ladder(K, F, cache=km.autotune)
    out.append(row("serve_ladder_plan", 0.0,
                   f"buckets={'|'.join(map(str, plan.buckets))};"
                   f"window_us={plan.window_us:.1f}"))

    payload = {
        "shape": {"k": K, "f": F, "requests": REQUESTS,
                  "request_rows": REQ_ROWS},
        "buckets": list(comp.buckets),
        "planned": {"buckets": list(plan.buckets),
                    "window_us": plan.window_us},
        "interpret_rungs": [],
        "rows": [r.split(",", 2) for r in out],
        "latency_sim": lat_rows,
    }
    return out, payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + serving config to PATH")
    args = ap.parse_args(argv)
    rows, payload = _collect()
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
