"""Batched many-problem K-means estimator over the one-pass kernel stack.

One :class:`BatchedKMeans` fits B independent clustering problems at once:

    bkm = BatchedKMeans(n_clusters=8)
    bkm.fit(x)                  # x (B, N, F): B stacked problems
    labels = bkm.predict(x)     # (B, N) per-problem labels
    state = bkm.get_state()     # serializable fitted state

The whole fit is one kernel launch per iteration (the batched one-pass
Lloyd kernel maps problems to the outermost grid dimension) and one
``lax.scan`` per ``sync_every``-iteration chunk: per-problem convergence
masks freeze finished problems in place, so early convergers stop updating
without desynchronizing the batch, and per-problem results are
bit-identical to running each problem alone (same epilogue, same reduction
order, same seeds — problem ``b`` uses ``random_state + b``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.cache import AutotuneCache, default_cache
from repro.api.estimator import _host_read
from repro.api.registry import (AssignmentBackend, BackendCapabilityError,
                                get_backend)
from repro.kernels import ops

_INITS = ("kmeans++", "random", "kmeans++-fused")
_COMPUTE_DTYPES = ("float32", "bfloat16", "float16")


def make_batched_chunk(backend, params, cast, tol: float, n_steps: int):
    """Build the (un-jitted) ``n_steps``-iteration batched Lloyd chunk.

    One definition serves both drivers: :class:`BatchedKMeans` jits it
    directly, and the problem-axis-sharded ``DistributedKMeans`` mode runs
    it inside ``shard_map`` on each shard's slice of the problem stack —
    per-problem arithmetic (masks, reseeding, reduction order) is then
    identical on both paths by construction, which is what makes sharded
    results bit-comparable to single-device ones.

    Every step computes the full batched kernel launch, then a per-problem
    ``where`` mask keeps finished problems' centroids/labels/inertia
    frozen: early convergers stop *changing* without desynchronizing the
    batch (one problem's convergence can never alter another's
    arithmetic). The returned callable maps
    ``(plan, centroids, am0, inertia0, done0, det0, keys, it0)`` to
    ``((centroids, am, inertia, done, det), live_hist)`` where ``plan`` is
    a :class:`~repro.kernels.ops.BatchPlan` for ``takes_params`` backends
    and the cast (B, N, F) stack otherwise, and ``live_hist`` has shape
    ``(n_steps, B)``.
    """
    from repro.core.kmeans import means_from_sums, reseed_empty
    takes_params = backend.takes_params

    def chunk(plan, centroids, am0, inertia0, done0, det0, keys, it0):
        # the BatchPlan feeds the kernel directly (takes_params); the
        # XLA analogue gets the cast stack itself; reseeding always
        # draws donors from the unpadded samples
        x = plan.x if takes_params else plan

        def body(carry, t):
            c, am, inertia, done, det = carry
            out = backend(plan, cast(c),
                          params=params if takes_params else None)
            am_n, md, det_i, sums, counts = out
            inertia_n = jnp.sum(md, axis=1)                    # (B,)
            new_c = jax.vmap(means_from_sums)(sums, counts, c)
            shift = jnp.sqrt(jnp.sum((new_c - c) ** 2, axis=(1, 2)))
            rk = jax.vmap(
                lambda kb: jax.random.fold_in(kb, it0 + t))(keys)
            new_c = jax.vmap(reseed_empty)(rk, x, new_c, counts, md)
            live = jnp.logical_not(done)                       # (B,)
            new_c = jnp.where(live[:, None, None], new_c, c)
            am_o = jnp.where(live[:, None], am_n, am)
            inertia_o = jnp.where(live, inertia_n, inertia)
            done_n = jnp.logical_or(done, shift < tol)
            det_o = det + det_i.astype(jnp.int32)
            return (new_c, am_o, inertia_o, done_n, det_o), live

        init = (centroids, am0, inertia0, done0, det0)
        return jax.lax.scan(body, init, jnp.arange(n_steps),
                            length=n_steps)

    return chunk


class BatchedKMeans:
    """K-means over B stacked independent problems, one launch per step.

    Fits ``x`` of shape ``(B, N, F)`` — B problems, each with N samples of
    F features — against per-problem centroid stacks ``(B, K, F)``. The
    paper's template framework (§III-B) adapts one kernel to many shapes;
    this estimator adapts one *launch* to many problems: the batched
    one-pass Lloyd kernel threads the problem axis through the outermost
    grid dimension, so B small problems cost one dispatch instead of B
    (the regime where per-problem launches waste the MXU).

    Parameters
    ----------
    n_clusters : int, default=8
        Number of clusters K in *every* problem (stacked problems share
        K — ragged K would break the single centroid tile the batched
        template is built on).
    max_iter : int, default=100
        Lloyd iteration budget per problem.
    tol : float, default=1e-4
        Per-problem centroid-shift convergence threshold: problem ``b``
        freezes once ``||C_b' - C_b||_F < tol``. Frozen problems stop
        updating (their carry passes through the scan unchanged) but the
        batch keeps stepping until every problem froze or ``max_iter``.
    init : {"kmeans++", "random", "kmeans++-fused"}, default="kmeans++"
        Per-problem seeding; problem ``b`` draws from its own key (see
        ``random_state``). ``"kmeans++-fused"`` runs D² sampling through
        the fused round kernel (one launch per round for the whole batch,
        tiled inverse-CDF selection) instead of B vmapped categorical
        draws — same distribution, different stream consumption, so its
        seeds are reproducible against itself but not against
        ``"kmeans++"``.
    backend : str, optional
        Pin a registered backend by name; it must declare
        ``supports_batch=True``. Default: the batched Pallas kernel
        (``lloyd_batched``) on TPU, its XLA analogue
        (``lloyd_batched_xla``) elsewhere.
    params : KernelParams, optional
        Explicit tile override for the Pallas backend.
    autotune : AutotuneCache, optional
        Injectable kernel-selection table; defaults to the process cache.
        Batched winners live under the ``batched`` kind and the fit's B
        bucket (cache schema v4).
    sync_every : int, default=10
        Iterations per device-resident ``lax.scan`` chunk; the host
        observes convergence only at chunk boundaries.
    compute_dtype : {"float32", "bfloat16", "float16"}, default="float32"
        Kernel compute dtype; casts happen at the kernel boundary and the
        stored ``cluster_centers_`` stay f32 (same contract as
        :class:`repro.api.KMeans`).
    random_state : int, default=0
        Base seed. Problem ``b`` uses key ``PRNGKey(random_state + b)``
        for init and empty-cluster reseeding, so a batched fit is
        bit-identical to B single-problem fits seeded ``random_state + b``.

    Attributes
    ----------
    cluster_centers_ : jax.Array, shape (B, K, F), float32
        Fitted per-problem centroids.
    labels_ : jax.Array, shape (B, N), int32
        Assignment of each sample at the final executed iteration of its
        problem.
    inertia_ : numpy.ndarray, shape (B,), float
        Per-problem sum of squared distances at that iteration.
    n_iter_ : numpy.ndarray, shape (B,), int
        Iterations each problem actually executed before freezing.
    detected_errors_ : int
        Detected-SDC total (always 0 for the unprotected batched backends;
        the slot keeps the surface uniform with :class:`repro.api.KMeans`).

    See Also
    --------
    repro.api.KMeans : the single-problem estimator (fault policies,
        streaming, chunked inference).
    repro.kernels.ops.fused_lloyd_batched : the underlying batched op.

    Notes
    -----
    Fault policies are not yet wired into the batched path: the batched
    kernel has no FT template, so there is no ``fault`` parameter here.
    Protect giant single problems with ``KMeans(fault=...)``; batched
    traffic is (for now) unprotected by construction.

    Examples
    --------
    >>> import jax, jax.numpy as jnp
    >>> from repro.api import BatchedKMeans
    >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 256, 8))
    >>> bkm = BatchedKMeans(n_clusters=3, max_iter=10).fit(x)
    >>> bkm.cluster_centers_.shape
    (4, 3, 8)
    >>> bkm.predict(x).shape
    (4, 256)
    """

    def __init__(self, n_clusters: int = 8, *, max_iter: int = 100,
                 tol: float = 1e-4, init: str = "kmeans++",
                 backend: Optional[str] = None,
                 params=None,
                 autotune: Optional[AutotuneCache] = None,
                 sync_every: int = 10,
                 compute_dtype="float32",
                 random_state: int = 0):
        if n_clusters < 1:
            raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
        if init not in _INITS:
            raise ValueError(f"init must be one of {_INITS}, got {init!r}")
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        try:
            dtype_ok = jnp.dtype(compute_dtype).name in _COMPUTE_DTYPES
        except TypeError:
            dtype_ok = False
        if not dtype_ok:
            raise ValueError(f"compute_dtype must be one of "
                             f"{_COMPUTE_DTYPES}, got {compute_dtype!r}")
        self.n_clusters = n_clusters
        self.max_iter = max_iter
        self.tol = tol
        self.init = init
        self.backend = backend
        self.params = params
        self.autotune = autotune if autotune is not None else default_cache()
        self.sync_every = sync_every
        self.compute_dtype = jnp.dtype(compute_dtype)
        self.random_state = random_state

        self._backend: AssignmentBackend = self._resolve_backend(backend)
        self._step_cache: dict = {}

        self.cluster_centers_: Optional[jax.Array] = None
        self.labels_: Optional[jax.Array] = None
        self.inertia_: Optional[np.ndarray] = None
        self.n_iter_: Optional[np.ndarray] = None
        self.detected_errors_: int = 0

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    @staticmethod
    def _resolve_backend(name: Optional[str]) -> AssignmentBackend:
        """Pick the batched backend: the Pallas kernel on TPU, the XLA
        analogue elsewhere; an explicit name must declare the batch
        capability (the (B, N, F) contract is not adapter-compatible with
        single-problem backends)."""
        if name is None:
            name = "lloyd_batched" if ops.on_tpu() else "lloyd_batched_xla"
        backend = get_backend(name)
        if not backend.supports_batch:
            raise BackendCapabilityError(
                f"BatchedKMeans needs a supports_batch backend (stacked "
                f"(B, N, F) contract), but {backend.name!r} declares "
                f"supports_batch=False; use 'lloyd_batched' / "
                f"'lloyd_batched_xla' or register a batched backend")
        return backend

    def _check_fitted(self):
        if self.cluster_centers_ is None:
            from repro.api.estimator import NotFittedError
            raise NotFittedError(
                "this BatchedKMeans instance is not fitted yet; call fit() "
                "first")

    def _cast(self, a: jax.Array) -> jax.Array:
        return a if a.dtype == self.compute_dtype else \
            a.astype(self.compute_dtype)

    def _problem_keys(self, bsz: int) -> jax.Array:
        """Per-problem RNG keys: problem ``b`` seeds from
        ``random_state + b`` so its draws are independent of B (the
        batched-vs-loop bit-equality hinges on this)."""
        return jax.vmap(jax.random.PRNGKey)(
            self.random_state + jnp.arange(bsz))

    def _resolve_params(self, bsz: int, n: int, f: int):
        if not self._backend.takes_params:
            return None
        if self.params is not None:
            p = self.params
        else:
            _, p = self.autotune.lookup(n, self.n_clusters, f,
                                        kind=self._backend.kernel_kind,
                                        dtype=self.compute_dtype, batch=bsz)
        return ops.clamp_params(n, self.n_clusters, f, p,
                                dtype=self.compute_dtype)

    def init_centroids(self, x: jax.Array,
                       keys: Optional[jax.Array] = None) -> jax.Array:
        """Per-problem seeding: (B, K, F) from the stacked (B, N, F) data,
        every problem drawing from its own key."""
        from repro.core.kmeans import init_kmeanspp, init_random
        if keys is None:
            keys = self._problem_keys(x.shape[0])
        if self.init == "kmeans++-fused":
            from repro.kernels.kmeanspp_init import init_kmeanspp_fused
            return init_kmeanspp_fused(keys, x, self.n_clusters)
        fn = init_kmeanspp if self.init == "kmeans++" else init_random
        return jax.vmap(fn, in_axes=(0, 0, None))(keys, x, self.n_clusters)

    def _chunk_fn(self, params, n_steps: int):
        """jit'd device-resident chunk of up to ``n_steps`` batched Lloyd
        iterations (see :func:`make_batched_chunk` for the per-problem
        convergence-mask semantics), memoized per (params, n_steps, tol)."""
        cache_key = ("chunk", params, n_steps, self.tol)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        fn = jax.jit(make_batched_chunk(self._backend, params, self._cast,
                                        self.tol, n_steps))
        self._step_cache[cache_key] = fn
        return fn

    # ------------------------------------------------------------------
    # estimator API
    # ------------------------------------------------------------------

    def fit(self, x: jax.Array, *,
            centroids: Optional[jax.Array] = None) -> "BatchedKMeans":
        """Run batched Lloyd iterations to per-problem convergence.

        Parameters
        ----------
        x : jax.Array, shape (B, N, F)
            B stacked problems. Stacking implies every problem shares
            (N, K, F); pad ragged problems to a common N before stacking.
        centroids : jax.Array, shape (B, K, F), optional
            Warm-start stack; default is per-problem ``init`` seeding.

        Returns
        -------
        self : BatchedKMeans
            With ``cluster_centers_``, ``labels_``, ``inertia_``,
            ``n_iter_`` populated (all carrying the leading B axis).
        """
        x = jnp.asarray(x)
        if x.ndim != 3:
            raise ValueError(f"BatchedKMeans.fit wants stacked (B, N, F) "
                             f"problems, got shape {x.shape}; use "
                             f"repro.api.KMeans for one problem")
        bsz, n, f = x.shape
        keys = self._problem_keys(bsz)
        if centroids is None:
            split = jax.vmap(jax.random.split)(keys)       # (B, 2, 2)
            keys, subs = split[:, 0], split[:, 1]
            centroids = self.init_centroids(x, subs)
        centroids = jnp.asarray(centroids, jnp.float32)
        params = self._resolve_params(bsz, n, f)
        # per-fit batch plan: pad + row-norm the whole (B, N, F) block once
        plan = ops.plan_data_batched(self._cast(x), params) \
            if self._backend.takes_params else self._cast(x)

        am = jnp.zeros((bsz, n), jnp.int32)
        inertia = jnp.full((bsz,), jnp.inf, jnp.float32)
        done = jnp.zeros((bsz,), jnp.bool_)
        det = jnp.zeros((), jnp.int32)
        iters = np.zeros((bsz,), np.int64)
        it0 = 0
        while it0 < self.max_iter:
            n_steps = min(self.sync_every, self.max_iter - it0)
            chunk = self._chunk_fn(params, n_steps)
            (centroids, am, inertia, done, det), live_hist = chunk(
                plan, centroids, am, inertia, done, det, keys,
                jnp.int32(it0))
            done_h, live_h = _host_read((done, live_hist))
            iters += live_h.sum(axis=0).astype(np.int64)
            it0 += n_steps
            if bool(done_h.all()):
                break

        self.cluster_centers_ = centroids
        self.labels_ = am
        inertia_h, det_h = _host_read((inertia, det))
        self.inertia_ = np.asarray(inertia_h, np.float64)
        self.n_iter_ = np.maximum(iters, 1)
        self.detected_errors_ = int(det_h)
        return self

    def fit_predict(self, x: jax.Array) -> jax.Array:
        """Fit the B problems and return ``labels_`` (shape (B, N))."""
        return self.fit(x).labels_

    def _assign(self, x: jax.Array):
        bsz, n, f = x.shape
        params = self._resolve_params(bsz, n, f)
        key = ("assign", params)
        if key not in self._step_cache:
            backend = self._backend
            cast = self._cast
            if backend.takes_params:
                fn = jax.jit(lambda x, c: backend(cast(x), cast(c),
                                                  params=params)[:2])
            else:
                fn = jax.jit(lambda x, c: backend(cast(x), cast(c))[:2])
            self._step_cache[key] = fn
        return self._step_cache[key](x, self.cluster_centers_)

    def predict(self, x: jax.Array) -> jax.Array:
        """Per-problem nearest-centroid labels for new stacked data.

        Parameters
        ----------
        x : jax.Array, shape (B, N', F)
            New samples; B must match the fitted problem count.

        Returns
        -------
        labels : jax.Array, shape (B, N'), int32
        """
        self._check_fitted()
        x = jnp.asarray(x)
        if x.ndim != 3 or x.shape[0] != self.cluster_centers_.shape[0]:
            raise ValueError(
                f"predict wants (B, N, F) with B={self.cluster_centers_.shape[0]} "
                f"fitted problems, got shape {x.shape}")
        return self._assign(x)[0]

    def score(self, x: jax.Array) -> np.ndarray:
        """Per-problem negative inertia on ``x`` (sklearn sign convention:
        higher is better). Returns shape (B,)."""
        self._check_fitted()
        _, md = self._assign(jnp.asarray(x))
        return -np.asarray(_host_read(jnp.sum(md, axis=1)), np.float64)

    # ------------------------------------------------------------------
    # serializable state
    # ------------------------------------------------------------------

    def get_state(self) -> dict:
        """Fitted state as a flat dict of plain types + numpy arrays
        (``np.savez`` / JSON+base64 / ``ft.checkpoint`` compatible)."""
        self._check_fitted()
        return {
            "cluster_centers": np.asarray(self.cluster_centers_),
            "n_iter": np.asarray(self.n_iter_),
            "inertia": (None if self.inertia_ is None
                        else np.asarray(self.inertia_)),
            "detected_errors": int(self.detected_errors_),
            "config": {
                "n_clusters": self.n_clusters,
                "max_iter": self.max_iter,
                "tol": self.tol,
                "init": self.init,
                "backend": self.backend,
                "sync_every": self.sync_every,
                "compute_dtype": self.compute_dtype.name,
                "random_state": self.random_state,
                "params": (None if self.params is None else
                           [self.params.block_m, self.params.block_k,
                            self.params.block_f]),
            },
        }

    @classmethod
    def from_state(cls, state: dict, *,
                   autotune: Optional[AutotuneCache] = None
                   ) -> "BatchedKMeans":
        """Reconstruct a fitted estimator from :meth:`get_state` output."""
        cfg = state["config"]
        tiles = cfg.get("params")
        params = None if tiles is None else ops.KernelParams(*tiles)
        bkm = cls(cfg["n_clusters"], max_iter=cfg["max_iter"],
                  tol=cfg["tol"], init=cfg["init"], backend=cfg["backend"],
                  params=params, sync_every=cfg.get("sync_every", 10),
                  compute_dtype=cfg.get("compute_dtype", "float32"),
                  random_state=cfg["random_state"], autotune=autotune)
        bkm.cluster_centers_ = jnp.asarray(state["cluster_centers"])
        bkm.n_iter_ = np.asarray(state["n_iter"])
        inertia = state.get("inertia")
        bkm.inertia_ = None if inertia is None else np.asarray(inertia)
        bkm.detected_errors_ = int(state.get("detected_errors", 0))
        return bkm
