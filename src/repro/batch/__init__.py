"""``repro.batch`` — many-problem K-means over stacked independent problems.

Production traffic is rarely one big clustering problem: serving millions
of users means thousands of *independent small* problems (per-user
embeddings, per-shard codebooks) whose individual kernel launches waste
the MXU. This package runs B problems as one stacked (B, N, F) block
through the batched one-pass Lloyd kernel (problem axis outermost in the
grid — see ``docs/kernels.md``), with per-problem seeds, inits and
convergence masks inside a single ``lax.scan``.

  * :class:`BatchedKMeans` — the stacked-problem estimator
    (``fit`` / ``predict`` / ``score`` / ``get_state`` / ``from_state``);
  * problem-axis sharding — ``repro.dist.DistributedKMeans`` accepts a
    :class:`BatchedKMeans` and shards over B instead of rows
    (embarrassingly parallel: no psum on the hot path).
"""
from repro.batch.estimator import BatchedKMeans

__all__ = ["BatchedKMeans"]
