"""TPU v5e chip constants — the single source of truth.

Both performance models consume these numbers: the autotune selection model
(``repro.core.autotune``) and the roofline analyzer (``repro.roofline.hw``
re-exports this module). Keeping one copy means the two models cannot
drift apart on what the hardware can do.
"""

from typing import Any

PEAK_FLOPS_BF16: float = 197e12  # FLOP/s (MXU peak at 2-byte dtypes)
PEAK_FLOPS_F32: float = PEAK_FLOPS_BF16 / 2
# int8 MXU path: double the bf16 MAC rate (the systolic array packs two
# 1-byte operands per bf16 lane), accumulating in int32.
PEAK_FLOPS_INT8: float = PEAK_FLOPS_BF16 * 2
HBM_BW: float = 819e9           # bytes/s
ICI_LINK_BW: float = 50e9       # bytes/s per link
ICI_LINKS: int = 4              # v5e: 4 ICI links per chip (2D torus x2)
HBM_BYTES: int = 16 * 2**30     # 16 GiB
VMEM_BYTES: int = 128 * 2**20
# Usable VMEM per core for kernel working sets: half of the physical
# 128 MiB, leaving room for Mosaic's own double-buffering scratch.
VMEM_BUDGET: int = 96 * 2**20
# Fixed host-side cost of one kernel launch (runtime dispatch + grid
# setup), independent of the grid. It is invisible next to a multi-ms fit
# step but dominates small online predict cells, which is why the serving
# model (``kind="serve"`` in repro.core.autotune) adds it per launch and
# the micro-batcher exists at all.
DISPATCH_OVERHEAD_S: float = 5e-6


def peak_flops(dtype: Any) -> float:
    """MXU peak for an input dtype. Only bf16 has a native full-rate MXU
    path on v5e; fp16 is upconverted by XLA and runs at ~f32 rate (it
    still halves the HBM/VMEM bytes, which the byte models account for
    separately), f32 is half rate, and int8 doubles the bf16 rate (int32
    accumulation)."""
    import numpy as np
    name = np.dtype(dtype).name
    if name == "int8":
        return PEAK_FLOPS_INT8
    return PEAK_FLOPS_BF16 if name == "bfloat16" else PEAK_FLOPS_F32
