"""Static-analysis gates for the kernel stack (``python -m repro.analysis``).

Three passes, each runnable standalone and wired into CI before the test
job (see docs/analysis.md):

* :mod:`repro.analysis.contracts` — kernel contract checker (VMEM byte
  models vs real BlockSpecs, tile alignment, f32-accumulate rule,
  registry flags vs signatures, FT descriptor slots), all via abstract
  evaluation — no TPU.
* :mod:`repro.analysis.lint` — AST hygiene linter for the hot paths
  (host-sync funnel, jit-in-loop, module-global mutable state,
  hardcoded interpret mode).
* :mod:`repro.analysis.recompile` — recompile gate: warm reruns of the
  estimator hot paths must not trigger new XLA compiles.

Exit codes and the ``--format=github`` annotation style are shared with
``python -m repro.api.registry`` via :mod:`repro.analysis.report`.
"""
from repro.analysis.report import (EXIT_OK, EXIT_USAGE,  # noqa: F401
                                   EXIT_VIOLATIONS, Violation)

PASSES = ("contracts", "lint", "recompile")
