"""Pass 2 — hot-path hygiene linter (custom AST checks over src/repro).

Five rules, each targeting a bug class this repo has actually shipped or
explicitly designs against:

``host-sync``       device->host synchronization outside the designated
                    ``_host_read`` funnel: ``.item()``, ``jax.device_get``
                    anywhere; ``np.asarray`` and ``float()``/``int()`` on
                    bare variables in the hot-path packages (estimator
                    fit/predict paths). Serialization boundaries
                    (``get_state``/``from_state``) and the funnel itself
                    are exempt.
``jit-in-loop``     ``jax.jit``/``jax.pmap`` constructed inside a loop
                    body — a fresh jit wrapper per iteration recompiles
                    every call.
``module-state``    a module-global mutable literal (the PR-1
                    ``_cached_table`` bug class): process-wide hidden
                    state that leaks across estimators and tests.
                    ALL_CAPS names are exempt — constants by repo
                    convention (lookup tables, shape lists).
``interpret-mode``  a hardcoded ``interpret=True`` in library code —
                    interpret mode is a per-call decision owned by
                    ``ops.on_tpu()``, never baked in.
``pytree-state``    a module-level ``*State`` dataclass without a
                    ``register_pytree_node`` registration in the same
                    module. Iteration-carried state (the ``BoundsState``
                    pattern) must flatten/unflatten to ride a
                    ``lax.scan`` carry or a jit boundary; an unregistered
                    state dataclass traces once, then fails (or silently
                    constant-folds) the first time it crosses one.

Suppression: append ``# analysis: allow=<rule>[,<rule>...]`` to the
offending line. Every suppression is visible in the diff and greppable.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterator, Optional, Sequence

from repro.analysis.report import Violation

RULES = ("host-sync", "jit-in-loop", "module-state", "interpret-mode",
         "pytree-state")

_PRAGMA = re.compile(r"#\s*analysis:\s*allow=([\w,-]+)")

# The one sanctioned sync point, plus the serialization boundary where
# host transfer is the entire job.
_FUNNEL_FUNCS = frozenset({"_host_read"})
_HOST_BOUNDARY_FUNCS = frozenset({"_host_read", "get_state", "from_state"})

# Packages whose functions are (or call into) per-iteration hot paths;
# the scalar-read rules (np.asarray / float / int on bare names) apply
# here. ``.item()`` and ``jax.device_get`` are flagged everywhere.
# ``serve`` is the per-*request* hot path — a hidden sync there stalls
# every request sharing the micro-batch, not just one fit iteration.
# ``ft`` holds the recovery ladder: elastic rescale and checkpointing run
# *during* fits, so an unfunneled sync there stalls the surviving workers
# exactly when they can least afford it.
_HOT_PATH_PREFIXES = ("api", "batch", "core", "dist", "ft", "serve")


def _allowed(src: str) -> dict[int, frozenset[str]]:
    """line -> rules suppressed on that line via the pragma comment."""
    out: dict[int, frozenset[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _PRAGMA.search(line)
        if m:
            out[i] = frozenset(r.strip() for r in m.group(1).split(","))
    return out


def _dotted(node: ast.AST) -> str:
    """``jax.device_get`` -> "jax.device_get"; best effort."""
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str, allowed: dict[int, frozenset[str]],
                 hot_path: bool) -> None:
        self.relpath = relpath
        self.allowed = allowed
        self.hot_path = hot_path
        self.func_stack: list[str] = []
        self.loop_depth = 0
        self.violations: list[Violation] = []

    # -- helpers -----------------------------------------------------------

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", None)
        if line is not None and rule in self.allowed.get(line, frozenset()):
            return
        self.violations.append(Violation(
            "lint", rule, file=self.relpath, line=line, message=message))

    def _in_funnel(self) -> bool:
        return any(f in _FUNNEL_FUNCS for f in self.func_stack)

    def _in_host_boundary(self) -> bool:
        return any(f in _HOST_BOUNDARY_FUNCS for f in self.func_stack)

    # -- scopes ------------------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    def _visit_loop(self, node: ast.AST) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = visit_While = visit_AsyncFor = _visit_loop

    # -- rules -------------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        short = name.rsplit(".", 1)[-1]
        # host-sync: .item() and jax.device_get anywhere outside the funnel
        if short == "item" and isinstance(node.func, ast.Attribute) \
                and not node.args and not self._in_funnel():
            self._flag("host-sync", node,
                       ".item() synchronizes device->host; route the value "
                       "through the _host_read funnel")
        if name in ("jax.device_get",) and not self._in_funnel():
            self._flag("host-sync", node,
                       "jax.device_get outside the _host_read funnel; "
                       "every host transfer goes through one audited door")
        # host-sync (hot paths): np.asarray / float / int on device values
        if self.hot_path and not self._in_host_boundary():
            if name in ("np.asarray", "numpy.asarray") and node.args \
                    and isinstance(node.args[0], (ast.Name, ast.Attribute)) \
                    and not (isinstance(node.args[0], ast.Name)
                             and node.args[0].id.endswith(("_h", "_host"))):
                self._flag("host-sync", node,
                           "np.asarray on a (possibly traced) array "
                           "synchronizes; use _host_read (naming the "
                           "result with an _h suffix), or pragma if the "
                           "value is host data")
            # float(v)/int(v) on a bare variable is a hidden sync when v
            # is a device value. Values already read through the funnel
            # carry an _h/_host suffix by convention and are exempt; so
            # is float(_host_read(...)) directly.
            if name in ("float", "int") and len(node.args) == 1 \
                    and isinstance(node.args[0], ast.Name) \
                    and not node.args[0].id.endswith(("_h", "_host")):
                self._flag("host-sync", node,
                           f"{name}() on a bare variable blocks on the "
                           f"device if it is a traced/async value; read it "
                           f"via _host_read first (naming the result with "
                           f"an _h suffix), or pragma a genuine host "
                           f"scalar")
        # jit-in-loop
        if name in ("jax.jit", "jax.pmap") and self.loop_depth > 0:
            self._flag("jit-in-loop", node,
                       f"{name} constructed inside a loop body builds a "
                       f"fresh cache per iteration and recompiles every "
                       f"call; hoist the jit out of the loop")
        # interpret-mode
        for kw in node.keywords:
            if kw.arg == "interpret" \
                    and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is True:
                self._flag("interpret-mode", kw.value,
                           "hardcoded interpret=True in library code; "
                           "interpret mode is decided per call from "
                           "ops.on_tpu()")
        self.generic_visit(node)

    @staticmethod
    def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _dotted(target).rsplit(".", 1)[-1] == "dataclass":
                return True
        return False

    def visit_Module(self, node: ast.Module) -> None:
        # pytree-state: collect every register_pytree_node(SomeClass, ...)
        # in the module, then flag module-level *State dataclasses that
        # lack one. Scoped to the *State naming convention on purpose:
        # plan/param dataclasses (KernelPlan, BufferPlan) are static
        # launch descriptors that never ride a scan carry.
        registered = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and _dotted(sub.func).rsplit(".", 1)[-1] \
                    == "register_pytree_node" and sub.args \
                    and isinstance(sub.args[0], ast.Name):
                registered.add(sub.args[0].id)
        for stmt in node.body:
            if isinstance(stmt, ast.ClassDef) \
                    and stmt.name.endswith("State") \
                    and self._is_dataclass_decorated(stmt) \
                    and stmt.name not in registered:
                self._flag("pytree-state", stmt,
                           f"dataclass {stmt.name!r} looks like iteration-"
                           f"carried state but has no register_pytree_node"
                           f"(...) in this module; unregistered state "
                           f"cannot cross a lax.scan carry or jit "
                           f"boundary (the BoundsState failure mode)")
        for stmt in node.body:
            targets: list[ast.expr] = []
            value: Optional[ast.expr] = None
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            if value is None:
                continue
            mutable = isinstance(value, (ast.Dict, ast.List, ast.Set)) or (
                isinstance(value, ast.Call)
                and _dotted(value.func) in ("dict", "list", "set",
                                            "collections.defaultdict",
                                            "defaultdict"))
            if not mutable:
                continue
            for t in targets:
                # ALL_CAPS module globals are constants by repo convention
                # (lookup tables, shape lists); the bug class this rule
                # exists for (_cached_table) is a lowercase mutable.
                if isinstance(t, ast.Name) \
                        and not t.id.startswith("__") \
                        and not t.id.isupper():
                    self._flag("module-state", stmt,
                               f"module-global mutable {t.id!r}: hidden "
                               f"process-wide state (the _cached_table bug "
                               f"class); make it injectable or pragma a "
                               f"sanctioned registry")
        self.generic_visit(node)


def _py_files(root: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def lint_source(src: str, relpath: str) -> list[Violation]:
    """Lint one file's source text (``relpath`` is for reporting and for
    the hot-path scoping rule)."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation("lint", "parse", file=relpath, line=e.lineno,
                          message=f"file does not parse: {e.msg}")]
    parts = relpath.replace(os.sep, "/").split("/")
    try:
        sub = parts[parts.index("repro") + 1]
    except (ValueError, IndexError):
        sub = parts[0] if parts else ""
    hot = sub in _HOT_PATH_PREFIXES
    v = _Visitor(relpath, _allowed(src), hot)
    v.visit(tree)
    return v.violations


def run(root: Optional[str] = None,
        files: Optional[Sequence[str]] = None) -> list[Violation]:
    """Lint ``src/repro`` under ``root`` (default: this checkout), or an
    explicit file list; empty list = clean."""
    if files is None:
        base = root or os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        tree_root = os.path.join(base, "src", "repro")
        files = list(_py_files(tree_root))
        repo = base
    else:
        repo = root or os.getcwd()
    out: list[Violation] = []
    for path in files:
        rel = os.path.relpath(path, repo) if os.path.isabs(path) else path
        with open(path, encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), rel))
    return out
