"""Pass 3 — recompile gate: no shape-stable hot path may retrace.

``jax.monitoring`` fires ``/jax/core/compile/backend_compile_duration``
once per actual XLA compile; :class:`CompileCounter` listens and the gate
runs each hot-path scenario twice — a cold pass (compiles expected) and a
warm pass (zero new compiles allowed). A change that threads a Python
scalar, a fresh ``jax.jit`` wrapper, or a shape-dependent constant into a
fit/predict path shows up as a nonzero warm count here, before it ships
as a 100x slowdown on device.

Distinct (m, f) *buckets* retracing is by design (the DataPlan carries
static dims); the invariant gated here is that *reuse* — same estimator,
same shapes, new data — never compiles again. Scenarios are injectable
so the test suite can prove the gate fires on a deliberately
recompile-happy fixture.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.analysis.report import Violation

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class CompileCounter:
    """Counts XLA backend compiles via the jax.monitoring listener API.

    The listener registry has no public unregister, so the callback stays
    registered but inert (``enabled`` False) outside ``counting()``.
    """

    def __init__(self) -> None:
        self.count = 0
        self.enabled = False
        self._registered = False

    def _cb(self, event: str, duration: float, **kw: object) -> None:
        if self.enabled and event == _COMPILE_EVENT:
            self.count += 1

    def install(self) -> "CompileCounter":
        if not self._registered:
            jax.monitoring.register_event_duration_secs_listener(self._cb)
            self._registered = True
        return self

    def counting(self) -> "_Counting":
        return _Counting(self)


class _Counting:
    """Context manager: enable the counter, report compiles seen."""

    def __init__(self, counter: CompileCounter) -> None:
        self._counter = counter
        self._start = 0

    def __enter__(self) -> "_Counting":
        self._counter.install()
        self._counter.enabled = True
        self._start = self._counter.count
        return self

    def __exit__(self, *exc: object) -> None:
        self._counter.enabled = False

    @property
    def compiles(self) -> int:
        return self._counter.count - self._start


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One hot path: ``make()`` returns a step thunk; the gate runs it
    once cold, then asserts the warm rerun stays within ``warm_budget``
    (0 = fully cached) new compiles."""

    name: str
    make: Callable[[], Callable[[], None]]
    warm_budget: int = 0
    file: Optional[str] = None


def _fit_predict_scenario() -> Callable[[], None]:
    from repro.api.estimator import KMeans
    rng = np.random.default_rng(0)
    est = KMeans(n_clusters=8, max_iter=3, backend="lloyd_xla",
                 sync_every=1, random_state=0)
    xs = [np.asarray(rng.normal(size=(384, 16)), np.float32)
          for _ in range(2)]
    state = {"i": 0}

    def step() -> None:
        x = xs[state["i"] % len(xs)]   # new data, same shape, every pass
        state["i"] += 1
        est.fit(x)
        est.predict(x)
        est.predict(x)                 # immediate predict reuse
    return step


def _chunked_predict_scenario() -> Callable[[], None]:
    from repro.api.estimator import KMeans
    rng = np.random.default_rng(1)
    est = KMeans(n_clusters=4, max_iter=2, backend="lloyd_xla",
                 sync_every=1, predict_chunk_rows=128, random_state=0)
    x = np.asarray(rng.normal(size=(256, 8)), np.float32)
    est.fit(x)
    q = np.asarray(rng.normal(size=(300, 8)), np.float32)

    def step() -> None:
        est.predict(q)   # 300 rows / 128-row chunks: tail chunk included
    return step


def _batched_fit_scenario() -> Callable[[], None]:
    from repro.batch.estimator import BatchedKMeans
    rng = np.random.default_rng(2)
    est = BatchedKMeans(n_clusters=4, max_iter=3, backend="lloyd_batched_xla",
                        sync_every=1, random_state=0)
    xs = [np.asarray(rng.normal(size=(4, 128, 8)), np.float32)
          for _ in range(2)]
    state = {"i": 0}

    def step() -> None:
        est.fit(xs[state["i"] % len(xs)])
        state["i"] += 1
    return step


def _serve_predict_scenario() -> Callable[[], None]:
    """The serving layer's core claim: with every (bucket, variant) cell
    AOT-compiled at construction (in ``make()``, outside the counted
    passes), dispatching the whole registered set — zero-row, one-row,
    exact-bucket, mid-bucket and beyond-top-bucket requests, plus a
    publish-then-serve hot-swap — compiles nothing. Not just warm: the
    *cold* pass may only compile the eager pad/concat glue, and the warm
    pass must be at zero like every other shape-stable path."""
    from repro.api.estimator import KMeans
    rng = np.random.default_rng(3)
    x = np.asarray(rng.normal(size=(256, 16)), np.float32)
    est = KMeans(n_clusters=4, max_iter=2, backend="lloyd_xla",
                 sync_every=1, random_state=0)
    est.fit(x)
    svc = est.to_service(buckets=(32, 128), window_s=0.0)
    queries = [np.asarray(rng.normal(size=(m, 16)), np.float32)
               for m in (0, 1, 32, 100, 300)]
    state = {"i": 0}

    def step() -> None:
        for q in queries:
            svc.predict(q)
        # hot-swap mid-traffic: a publish must reuse the same executables
        svc.publish(np.asarray(est.cluster_centers_) + 0.5 * state["i"])
        state["i"] += 1
        svc.predict(queries[-1])
    return step


def default_scenarios() -> list[Scenario]:
    return [
        Scenario("kmeans-fit-predict-warm", _fit_predict_scenario,
                 file="src/repro/api/estimator.py"),
        Scenario("kmeans-chunked-predict-warm", _chunked_predict_scenario,
                 file="src/repro/api/estimator.py"),
        Scenario("batched-fit-warm", _batched_fit_scenario,
                 file="src/repro/batch/estimator.py"),
        Scenario("serve-aot-predict-warm", _serve_predict_scenario,
                 file="src/repro/serve/compiler.py"),
    ]


def run(scenarios: Optional[Sequence[Scenario]] = None,
        counter: Optional[CompileCounter] = None) -> List[Violation]:
    """Run every scenario cold then warm; empty list = clean."""
    out: List[Violation] = []
    ctr = counter if counter is not None else CompileCounter()
    for sc in scenarios if scenarios is not None else default_scenarios():
        step = sc.make()
        with ctr.counting() as cold:
            step()
        cold_compiles = cold.compiles
        with ctr.counting() as warm:
            step()
        if warm.compiles > sc.warm_budget:
            out.append(Violation(
                "recompile", "shape-stable-retrace", file=sc.file,
                message=f"scenario {sc.name!r}: warm rerun triggered "
                        f"{warm.compiles} compile(s) (budget "
                        f"{sc.warm_budget}; cold pass compiled "
                        f"{cold_compiles}) — a shape-stable hot path is "
                        f"retracing"))
    return out
