"""Pass 1 — kernel contract checker (no TPU, no compile, no real compute).

The Pallas kernels' correctness rests on contracts that nothing used to
enforce: VMEM byte models must match the BlockSpecs the kernels actually
launch with, tiles must respect dtype-aware sublane alignment, 16-bit
inputs must still produce f32 accumulators/outputs, registry capability
flags must match the real callables, and the FT backends' declared
``protected_intervals`` must match the injection-descriptor slots the
kernels implement. Each is checked here statically:

``vmem-model``      declared model vs the jaxpr-implied footprint
                    (:func:`repro.kernels.ops.kernel_plan`), within a
                    small tolerance, and under the ``repro.hw`` budget
``tile-align``      autotune winners respect ``sublane_align(dtype)``
                    and the 128-lane tile rule
``f32-accumulate``  16-bit inputs yield f32 distance/sums/counts and
                    i32 assignment outputs (via ``jax.eval_shape``); the
                    int8 template's traced plan carries an int32
                    accumulator and emits only f32/i32
``flags``           capability flags vs ``inspect.signature`` and the
                    abstract-eval output arity/batch axis
``intervals``       ``protected_intervals``/``kernel_kind`` vs the FT
                    kernels' ``INJ_SLOTS`` and ``autotune.KINDS``
``dist-ft``         the distribution/recovery layer: int8 transport
                    shape/dtype invariants (abstract, ragged tails
                    included), ReducePlan/FaultPolicy enum hygiene, and
                    ``worker_loss="shrink"`` resolving to real
                    ``ft.elastic`` entry points

Every input is injectable (``backends=``, ``vmem_models=``,
``descriptor_slots=``) so the test suite can prove each rule fires on a
deliberately broken fixture without mutating the global registry.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import hw
from repro.analysis.report import Violation
from repro.core import autotune
from repro.kernels import distance_argmin_ft as _daft
from repro.kernels import lloyd_step_ft as _llft
from repro.kernels import ops

# Representative (m, k, f) grid: a small-K shape (smallk template), a
# multi-tile generic shape, and a large-M bucket. Kept small — each cell
# is a handful of abstract traces.
DEFAULT_SHAPES: tuple[tuple[int, int, int], ...] = (
    (1024, 16, 256), (2048, 256, 512), (65536, 64, 256))
DEFAULT_DTYPES: tuple[str, ...] = ("float32", "bfloat16", "float16")

# Declared-vs-implied tolerance: the models are working-set *estimates*
# (lloyd_ft's model folds the tiny det/checksum blocks into its sums
# term), but a wrong itemsize or a forgotten stash buffer is a >=30%
# miss — far outside this band.
VMEM_RTOL = 0.02
VMEM_ATOL = 64 * 1024

VmemModel = Callable[[ops.KernelParams, int, int, Any], int]


def _default_vmem_models() -> dict[str, VmemModel]:
    """kind -> declared byte model, the registry's documented convention."""
    return {
        "assign": lambda p, k, f, dt: p.vmem_bytes(dt),
        "lloyd": ops.lloyd_vmem_bytes,
        "lloyd_ft": ops.lloyd_ft_vmem_bytes,
        "batched": ops.lloyd_batched_vmem_bytes,
        "pruned": ops.pruned_vmem_bytes,
        "int8": lambda p, k, f, dt: ops.int8_vmem_bytes(p),
        "init": lambda p, k, f, dt: ops.init_vmem_bytes(p, f),
        # a serve predict cell is the assignment kernel at a bucket shape
        "serve": lambda p, k, f, dt: p.vmem_bytes(dt),
    }


def _default_descriptor_slots() -> dict[str, int]:
    """kind -> injection-descriptor slots the FT kernels implement."""
    return {"assign": _daft.INJ_SLOTS, "lloyd_ft": _llft.INJ_SLOTS}


def check_vmem_models(
    shapes: Sequence[tuple[int, int, int]] = DEFAULT_SHAPES,
    dtypes: Sequence[str] = DEFAULT_DTYPES,
    *,
    vmem_models: Optional[Mapping[str, VmemModel]] = None,
    plan_fn: Callable[..., ops.KernelPlan] = ops.kernel_plan,
) -> list[Violation]:
    """``vmem-model`` + ``tile-align``: for every (kind, dtype, shape)
    cell, select the autotune winner, trace the kernel's real plan, and
    verify the declared byte model against the implied footprint and the
    hardware budget; verify the winner's tiles are dtype-legal."""
    models = dict(vmem_models) if vmem_models is not None \
        else _default_vmem_models()
    out: list[Violation] = []
    src = "src/repro/kernels/ops.py"
    for kind in ops.PLAN_KINDS:
        model = models.get(kind)
        if model is None:
            out.append(Violation(
                "contracts", "vmem-model", file=src,
                message=f"kernel kind {kind!r} has no declared VMEM model"))
            continue
        # Per-kind dtype set: the f32 template family is checked at every
        # requested dtype; a fixed-dtype kind (int8 tiles are int8 by
        # construction) falls back to its own dtypes when the requested
        # ones don't apply, so passing ("float32",) still covers it.
        allowed = ops.PLAN_KIND_DTYPES.get(kind, tuple(dtypes))
        kind_dtypes = [d for d in dtypes if d in allowed] or list(allowed)
        for dtype in kind_dtypes:
            dt = jnp.dtype(dtype)
            for (m, k, f) in shapes:
                batch = 8 if kind == "batched" else 1
                _, p = autotune.select_params(m, k, f, mode="model",
                                              dtype=dt, kind=kind,
                                              batch=batch)
                p = ops.clamp_params(m, k, f, p, dtype=dt)
                cell = (f"kind={kind} dtype={dtype} shape={(m, k, f)} "
                        f"tiles=({p.block_m},{p.block_k},{p.block_f})")
                align = ops.sublane_align(dt)
                if (p.block_m % align or p.block_k % 128
                        or p.block_f % 128):
                    out.append(Violation(
                        "contracts", "tile-align", file=src,
                        message=f"winner tiles break alignment (block_m "
                                f"% {align} / 128-lane rule): {cell}"))
                declared = int(model(p, k, f, dt))
                plan = plan_fn(kind, m, k, f, p, dtype=dt, batch=batch)
                implied = plan.vmem_bytes()
                if kind == "int8":
                    # i32-accumulate mirror of the f32-under-16-bit rule:
                    # i8 x i8 tile products overflow anything narrower, so
                    # the traced plan must carry an int32 VMEM accumulator
                    # and emit only f32/i32 outputs.
                    if not any(b.dtype == "int32" for b in plan.scratch):
                        out.append(Violation(
                            "contracts", "f32-accumulate", file=src,
                            message=f"int8 template must accumulate in an "
                                    f"int32 VMEM scratch; traced scratch "
                                    f"dtypes "
                                    f"{[b.dtype for b in plan.scratch]}: "
                                    f"{cell}"))
                    bad = [b.dtype for b in plan.outputs
                           if b.dtype not in ("float32", "int32")]
                    if bad:
                        out.append(Violation(
                            "contracts", "f32-accumulate", file=src,
                            message=f"int8 template must emit f32/i32 "
                                    f"outputs, got {bad}: {cell}"))
                tol = max(VMEM_ATOL, int(VMEM_RTOL * implied))
                if abs(declared - implied) > tol:
                    out.append(Violation(
                        "contracts", "vmem-model", file=src,
                        message=f"declared VMEM model ({declared} B) "
                                f"disagrees with the BlockSpec-implied "
                                f"footprint ({implied} B, tol {tol} B): "
                                f"{cell}"))
                if max(declared, implied) > hw.VMEM_BUDGET:
                    out.append(Violation(
                        "contracts", "vmem-model", file=src,
                        message=f"working set exceeds the "
                                f"hw.VMEM_BUDGET ({hw.VMEM_BUDGET} B): "
                                f"declared={declared} implied={implied} "
                                f"{cell}"))
    return out


def _abstract_outputs(backend: Any, m: int, k: int, f: int,
                      dtype: Any) -> tuple[Any, ...]:
    """Abstractly evaluate the backend's uniform call on (m, k, f)."""
    dt = jnp.dtype(dtype)
    if backend.supports_batch:
        xs = jax.ShapeDtypeStruct((4, m, f), dt)
        cs = jax.ShapeDtypeStruct((4, k, f), dt)
    else:
        xs = jax.ShapeDtypeStruct((m, f), dt)
        cs = jax.ShapeDtypeStruct((k, f), dt)
    params = None
    if backend.takes_params:
        params = ops.clamp_params(m, k, f, ops.DEFAULT_PARAMS, dtype=dt)
    out = jax.eval_shape(lambda x, c: backend(x, c, params=params), xs, cs)
    return tuple(out) if isinstance(out, (tuple, list)) else (out,)


def check_backend_contracts(
    backends: Optional[Mapping[str, Any]] = None,
    *,
    descriptor_slots: Optional[Mapping[str, int]] = None,
    shape: tuple[int, int, int] = (1024, 16, 256),
    dtypes: Sequence[str] = DEFAULT_DTYPES,
) -> list[Violation]:
    """``flags`` + ``f32-accumulate`` + ``intervals``: registry metadata
    vs the real callables, via ``inspect`` and ``jax.eval_shape``."""
    if backends is None:
        from repro.api.registry import list_backends
        backends = list_backends()
    slots = dict(descriptor_slots) if descriptor_slots is not None \
        else _default_descriptor_slots()
    out: list[Violation] = []
    src = "src/repro/core/assignment.py"
    m, k, f = shape
    for name in sorted(backends):
        b = backends[name]
        contract = b.contract()
        fn = inspect.unwrap(getattr(b.fn, "__wrapped__", b.fn))
        try:
            sig_params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            sig_params = {}
        for flag, pname in (("takes_params", "params"),
                            ("takes_injection", "inj"),
                            ("supports_bounds", "bounds")):
            if contract["flags"][flag] != (pname in sig_params):
                out.append(Violation(
                    "contracts", "flags", file=src,
                    message=f"backend {name!r} declares {flag}="
                            f"{contract['flags'][flag]} but its callable "
                            f"{'lacks' if contract['flags'][flag] else 'has'}"
                            f" a {pname!r} parameter"))
                continue
        if contract["kernel_kind"] not in autotune.KINDS:
            out.append(Violation(
                "contracts", "intervals", file=src,
                message=f"backend {name!r} derives kernel_kind="
                        f"{contract['kernel_kind']!r}, not an autotune "
                        f"kind {autotune.KINDS}"))
        if b.takes_injection:
            expect = slots.get(contract["kernel_kind"])
            if expect is None or contract["protected_intervals"] != expect:
                out.append(Violation(
                    "contracts", "intervals", file=src,
                    message=f"backend {name!r} declares "
                            f"{contract['protected_intervals']} protected "
                            f"intervals but its {contract['kernel_kind']!r} "
                            f"kernel implements {expect} injection-"
                            f"descriptor slot(s) (INJ_SLOTS)"))
        for dtype in dtypes:
            try:
                outs = _abstract_outputs(b, m, k, f, dtype)
            except Exception as e:  # pragma: no cover - trace failure
                out.append(Violation(
                    "contracts", "flags", file=src,
                    message=f"backend {name!r} failed abstract evaluation "
                            f"at dtype={dtype}: {e}"))
                continue
            if len(outs) != contract["expected_arity"]:
                out.append(Violation(
                    "contracts", "flags", file=src,
                    message=f"backend {name!r} returns {len(outs)} values "
                            f"but fuses_update={b.fuses_update} implies "
                            f"{contract['expected_arity']}"))
                continue
            am, md, det = outs[0], outs[1], outs[2]
            lead = (4,) if b.supports_batch else ()
            if tuple(am.shape) != lead + (m,):
                out.append(Violation(
                    "contracts", "flags", file=src,
                    message=f"backend {name!r} (supports_batch="
                            f"{b.supports_batch}) returned assignment "
                            f"shape {tuple(am.shape)}, expected "
                            f"{lead + (m,)}"))
            if jnp.dtype(am.dtype) != jnp.int32 \
                    or jnp.dtype(det.dtype) != jnp.int32:
                out.append(Violation(
                    "contracts", "f32-accumulate", file=src,
                    message=f"backend {name!r} must return i32 assignment "
                            f"and detected-count (got {am.dtype}/"
                            f"{det.dtype})"))
            if b.takes_params and jnp.dtype(dtype).itemsize <= 2:
                # outs[3:5] are the fused sums/counts; a bounds-carrying
                # backend's trailing (BoundsState, prune_frac) pair is a
                # pytree + scalar, not an accumulator stream
                bad = [o for o in (md,) + tuple(outs[3:5])
                       if jnp.dtype(o.dtype) != jnp.float32]
                if bad:
                    out.append(Violation(
                        "contracts", "f32-accumulate", file=src,
                        message=f"backend {name!r} at dtype={dtype} "
                                f"returned {[str(o.dtype) for o in bad]} "
                                f"outputs; 16-bit kernel tiles must "
                                f"accumulate and emit f32"))
    return out


def check_dist_ft_contracts(
    *,
    compression_mod: Any = None,
    reduce_mod: Any = None,
    policy_cls: Any = None,
    elastic_mod: Any = None,
) -> list[Violation]:
    """``dist-ft``: the distribution/recovery layer's static contracts.

    The compressed reduce and the elastic restart are correct only if
    three interfaces agree without ever running a fit: the int8 transport
    must preserve shapes/dtypes abstractly (quantize emits int8 payload +
    f32 per-block scales, dequantize round-trips the original shape, ragged
    tails included), the :class:`~repro.dist.reduce.ReducePlan` /
    :class:`~repro.api.FaultPolicy` enums must reject unknown routes, and
    every policy value that *promises* a recovery path must resolve to
    real code (``worker_loss="shrink"`` -> ``ft.elastic`` entry points).
    """
    if compression_mod is None:
        from repro.dist import compression as compression_mod
    if reduce_mod is None:
        from repro.dist import reduce as reduce_mod
    if policy_cls is None:
        from repro.api import FaultPolicy as policy_cls
    if elastic_mod is None:
        from repro.ft import elastic as elastic_mod
    out: list[Violation] = []
    src = "src/repro/dist/compression.py"
    # int8 transport invariants, abstractly, at an aligned and a ragged n
    for n in (256, 100):
        x = jax.ShapeDtypeStruct((16, n), jnp.float32)
        try:
            q, scale = jax.eval_shape(compression_mod.quantize, x)
            deq = jax.eval_shape(
                lambda qq, ss: compression_mod.dequantize(qq, ss, n),
                q, scale)
        except Exception as e:  # pragma: no cover - trace failure
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"int8 transport failed abstract eval at n={n}: "
                        f"{e}"))
            continue
        if jnp.dtype(q.dtype) != jnp.int8 \
                or jnp.dtype(scale.dtype) != jnp.float32:
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"quantize must emit int8 payload + f32 scales, "
                        f"got {q.dtype}/{scale.dtype} at n={n}"))
        if q.shape[:-1] != scale.shape[:-1] or scale.shape[-1] != 1:
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"per-block scales must broadcast over the "
                        f"payload blocks: q={q.shape} scale={scale.shape}"))
        if tuple(deq.shape) != tuple(x.shape) \
                or jnp.dtype(deq.dtype) != jnp.float32:
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"dequantize must round-trip shape/dtype "
                        f"{x.shape}/f32, got {deq.shape}/{deq.dtype} "
                        f"(ragged tail n={n})"))
    src = "src/repro/dist/reduce.py"
    try:
        reduce_mod.ReducePlan(cross_host="fp4")
        out.append(Violation(
            "contracts", "dist-ft", file=src,
            message="ReducePlan accepted an unknown cross_host transport"))
    except ValueError:
        pass
    if reduce_mod.ReducePlan.compressed(exact=True).cross_host != "exact":
        out.append(Violation(
            "contracts", "dist-ft", file=src,
            message="ReducePlan.compressed(exact=True) must be the exact "
                    "escape hatch"))
    src = "src/repro/api/policy.py"
    from repro.api import policy as _policy_mod
    for value in _policy_mod.WORKER_LOSS:
        try:
            policy_cls(worker_loss=value)
        except ValueError:
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"FaultPolicy rejects documented worker_loss="
                        f"{value!r}"))
    try:
        policy_cls(worker_loss="migrate")
        out.append(Violation(
            "contracts", "dist-ft", file=src,
            message="FaultPolicy accepted an unknown worker_loss mode"))
    except ValueError:
        pass
    # "shrink" promises the fail-stop rung: the entry points must exist
    for name in ("plan_rescale_rows", "WorkerLossError", "FailureSchedule"):
        if not hasattr(elastic_mod, name):
            out.append(Violation(
                "contracts", "dist-ft", file=src,
                message=f"worker_loss='shrink' routes to ft.elastic."
                        f"{name}, which does not exist"))
    return out


def run(shapes: Sequence[tuple[int, int, int]] = DEFAULT_SHAPES,
        dtypes: Sequence[str] = DEFAULT_DTYPES,
        *,
        backends: Optional[Mapping[str, Any]] = None,
        vmem_models: Optional[Mapping[str, VmemModel]] = None,
        descriptor_slots: Optional[Mapping[str, int]] = None,
        ) -> list[Violation]:
    """Run the whole contract pass; empty list = clean."""
    out = check_vmem_models(shapes, dtypes, vmem_models=vmem_models)
    out += check_backend_contracts(backends, dtypes=dtypes,
                                   descriptor_slots=descriptor_slots)
    out += check_dist_ft_contracts()
    return out
