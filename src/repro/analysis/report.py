"""Shared violation reporting for the static-analysis gates.

Both ``python -m repro.analysis`` and ``python -m repro.api.registry``
speak this vocabulary so CI wiring is uniform:

exit codes
    ``EXIT_OK`` (0)          every check passed
    ``EXIT_VIOLATIONS`` (1)  at least one violation (or a stale file)
    ``EXIT_USAGE`` (2)       bad invocation (argparse's own convention)

formats
    ``text``    ``[pass/rule] file:line: message`` — human/grep friendly
    ``github``  GitHub Actions workflow commands (``::error file=...``)
                so CI failures annotate the offending file/line in the PR
"""
from __future__ import annotations

import dataclasses
import sys
from typing import IO, Optional, Sequence

EXIT_OK = 0
EXIT_VIOLATIONS = 1
EXIT_USAGE = 2

FORMATS = ("text", "github")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding from one analysis pass.

    pass_name: which gate fired ("contracts" | "lint" | "recompile" |
               "docs"); rule: the short machine-readable rule id within
               that pass (e.g. "vmem-model", "host-sync").
    """

    pass_name: str
    rule: str
    message: str
    file: Optional[str] = None
    line: Optional[int] = None

    def render(self, fmt: str = "text") -> str:
        if fmt not in FORMATS:
            raise ValueError(f"format must be one of {FORMATS}, got {fmt!r}")
        if fmt == "github":
            loc = ""
            if self.file:
                loc = f" file={self.file}"
                if self.line is not None:
                    loc += f",line={self.line}"
            sep = "," if loc else " "
            return (f"::error{loc}{sep}title={self.pass_name}/{self.rule}::"
                    f"{self.message}")
        where = ""
        if self.file:
            where = (f"{self.file}:{self.line}: " if self.line is not None
                     else f"{self.file}: ")
        return f"[{self.pass_name}/{self.rule}] {where}{self.message}"


def emit(violations: Sequence[Violation], fmt: str = "text",
         stream: Optional[IO[str]] = None) -> int:
    """Print every violation and return the matching exit code."""
    out = stream if stream is not None else sys.stderr
    for v in violations:
        print(v.render(fmt), file=out)
    return EXIT_VIOLATIONS if violations else EXIT_OK
