"""Driver: ``python -m repro.analysis [--check] [--pass NAME] ...``.

``--check`` runs every pass (the CI gate); ``--pass`` narrows to one.
Exit codes (shared with ``python -m repro.api.registry``): 0 clean,
1 violations, 2 usage error. ``--format=github`` emits workflow-command
annotations pointing at the offending file/line.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

from repro.analysis import PASSES, report


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis gates: kernel contracts, hot-path "
                    "hygiene lint, recompile gate")
    ap.add_argument("--check", action="store_true",
                    help="run every pass and exit 1 on any violation "
                         "(the CI gate; also the default action)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASSES, metavar="NAME",
                    help=f"run only this pass (repeatable); one of "
                         f"{', '.join(PASSES)}")
    ap.add_argument("--format", choices=report.FORMATS, default="text",
                    help="violation output style (github = workflow "
                         "annotations)")
    ap.add_argument("--root", default=None,
                    help="repository root for the lint pass (default: "
                         "this checkout)")
    args = ap.parse_args(argv)
    selected = tuple(args.passes) if args.passes else PASSES

    violations: list[report.Violation] = []
    for name in selected:
        if name == "contracts":
            from repro.analysis import contracts
            found = contracts.run()
        elif name == "lint":
            from repro.analysis import lint
            found = lint.run(root=args.root)
        else:
            from repro.analysis import recompile
            found = recompile.run()
        print(f"[repro.analysis] {name}: "
              f"{len(found) or 'no'} violation(s)")
        violations.extend(found)
    code = report.emit(violations, fmt=args.format)
    if code == report.EXIT_OK:
        print(f"[repro.analysis] all {len(selected)} pass(es) clean")
    return code


if __name__ == "__main__":
    sys.exit(main())
