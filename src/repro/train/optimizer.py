"""AdamW with WSD / cosine schedules, sharded optimizer state.

WSD (warmup-stable-decay) is MiniCPM's schedule (arXiv:2404.06395): linear
warmup -> constant plateau -> short sharp decay; selected per arch via
configs.train_schedule. Optimizer state dtype is configurable —
``opt_state_dtype='bfloat16'`` halves the ZeRO-3 footprint for the 400B
config (DESIGN.md §5 memory budget).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # cosine | wsd | constant
    wsd_decay_frac: float = 0.1
    min_lr_frac: float = 0.1
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"
    compress_cross_pod: bool = False   # int8 gradient compression knob
    grad_accum: int = 1                # microbatches per step (activation cap)
    accum_dtype: str = "float32"       # grad-accumulation buffer dtype;
                                       # bf16 halves the buffer + grad
                                       # reduce traffic (400B configs)


def lr_at(cfg: TrainConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    peak = cfg.learning_rate
    if cfg.schedule == "constant":
        return peak * warm
    if cfg.schedule == "wsd":
        decay_steps = max(int(cfg.total_steps * cfg.wsd_decay_frac), 1)
        decay_start = cfg.total_steps - decay_steps
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        stable = 1.0 - (1.0 - cfg.min_lr_frac) * frac
        return peak * warm * stable
    # cosine
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return peak * warm * cos


def init_opt_state(params, cfg: TrainConfig):
    dt = jnp.dtype(cfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params_sds, cfg: TrainConfig):
    dt = jnp.dtype(cfg.opt_state_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(sds, params_sds),
        "v": jax.tree_util.tree_map(sds, params_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state, cfg: TrainConfig):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    dt = jnp.dtype(cfg.opt_state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
