"""Step builders: mesh-aware train_step and serve (prefill/decode) steps.

These produce the exact jit'd callables the launchers AND the dry-run use —
one code path from the CPU smoke tests to the 512-chip AOT compile.

Sharding: parameters/optimizer state get name-based specs
(dist/sharding.py); batch inputs shard over the data axes; decode caches
get rank/shape-based specs (kv-heads over 'model' when divisible, else
head_dim — GQA caches with few KV heads still shard).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models.model import LM
from repro.train import optimizer as opt_mod
from repro.ft import abft_dense


def _batch_sharding(mesh: Mesh, spec_dict: dict) -> dict:
    daxes = shd.data_axes(mesh)
    row = daxes if len(daxes) > 1 else daxes[0]

    def attach(sds):
        if sds.ndim == 0:
            sh = NamedSharding(mesh, P())
        elif sds.shape[0] == 1:     # unshardable batch of 1 (long_500k)
            sh = NamedSharding(mesh, P(*([None] * sds.ndim)))
        else:
            sh = NamedSharding(mesh, P(row, *([None] * (sds.ndim - 1))))
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    return {k: attach(v) for k, v in spec_dict.items()}


def _cache_shardings(cfg: ArchConfig, mesh: Mesh, caches):
    """Rank/shape/name-based cache sharding (see module docstring).

    Leaves (optionally stacked with a leading 'layers' dim from the period
    scan):
      kv cache k/v  (B, Len, KV, hd) -> batch over data; KV over 'model'
                    when divisible, else hd (GQA with few KV heads)
      kv positions  (Len,)           -> replicated
      ssm state     (B, H, P, N)     -> batch over data; H over 'model'
      conv carry    (B, W-1, C)      -> batch; C over 'model'
      rglru state   (B, W)           -> batch; W over 'model'
      encoder_out   (B, S, D)        -> batch only
    """
    model_n = mesh.shape.get("model", 1)
    daxes = shd.data_axes(mesh)
    row = daxes if len(daxes) > 1 else daxes[0]
    dp = 1
    for a in (row if isinstance(row, tuple) else (row,)):
        dp *= mesh.shape[a]

    def attach(path, leaf):
        shape = leaf.shape
        names = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", "")))) for p in path)
        spec = [None] * len(shape)
        stacked = "periods" in names
        off = 1 if stacked else 0
        rank = len(shape) - off
        is_pos = leaf.dtype == jnp.int32 and rank == 1
        if not is_pos and rank >= 2:
            if shape[off] % dp == 0 and shape[off] >= dp:
                spec[off] = row                      # batch dim
            if "ssm/state" in names or ("ssm" in names and rank == 4
                                        and "conv" not in names):
                if shape[off + 1] % model_n == 0:
                    spec[off + 1] = "model"          # SSD heads
            elif rank == 4:                           # kv cache (B,L,KV,hd)
                # Sequence-sharded cache: the decode contraction over L
                # reduce-scatters tiny (B,H,hd) partials instead of
                # all-reducing f32 score tensors (hd-sharded caches) or
                # replicating 500k-token caches (unshardable KV heads).
                if shape[off + 1] % model_n == 0:
                    spec[off + 1] = "model"
                elif shape[off + 2] % model_n == 0:
                    spec[off + 2] = "model"
            elif rank in (2, 3) and "encoder_out" not in names:
                if shape[-1] % model_n == 0:
                    spec[-1] = "model"               # conv/rglru channels
        sh = NamedSharding(mesh, P(*spec))
        return jax.ShapeDtypeStruct(shape, leaf.dtype, sharding=sh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = [attach(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class StepBundle:
    """Everything a launcher (or the dry-run) needs for one cell."""
    lm: LM
    step_fn: Any                  # jit'd callable
    arg_specs: tuple              # ShapeDtypeStructs with shardings
    kind: str


def abstract_train_state(cfg: ArchConfig, mesh: Mesh,
                         tcfg: opt_mod.TrainConfig):
    lm = LM(cfg)
    params_sds, axes = lm.abstract_params()
    params_sh = shd.shard_params(mesh, params_sds, axes)
    opt_sds = opt_mod.abstract_opt_state(params_sds, tcfg)
    opt_sh = {
        "m": shd.shard_params(mesh, opt_sds["m"], axes),
        "v": shd.shard_params(mesh, opt_sds["v"], axes),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P())),
    }
    return lm, params_sh, opt_sh, axes


def default_grad_accum(shape: ShapeConfig) -> int:
    """Bound per-microbatch activations: the full 256 x 4k global batch
    stores ~num_layers full-sequence residuals under remat-scan; 4-way
    accumulation divides that by 4 at <1% step overhead (one extra
    grad buffer, amortized weight all-gathers)."""
    if shape.global_batch >= 64:
        return 4
    return 1


def build_train_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig,
                     tcfg: Optional[opt_mod.TrainConfig] = None,
                     *, donate: bool = True) -> StepBundle:
    from repro.configs.base import input_specs
    tcfg = tcfg or opt_mod.TrainConfig(
        opt_state_dtype=cfg.opt_state_dtype,
        grad_accum=cfg.grad_accum_override or default_grad_accum(shape),
        # bf16 moments imply the config accepts reduced-precision optimizer
        # paths; extend it to the accumulation buffer (halves temp + grad
        # reduce bytes on the 400B config — §Perf llama4 iteration 3).
        accum_dtype=cfg.opt_state_dtype)
    lm, params_sh, opt_sh, axes = abstract_train_state(cfg, mesh, tcfg)
    batch_sh = _batch_sharding(mesh, input_specs(cfg, shape))
    accum = max(tcfg.grad_accum, 1)

    def loss_fn(p, b):
        return lm.loss(p, b)

    def train_step(params, opt_state, batch):
        abft_dense.configure(cfg.abft)
        shd.set_active_mesh(mesh)
        try:
            if accum > 1:
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape(
                        (accum, x.shape[0] // accum) + x.shape[1:]), batch)

                def mb_body(carry, mbatch):
                    gsum, lsum = carry
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    gsum = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(a.dtype), gsum, grads)
                    return (gsum, lsum + loss), metrics

                # Accumulator MUST carry the param sharding: an unsharded
                # zeros tree makes SPMD all-reduce full f32 expert grads
                # (6.5 TB/device on the 400B config) instead of
                # reduce-scattering into the ZeRO-3 layout.
                acc_dt = jnp.dtype(tcfg.accum_dtype)
                gzero = jax.tree_util.tree_map(
                    lambda p, sds: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, acc_dt), sds.sharding),
                    params, params_sh)
                (grads, loss), metrics = jax.lax.scan(
                    mb_body, (gzero, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
                loss = loss / accum
                metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            new_params, new_opt, ometrics = opt_mod.adamw_update(
                params, grads, opt_state, tcfg)
            metrics = dict(metrics, loss=loss, **ometrics)
            return new_params, new_opt, metrics
        finally:
            shd.set_active_mesh(None)

    fn = jax.jit(train_step,
                 donate_argnums=(0, 1) if donate else ())
    return StepBundle(lm, fn, (params_sh, opt_sh, batch_sh), "train")


def build_serve_steps(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig) -> StepBundle:
    """Prefill bundle for 'prefill' cells; decode bundle for 'decode'."""
    from repro.configs.base import input_specs
    lm = LM(cfg)
    params_sds, axes = lm.abstract_params()
    params_sh = shd.shard_params(mesh, params_sds, axes)
    specs = _batch_sharding(mesh, input_specs(cfg, shape))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            abft_dense.configure(cfg.abft)
            shd.set_active_mesh(mesh)
            try:
                logits, caches = lm.prefill(params, batch,
                                            max_len=shape.seq_len)
                # serving returns greedy next token + caches
                return jnp.argmax(logits[:, -1], axis=-1), caches
            finally:
                shd.set_active_mesh(None)
        fn = jax.jit(prefill_step)
        return StepBundle(lm, fn, (params_sh, specs), "prefill")

    # decode
    caches_sds = lm.init_caches(shape.global_batch, shape.seq_len,
                                abstract=True)
    caches_sh = _cache_shardings(cfg, mesh, caches_sds)

    def serve_step(params, caches, batch):
        abft_dense.configure(cfg.abft)
        shd.set_active_mesh(mesh)
        try:
            logits, new_caches = lm.decode_step(
                params, caches, batch["tokens"], batch["pos"])
            return jnp.argmax(logits[:, -1], axis=-1), new_caches
        finally:
            shd.set_active_mesh(None)

    fn = jax.jit(serve_step, donate_argnums=(1,))
    return StepBundle(lm, fn, (params_sh, caches_sh, specs), "decode")
