from repro.train.optimizer import TrainConfig, init_opt_state, adamw_update, lr_at
from repro.train.steps import build_train_step, build_serve_steps

__all__ = ["TrainConfig", "init_opt_state", "adamw_update", "lr_at",
           "build_train_step", "build_serve_steps"]
