"""Logical-axis sharding: name-based specs over a (data, model) mesh.

Parameters carry *logical* axis names (``("embed", "mlp")``, see
``models/layers.py``); this module maps them onto mesh axes:

  * model-parallel names ("mlp", "heads", "vocab", "experts", "seq_tp", ...)
    shard over the ``model`` mesh axis;
  * ``fsdp`` (promoted onto the embed dim of large weights by
    :func:`fsdp_hint`) shards over the data axes — ZeRO-3 layout;
  * everything else replicates.

Activations use :func:`constrain` with the same names; it is a no-op
outside a mesh context, so single-device code paths (CPU tests) run the
exact code the 512-chip launch runs.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical name -> mesh-axis role. "data" expands to the data axes of the
# active mesh (("pod", "data") on multi-pod meshes).
_MODEL_NAMES = frozenset(
    {"mlp", "expert_mlp", "heads", "vocab", "experts", "seq_tp", "model"})
_DATA_NAMES = frozenset({"batch", "fsdp", "data"})

_FSDP_MIN_SIZE = 2 ** 20   # elements; below this replication is cheaper

_state = threading.local()


# ---------------------------------------------------------------------------
# Active-mesh context (explicit, not ambient jax state: works under jit)
# ---------------------------------------------------------------------------

def set_active_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def active_mesh() -> Optional[Mesh]:
    mesh = getattr(_state, "mesh", None)
    if mesh is not None:
        return mesh
    # fall back to an enclosing `with mesh:` context if one is active
    try:
        env = jax.interpreters.pxla.thread_resources.env
        phys = env.physical_mesh
        if phys.axis_names:
            return Mesh(phys.devices, phys.axis_names)
    except Exception:
        pass
    return None


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything that isn't 'model').
    Empty for a tensor-parallel-only mesh — never the 'model' axis, which
    would let one PartitionSpec claim it twice."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh2d(rows: int, problems: int = 1, *, hosts: int = 1,
           devices: Optional[list] = None) -> Mesh:
    """The pod-scale K-means mesh: ``("host", "row", "problem")``.

    ``rows`` is the *total* row parallelism — it factors into
    ``hosts x (rows // hosts)`` so the centroid reduce can run
    hierarchically (exact psum inside each host group, then one
    cross-host hop per iteration, optionally int8-compressed — see
    ``dist/reduce.py``). ``problems`` shards a :class:`BatchedKMeans`
    problem stack; independent problems never exchange traffic, so the
    problem axis plays the role TP groups play in ``plan_rescale``
    (groups stay whole when the mesh shrinks).

    Degenerate sizes keep one uniform code path: ``mesh2d(8)`` is the
    old flat data-parallel mesh with extra size-1 axes, and
    ``mesh2d(1, 8)`` is pure problem-axis sharding. All three axes are
    data axes for :func:`data_axes` (none is named ``model``), so
    parameter sharding and legacy callers keep working unchanged.
    """
    if rows < 1 or problems < 1 or hosts < 1:
        raise ValueError(f"mesh2d needs positive sizes, got rows={rows} "
                         f"problems={problems} hosts={hosts}")
    if rows % hosts:
        raise ValueError(f"rows={rows} must divide over hosts={hosts}")
    devices = list(devices if devices is not None else jax.devices())
    need = rows * problems
    if len(devices) < need:
        raise ValueError(f"mesh2d({rows}, {problems}) needs {need} devices, "
                         f"only {len(devices)} available")
    import numpy as np
    grid = np.asarray(devices[:need]).reshape(hosts, rows // hosts, problems)
    return Mesh(grid, ("host", "row", "problem"))


# ---------------------------------------------------------------------------
# Logical axes -> PartitionSpec
# ---------------------------------------------------------------------------

def fsdp_hint(shape: tuple, axes: tuple) -> tuple:
    """Promote the embed dim of large weights to 'fsdp' (ZeRO-3 layout).

    Small tensors stay replicated: their all-gather latency costs more than
    the memory they would save."""
    size = 1
    for s in shape:
        size *= s
    if size < _FSDP_MIN_SIZE:
        return tuple(axes)
    out = []
    promoted = False
    for s, name in zip(shape, axes):
        if not promoted and name == "embed":
            out.append("fsdp")
            promoted = True
        else:
            out.append(name)
    return tuple(out)


def _spec_for(mesh: Mesh, shape: tuple, axes: tuple) -> P:
    """One PartitionSpec: first divisible model-name dim gets 'model', first
    data-name dim gets the data axes; a mesh axis is never used twice."""
    daxes = data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    model_n = mesh.shape.get("model", 1)
    spec: list = [None] * len(shape)
    used_model = used_data = False
    for i, (dim, name) in enumerate(zip(shape, axes)):
        if name is None:
            continue
        if name in _MODEL_NAMES and not used_model and model_n > 1 \
                and dim % model_n == 0:
            spec[i] = "model"
            used_model = True
        elif name in _DATA_NAMES and not used_data and daxes \
                and dp > 1 and dim % dp == 0:
            spec[i] = daxes if len(daxes) > 1 else daxes[0]
            used_data = True
    return P(*spec)


def shard_params(mesh: Mesh, params, axes):
    """Attach NamedShardings to a params pytree from its logical-axes tree.

    Works on both concrete arrays (device_put) and ShapeDtypeStructs
    (returns SDS-with-sharding, for AOT compilation / the dry-run).
    """
    def leaf_axes(ax):
        return isinstance(ax, tuple) and all(
            a is None or isinstance(a, str) for a in ax)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_a = jax.tree_util.tree_flatten(axes, is_leaf=leaf_axes)[0]
    out = []
    for leaf, ax in zip(flat_p, flat_a):
        ax = tuple(ax) if ax else (None,) * len(leaf.shape)
        if len(ax) < len(leaf.shape):   # stacked ('layers', ...) prefix etc.
            ax = (None,) * (len(leaf.shape) - len(ax)) + ax
        sh = NamedSharding(mesh, _spec_for(mesh, leaf.shape, ax))
        if isinstance(leaf, jax.ShapeDtypeStruct):
            out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                            sharding=sh))
        else:
            out.append(jax.device_put(leaf, sh))
    return jax.tree_util.tree_unflatten(treedef, out)


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """Pin an activation's sharding by logical names; no-op without a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = _spec_for(mesh, x.shape, tuple(logical_axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
