"""Hierarchical centroid-update reduction for pod-scale meshes.

The map-reduce k-means line (Li et al., PAPERS.md) identifies the
centroid-update reduce as the scaling wall: a flat psum of per-shard
``(sums, counts)`` crosses the slowest link in the machine once per
device. On a :func:`~repro.dist.sharding.mesh2d` mesh the reduce instead
runs in two hops:

  1. **intra-host** — exact psum over the ``row`` (and size-1 ``problem``)
     axes: fast links, full precision;
  2. **cross-host** — one hop over the ``host`` axis, routable through
     :func:`~repro.dist.compression.compressed_psum` (blockwise int8 with
     an error-feedback residual carried across iterations) or kept exact
     via the ``exact=True`` escape hatch.

Counts always reduce exactly: they are the denominators of the
distributed mean ``psum(sums) / psum(counts)`` and the renormalization
basis of the straggler policy — at count magnitudes, quantization would
bias every centroid. Only the sums route through the int8 hop.

ABFT composes with both hops. The update checksums are *linear* in
``(sums, counts)``, so each hop psums the expected checksums of exactly
the contributions it reduces and re-verifies afterwards — for the
compressed hop the expectations are computed on the locally *dequantized*
values, so quantization error can never masquerade as (or mask)
transport corruption. One detection increment per corrupted hop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.compression import dequantize, quantize

CROSS_HOST = ("exact", "int8")


@dataclasses.dataclass(frozen=True)
class ReducePlan:
    """How each Lloyd step's ``(sums, counts)`` cross the mesh.

    Parameters
    ----------
    hierarchical : bool, default=True
        Split the reduce into an intra-host hop and a cross-host hop when
        the mesh names a ``host`` axis (size > 1). On meshes without one
        the plan degenerates to the flat single-hop psum either way.
    cross_host : {"exact", "int8"}, default="exact"
        Transport of the cross-host hop. ``"int8"`` routes the partial
        sums through blockwise int8 quantization with an error-feedback
        residual (EF-SGD); counts and checksums stay exact.
    """

    hierarchical: bool = True
    cross_host: str = "exact"

    def __post_init__(self) -> None:
        if self.cross_host not in CROSS_HOST:
            raise ValueError(f"ReducePlan.cross_host must be one of "
                             f"{CROSS_HOST}, got {self.cross_host!r}")

    @classmethod
    def flat(cls) -> "ReducePlan":
        """The PR-1 behavior: one flat psum over every data axis."""
        return cls(hierarchical=False)

    @classmethod
    def compressed(cls, *, exact: bool = False) -> "ReducePlan":
        """Int8 error-feedback cross-host hop. ``exact=True`` is the
        escape hatch: same two-hop structure, no quantization — for
        debugging a tolerance regression or pinning bit-identical runs."""
        return cls(hierarchical=True,
                   cross_host="exact" if exact else "int8")


def hop_axes(mesh, reduce_axes: tuple,
             plan: ReducePlan) -> tuple[tuple, Optional[str]]:
    """Split the reduce axes into ``(intra, cross)`` hops.

    ``host`` is the cross-host hop when the plan is hierarchical and the
    mesh gives the axis size > 1; everything else reduces in the intra
    hop. A flat plan — or a mesh without a host axis — reduces every
    axis in one hop (``cross is None``)."""
    if plan.hierarchical and "host" in reduce_axes \
            and mesh.shape["host"] > 1:
        return tuple(a for a in reduce_axes if a != "host"), "host"
    return tuple(reduce_axes), None


def update_checksums(sums: jax.Array,
                     cnt: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dual linear checksums of one ``(sums, counts)`` contribution:
    ``e1`` = column sum over K, ``e2`` = index-weighted sum (paper §IV
    weights ``w_k = 1..K``). Linearity is the whole point — the checksum
    of a psum equals the psum of the checksums, hop by hop."""
    k = sums.shape[0]
    w_k = jnp.arange(1.0, k + 1.0, dtype=jnp.float32)
    return (jnp.stack([jnp.sum(sums, axis=0), w_k @ sums]),
            jnp.stack([jnp.sum(cnt), w_k @ cnt]))


def checksums_mismatch(sums: jax.Array, cnt: jax.Array, exp: jax.Array,
                       cexp: jax.Array, m_total: int) -> jax.Array:
    """True when reduced ``(sums, counts)`` disagree with the reduced
    expected checksums beyond the f32 rounding floor of an ``m_total``-row
    accumulation. Each e1/e2 pair thresholds against its own clean-side
    magnitude (the e2 row is ~K x larger; a shared scale would raise the
    e1 detection floor by that factor — the PR-4 self-masking lesson)."""
    from repro.core.checksum import threshold_factor
    factor = threshold_factor(m_total, jnp.float32)
    got, cgot = update_checksums(sums, cnt)
    thr1 = factor * jnp.maximum(jnp.max(jnp.abs(exp[0])), 1.0)
    thr2 = factor * jnp.maximum(jnp.max(jnp.abs(exp[1])), 1.0)
    return (jnp.any(jnp.abs(got[0] - exp[0]) > thr1)
            | jnp.any(jnp.abs(got[1] - exp[1]) > thr2)
            | (jnp.abs(cgot[0] - cexp[0])
               > factor * jnp.maximum(cexp[0], 1.0))
            | (jnp.abs(cgot[1] - cexp[1])
               > factor * jnp.maximum(cexp[1], 1.0)))


def reduce_update(sums: jax.Array, cnt: jax.Array, *, intra: tuple,
                  cross: Optional[str], compress: bool = False,
                  residual: Optional[jax.Array] = None,
                  checked: bool = False, m_total: int = 0):
    """Reduce one Lloyd step's ``(sums, counts)`` over the mesh.

    Runs inside ``shard_map``. Returns
    ``(sums, counts, bad_hops, residual_out)`` where ``bad_hops`` counts
    hops whose post-reduce checksum re-verification failed (0 when
    ``checked=False``) and ``residual_out`` is the next iteration's
    error-feedback carry (None unless ``compress``).

    The compressed hop quantizes ``sums + residual`` per host group (the
    intra hop already made the group's partial identical on every member)
    and psums the *dequantized* value — exactly the numerics an int8
    transport with local dequant-accumulate would produce, per
    ``dist/compression.py``'s modeling note.
    """
    bad = jnp.zeros((), jnp.int32)
    if intra:
        if checked:
            exp, cexp = update_checksums(sums, cnt)
            exp = jax.lax.psum(exp, intra)
            cexp = jax.lax.psum(cexp, intra)
        sums = jax.lax.psum(sums, intra)
        cnt = jax.lax.psum(cnt, intra)
        if checked:
            bad = bad + checksums_mismatch(
                sums, cnt, exp, cexp, m_total).astype(jnp.int32)
    if cross is not None:
        contrib = sums
        if compress:
            carried = contrib if residual is None else contrib + residual
            q, scale = quantize(carried)
            contrib = dequantize(q, scale, carried.shape[-1])
            residual = carried - contrib
        if checked:
            exp, cexp = update_checksums(contrib, cnt)
            exp = jax.lax.psum(exp, cross)
            cexp = jax.lax.psum(cexp, cross)
        sums = jax.lax.psum(contrib, cross)
        cnt = jax.lax.psum(cnt, cross)
        if checked:
            bad = bad + checksums_mismatch(
                sums, cnt, exp, cexp, m_total).astype(jnp.int32)
    return sums, cnt, bad, residual
