"""Distributed runtime: logical-axis sharding, data-parallel K-means,
hierarchical + compressed centroid reduction. See ``sharding.py`` for the
axis-name conventions and ``reduce.py`` for the reduce plans."""
from repro.dist import reduce, sharding
from repro.dist.reduce import ReducePlan
from repro.dist.sharding import mesh2d

__all__ = ["sharding", "reduce", "ReducePlan", "mesh2d"]
