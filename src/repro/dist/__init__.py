"""Distributed runtime: logical-axis sharding, data-parallel K-means,
gradient compression. See ``sharding.py`` for the axis-name conventions."""
from repro.dist import sharding

__all__ = ["sharding"]
