"""Gradient/update compression for cross-host reductions.

Blockwise int8 quantization with error feedback: the quantization residual
is returned to the caller, who folds it into the *next* step's local value
(standard EF-SGD), so the compression error accumulates as O(1) instead of
O(steps).

``compressed_psum`` here models the *numerics* of the scheme — it
quantizes, dequantizes and psums the dequantized values, so accuracy and
the error-feedback residual are exactly what a real int8 transport would
produce. The wire-level byte reduction (~4x for f32 -> int8 + scales) is
NOT realized by this simulation: XLA's psum still moves f32. Realizing it
needs an int8 all-gather + local dequant-accumulate, which only pays off
on real cross-pod links.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def quantize(x: jax.Array, block: int = BLOCK):
    """Blockwise symmetric int8. Returns (q int8 (N/b, b), scale (N/b, 1))."""
    n = x.shape[-1]
    pad = (-n) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(xp.shape[:-1] + (-1, block))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_rows(x: jax.Array):
    """Per-row symmetric int8: the blockwise scheme above with the whole
    last axis as the block (no padding/reshape — one scale per row).
    Returns (q int8, shape of x; scale f32, last axis collapsed to 1).
    Rows of integer values in [-127, 127] that pin a +-127 entry get scale
    exactly 1.0, making the quantization an identity — the property the
    int8 kernel template's bit-exactness contract rests on."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, n: int | None = None):
    x = (q.astype(jnp.float32) * scale).reshape(q.shape[:-2] + (-1,))
    return x if n is None else x[..., :n]


def compressed_psum(g: jax.Array, axis_name: str, *, block: int = BLOCK):
    """psum of the int8-quantized value + local error-feedback residual.

    Inside shard_map: ``red, res = compressed_psum(grad + carried_res, ax)``.
    """
    q, scale = quantize(g, block)
    deq = dequantize(q, scale, g.shape[-1])
    res = g - deq
    red = jax.lax.psum(deq, axis_name)
    return red, res
