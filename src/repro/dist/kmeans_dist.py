"""Data-parallel FT K-means over a device mesh — up to pod scale.

Rows of X shard over the row axes; centroids replicate. Each Lloyd step
runs the policy-resolved assignment backend on the local shard (the fused
ABFT kernel protects each shard independently — SEU detection is local by
construction) and reduces per-cluster (sums, counts) across the mesh:
the distributed equality ``mean = psum(sums) / psum(counts)`` makes the
result bit-comparable to the single-device iteration.

The reduce itself follows a :class:`~repro.dist.reduce.ReducePlan`: on a
:func:`~repro.dist.sharding.mesh2d` mesh (axes ``("host", "row",
"problem")``) it runs hierarchically — exact psum inside each host group,
then one cross-host hop per iteration that can route through the int8
error-feedback transport (``ReducePlan.compressed()``) with an
``exact=True`` escape hatch. One-pass FT backends extend ABFT across
every hop: the shard's verified update checksums are psum'd alongside its
partials (they are linear, so the invariant survives each reduction) and
re-checked after *each* hop — corruption introduced by the reduction
itself lands in the returned ``detected`` total, and on the compressed
hop the expectations are taken on the dequantized values so quantization
error is never mistaken for corruption.

Accepts either a ``repro.api.KMeans`` estimator (preferred), a
``repro.api.BatchedKMeans`` (problem-axis sharding — see below), or a
legacy ``KMeansConfig``.

Problem-axis mode: handing ``DistributedKMeans`` a
:class:`~repro.batch.BatchedKMeans` switches the sharded dimension from
rows to *problems*. On a flat mesh each device runs the batched one-pass
chunk on its own slice of the (B, N, F) stack — no psum on the hot path,
bit-comparable per problem to the single-device batched fit because both
drivers run the same ``make_batched_chunk`` body. On a 2D mesh with row
parallelism (``mesh2d(rows, problems)`` with rows > 1) each problem's
rows additionally shard over the row axes and the per-problem (sums,
counts) reduce hierarchically — the same per-iteration arithmetic as the
batched chunk minus empty-cluster reseeding (donor rows are shard-local,
so row-sharded modes keep an empty cluster at its previous centroid; the
paths are bit-identical whenever no cluster empties).

Whole-worker failures: :meth:`DistributedKMeans.fit_elastic` runs the
row-mode fit under the recovery ladder's fail-stop rung — on
:class:`~repro.ft.elastic.WorkerLossError` it shrinks the mesh
(``plan_rescale_rows``), restores the last checkpoint and resumes,
when the estimator's :class:`~repro.api.FaultPolicy` says
``worker_loss="shrink"``.
"""
from __future__ import annotations

import json
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.reduce import ReducePlan, hop_axes, reduce_update
from repro.dist.sharding import data_axes
from repro.ft.elastic import WorkerLossError, build_mesh, plan_rescale_rows


def _host_read(value):
    """The one sanctioned device->host sync of the distributed driver:
    chunk-boundary convergence state and detection counters (same funnel
    contract as ``repro.api.estimator._host_read``)."""
    return jax.device_get(value)


def _axes_spec(axes: tuple):
    """PartitionSpec entry for a set of mesh axes (name, tuple, or None)."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def restore_estimator(checkpointer):
    """Rebuild ``(estimator, start_iteration)`` from the newest snapshot.

    Row-mode checkpoints written by :meth:`DistributedKMeans.fit` carry
    the estimator's full ``get_state`` config alongside the centroid
    arrays, so the elastic restart path — or a cold process — can restore
    the *estimator*, not just raw centroids: the FaultPolicy (including
    ``worker_loss``), backend pin, dtype and seeds all round-trip.
    Returns ``(None, 0)`` when no restorable snapshot exists.
    """
    st = checkpointer.restore()
    if st is None or "config_json" not in st:
        return None, 0
    from repro.api import KMeans
    cfg = json.loads(bytes(bytearray(st["config_json"])).decode())
    est = KMeans.from_state({
        "cluster_centers": st["centroids"], "counts": None,
        "n_iter": int(st["iteration"]), "inertia": None,
        "detected_errors": 0, "config": cfg})
    return est, int(st["iteration"])


class DistributedKMeans:
    def __init__(self, config, mesh, *, reduce: Optional[ReducePlan] = None):
        from repro.api import BatchedKMeans, KMeans as ApiKMeans
        self.problem_axis = isinstance(config, BatchedKMeans)
        if isinstance(config, (ApiKMeans, BatchedKMeans)):
            self.est = config
        else:   # legacy KMeansConfig
            from repro.core.kmeans import _make_estimator
            self.est = _make_estimator(config, None)
        self.reduce = reduce if reduce is not None else ReducePlan()
        self._bind_mesh(mesh)

    def _bind_mesh(self, mesh) -> None:
        """Adopt a mesh: derive the row/problem axis split, the reduce
        hops, and drop every compiled step (a rescale re-resolves winners
        at the new per-shard shape — see ``autotune.shard_shape``)."""
        self.mesh = mesh
        self._daxes = data_axes(mesh)
        assert self._daxes, ("DistributedKMeans needs a mesh with at least "
                             "one data axis (got model-parallel-only mesh)")
        has_problem = "problem" in self._daxes
        if self.problem_axis:
            self._paxes = ("problem",) if has_problem else self._daxes
            self._raxes = tuple(a for a in self._daxes if a != "problem") \
                if has_problem else ()
        else:
            if has_problem and mesh.shape["problem"] != 1:
                raise ValueError(
                    f"single-problem KMeans on a mesh with problem axis "
                    f"size {mesh.shape['problem']}; shard a BatchedKMeans "
                    f"over it, or build mesh2d(rows, problems=1)")
            self._paxes = ()
            self._raxes = self._daxes
        self._rp = 1
        for a in self._raxes:
            self._rp *= mesh.shape[a]
        self._pp = 1
        for a in self._paxes:
            self._pp *= mesh.shape[a]
        self._row = _axes_spec(self._raxes if not self.problem_axis
                               else self._paxes)   # legacy spec attr
        self._dp = self._rp * self._pp
        self._intra, self._cross = hop_axes(mesh, self._raxes, self.reduce)
        self._compress = (not self.problem_axis) \
            and self.reduce.cross_host == "int8" and self._cross is not None
        self._steps: dict = {}

    # -- data placement -----------------------------------------------------

    def shard_data(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        if self.problem_axis:
            assert x.ndim == 3, (
                f"problem-axis mode shards stacked (B, N, F) problems, "
                f"got shape {x.shape}")
            assert x.shape[0] % self._pp == 0, (
                f"problems {x.shape[0]} must divide problem parallelism "
                f"{self._pp}")
            if self._rp > 1:
                assert x.shape[1] % self._rp == 0, (
                    f"rows {x.shape[1]} must divide row parallelism "
                    f"{self._rp}")
                spec = P(_axes_spec(self._paxes), _axes_spec(self._raxes),
                         None)
            else:
                spec = P(_axes_spec(self._paxes), None, None)
            return jax.device_put(x, NamedSharding(self.mesh, spec))
        assert x.shape[0] % self._rp == 0, (
            f"rows {x.shape[0]} must divide data parallelism {self._rp}")
        return jax.device_put(
            x, NamedSharding(self.mesh, P(_axes_spec(self._raxes), None)))

    # -- one reduced Lloyd step ---------------------------------------------

    def _shard_backend(self):
        """The per-shard assignment backend. Off-TPU, Pallas kernels run in
        interpret mode — Python-loop bound and far too slow to trace once
        per shard — so they resolve to their jnp analogues with the same
        protection level (fused_ft -> offline ABFT, fused -> XLA-fused)."""
        from repro.api import get_backend
        from repro.kernels.ops import on_tpu
        backend = self.est._backend
        if not on_tpu():
            backend = get_backend({
                "fused": "gemm_fused", "fused_ft": "abft_offline",
                "lloyd": "lloyd_xla", "lloyd_ft": "lloyd_ft_xla",
                "lloyd_batched": "lloyd_batched_xla",
            }.get(backend.name, backend.name))
        return backend

    def _build_step(self, m_local: int, f: int):
        est = self.est
        backend = self._shard_backend()
        k = est.n_clusters
        params = est._resolve_params(m_local, f) if backend.takes_params \
            else None
        raxes = self._raxes
        intra, cross = self._intra, self._cross
        compress = self._compress
        m_total = m_local * self._rp   # reduce-checksum threshold scale

        use_dmr = est.fault.dmr_enabled(backend)

        def local_step(x, c, inj, res):
            from repro.core.kmeans import means_from_sums, protected_sums
            # the estimator's compute dtype applies per shard, at the same
            # kernel boundary as the single-device fit (the tile selection
            # above is already keyed by it); centroids stay f32 across the
            # reduce and the update
            x = est._cast(x)
            out = backend(
                x, est._cast(c), params=params,
                inj=inj if backend.takes_injection else None)
            checked = backend.fuses_update and backend.supports_ft
            if backend.fuses_update:
                # one-pass backend: the shard's (sums, counts) come out of
                # the kernel epilogue — reduce them directly, no second pass
                am, md, det, sums, cnt = out
            else:
                am, md, det = out
                sums, cnt = protected_sums(x, am, k, use_dmr=use_dmr)
            sums, cnt, bad, res_out = reduce_update(
                sums, cnt, intra=intra, cross=cross, compress=compress,
                residual=res[0] if compress else None,
                checked=checked, m_total=m_total)
            inertia = jax.lax.psum(jnp.sum(md), raxes)
            det = jax.lax.psum(det, raxes) + bad
            new_c = means_from_sums(sums, cnt, c)
            shift = jnp.sqrt(jnp.sum((new_c - c) ** 2))
            outs = (am, new_c, inertia, shift, det)
            if compress:
                outs = outs + (res_out[None],)
            return outs

        rspec = _axes_spec(self._raxes)
        in_specs = [P(rspec, None), P(None, None), P(None)]
        out_specs = [P(rspec), P(None, None), P(), P(), P()]
        if compress:
            # one error-feedback residual per host group, carried across
            # iterations; the intra-host psum makes every group member
            # compute the identical residual, so the block is consistent
            in_specs.append(P("host", None, None))
            out_specs.append(P("host", None, None))
        else:
            in_specs.append(P(None, None, None))

        return jax.jit(shard_map(
            local_step, mesh=self.mesh,
            in_specs=tuple(in_specs), out_specs=tuple(out_specs),
            check_rep=False))

    # -- problem-axis mode: shard over B, no psum on the hot path -----------

    def _build_step_problems(self, b_local: int, n: int, f: int,
                             n_steps: int):
        """One ``n_steps``-iteration batched chunk per shard: each device
        runs :func:`~repro.batch.estimator.make_batched_chunk` — the exact
        body the single-device :class:`~repro.batch.BatchedKMeans` jits —
        on its own problems. No collective touches the iteration loop; the
        single psum folds the per-shard detected-error counters once per
        chunk (control plane, not hot path)."""
        from repro.batch.estimator import make_batched_chunk
        from repro.kernels import ops
        est = self.est
        backend = self._shard_backend()
        params = est._resolve_params(b_local, n, f) \
            if backend.takes_params else None
        chunk = make_batched_chunk(backend, params, est._cast, est.tol,
                                   n_steps)
        daxes = self._daxes

        def local_chunk(x, c, am, inertia, done, keys, it0):
            plan = ops.plan_data_batched(est._cast(x), params) \
                if backend.takes_params else est._cast(x)
            det0 = jnp.zeros((), jnp.int32)
            (c, am, inertia, done, det), live = chunk(
                plan, c, am, inertia, done, det0, keys, it0)
            return c, am, inertia, done, jax.lax.psum(det, daxes), live

        row = _axes_spec(self._paxes)
        return jax.jit(shard_map(
            local_chunk, mesh=self.mesh,
            in_specs=(P(row, None, None), P(row, None, None), P(row, None),
                      P(row), P(row), P(row, None), P()),
            out_specs=(P(row, None, None), P(row, None), P(row), P(row),
                       P(), P(None, row)),
            check_rep=False))

    def _fit_problems(self, xs: jax.Array, centroids: jax.Array,
                      max_iters: int, start_iteration: int,
                      checkpointer, checkpoint_interval: int,
                      on_iteration: Optional[Callable] = None):
        est = self.est
        bsz, n, f = xs.shape
        keys = est._problem_keys(bsz)     # problem b seeds from its global
        centroids = jnp.asarray(centroids, jnp.float32)     # index, so the
        am = jnp.zeros((bsz, n), jnp.int32)   # sharded fit matches the
        inertia = jnp.full((bsz,), jnp.inf, jnp.float32)   # single-device
        done = jnp.zeros((bsz,), jnp.bool_)                # one exactly
        iters = np.zeros((bsz,), np.int64)
        total_det = 0
        it0 = start_iteration
        saved = False
        while it0 < max_iters:
            if on_iteration is not None:
                on_iteration(it0)
            n_steps = min(est.sync_every, max_iters - it0)
            key = (bsz // self._pp, n, f, n_steps, "problems")
            if key not in self._steps:
                self._steps[key] = self._build_step_problems(
                    bsz // self._pp, n, f, n_steps)
            centroids, am, inertia, done, det, live = self._steps[key](
                xs, centroids, am, inertia, done, keys, jnp.int32(it0))
            done_h, live_h, det_h = _host_read((done, live, det))
            iters += live_h.sum(axis=0).astype(np.int64)
            total_det += int(det_h)
            it0 += n_steps
            saved = it0 % checkpoint_interval == 0
            if checkpointer is not None and saved:
                checkpointer.save(
                    it0, self._checkpoint_state(centroids, it0))
            if bool(done_h.all()):
                break
        if checkpointer is not None and not saved and it0 > start_iteration:
            checkpointer.save(it0, self._checkpoint_state(centroids, it0))
        return centroids, am, inertia, np.maximum(iters, 1), total_det

    # -- combined mode: problems x rows, hierarchical per-problem reduce ----

    def _build_step_combined(self, b_local: int, n_local: int, f: int):
        """One reduced Lloyd step for row-sharded stacked problems: the
        per-iteration arithmetic of ``make_batched_chunk``'s body — same
        freeze masks, same update — with the per-problem (sums, counts)
        reduced over the row axes instead of computed whole. Empty-cluster
        reseeding is the one intentional difference (donor rows are
        shard-local; empties keep their previous centroid), so results
        are bit-identical to the single-device batched fit exactly when
        no cluster empties."""
        from repro.core.kmeans import means_from_sums
        from repro.kernels import ops
        est = self.est
        backend = self._shard_backend()
        params = est._resolve_params(b_local, n_local, f) \
            if backend.takes_params else None
        if self.reduce.cross_host == "int8" and self._cross is not None:
            raise NotImplementedError(
                "the int8 cross-host hop carries one residual per host "
                "group and is row-mode (single-problem) only; use "
                "ReducePlan.compressed(exact=True) or the exact default "
                "for row-sharded problem stacks")
        intra, cross = self._intra, self._cross
        raxes, daxes = self._raxes, self._daxes
        tol = est.tol

        def local_step(x, c, am, inertia, done):
            xb = est._cast(x)
            plan = ops.plan_data_batched(xb, params) \
                if backend.takes_params else xb
            out = backend(plan, est._cast(c),
                          params=params if backend.takes_params else None)
            am_n, md, det_i, sums, cnt = out
            # exact hierarchical reduce of the per-problem partials over
            # the row hops; the problem axis is never reduced
            sums, cnt, _, _ = reduce_update(sums, cnt, intra=intra,
                                            cross=cross)
            inertia_n = jax.lax.psum(jnp.sum(md, axis=1), raxes)   # (Bl,)
            new_c = jax.vmap(means_from_sums)(sums, cnt, c)
            shift = jnp.sqrt(jnp.sum((new_c - c) ** 2, axis=(1, 2)))
            live = jnp.logical_not(done)
            new_c = jnp.where(live[:, None, None], new_c, c)
            am_o = jnp.where(live[:, None], am_n, am)
            inertia_o = jnp.where(live, inertia_n, inertia)
            done_n = jnp.logical_or(done, shift < tol)
            det = jax.lax.psum(jnp.sum(det_i).astype(jnp.int32), daxes)
            return new_c, am_o, inertia_o, done_n, det

        pspec = _axes_spec(self._paxes)
        rspec = _axes_spec(self._raxes)
        return jax.jit(shard_map(
            local_step, mesh=self.mesh,
            in_specs=(P(pspec, rspec, None), P(pspec, None, None),
                      P(pspec, rspec), P(pspec), P(pspec)),
            out_specs=(P(pspec, None, None), P(pspec, rspec), P(pspec),
                       P(pspec), P()),
            check_rep=False))

    def _fit_combined(self, xs: jax.Array, centroids: jax.Array,
                      max_iters: int, start_iteration: int,
                      checkpointer, checkpoint_interval: int,
                      on_iteration: Optional[Callable] = None):
        est = self.est
        bsz, n, f = xs.shape
        key = (bsz // self._pp, n // self._rp, f, "combined")
        if key not in self._steps:
            self._steps[key] = self._build_step_combined(
                bsz // self._pp, n // self._rp, f)
        step = self._steps[key]
        centroids = jnp.asarray(centroids, jnp.float32)
        am = jnp.zeros((bsz, n), jnp.int32)
        inertia = jnp.full((bsz,), jnp.inf, jnp.float32)
        done = jnp.zeros((bsz,), jnp.bool_)
        iters = np.zeros((bsz,), np.int64)
        total_det = 0
        completed = start_iteration
        saved = False
        for it in range(start_iteration, max_iters):
            if on_iteration is not None:
                on_iteration(it)
            done_h = _host_read(done)
            if bool(done_h.all()):
                break
            centroids, am, inertia, done, det = step(
                xs, centroids, am, inertia, done)
            det_h = _host_read(det)
            iters += np.logical_not(done_h).astype(np.int64)
            total_det += int(det_h)
            completed = it + 1
            saved = completed % checkpoint_interval == 0
            if checkpointer is not None and saved:
                checkpointer.save(
                    completed, self._checkpoint_state(centroids, completed))
        if checkpointer is not None and not saved and \
                completed > start_iteration:
            checkpointer.save(
                completed, self._checkpoint_state(centroids, completed))
        return centroids, am, inertia, np.maximum(iters, 1), total_det

    # -- checkpoint payloads -------------------------------------------------

    def _checkpoint_state(self, centroids, iteration: int) -> dict:
        """Snapshot payload: raw arrays plus — when the estimator has a
        ``get_state`` — its serialized config, so ``restore_estimator``
        rebuilds the full estimator (policy, backend, seeds) from the
        checkpoint alone."""
        payload = {"centroids": centroids,
                   "iteration": jnp.asarray(iteration, jnp.int32)}
        est = self.est
        if not self.problem_axis and hasattr(est, "get_state"):
            # mid-fit snapshot: stamp the current centroids so get_state()
            # (which requires a fitted estimator) serializes the config
            est.cluster_centers_ = jnp.asarray(centroids, jnp.float32)
            est.n_iter_ = iteration
            est._counts = getattr(est, "_counts", None)
            est.inertia_ = getattr(est, "inertia_", None)
            est.detected_errors_ = getattr(est, "detected_errors_", 0)
            state = est.get_state()
            payload["config_json"] = np.frombuffer(
                json.dumps(state["config"]).encode(), np.uint8).copy()
        return payload

    # -- driver --------------------------------------------------------------

    def fit(self, xs: jax.Array, centroids: jax.Array, *,
            max_iters: Optional[int] = None, start_iteration: int = 0,
            checkpointer=None, checkpoint_interval: int = 5,
            on_iteration: Optional[Callable] = None):
        """Run Lloyd iterations on sharded data.

        Returns (centroids, assign, inertia, iterations, detected) —
        ``iterations`` counts completed iterations from zero, so a restart
        with ``start_iteration`` continues the same trajectory.

        ``on_iteration`` (optional) is called with the iteration index at
        the *start* of each iteration (each chunk, in legacy problem-axis
        mode) — the fault-drill hook: a
        :class:`~repro.ft.elastic.FailureSchedule` raises
        :class:`~repro.ft.elastic.WorkerLossError` from here, before any
        of the iteration's work is spent.

        Problem-axis mode (a :class:`~repro.batch.BatchedKMeans` was
        passed): ``xs`` is the (B, N, F) problem stack sharded over B,
        ``centroids`` the (B, K, F) stack, and the returned ``assign`` /
        ``inertia`` / ``iterations`` all carry the per-problem leading
        axis (``iterations`` is each problem's executed count). With row
        parallelism (``mesh2d(rows, problems)``, rows > 1) each problem's
        rows also shard and the reduce runs hierarchically per problem.
        """
        est = self.est
        max_iters = max_iters if max_iters is not None else est.max_iter
        if self.problem_axis:
            args = (xs, centroids, max_iters, start_iteration,
                    checkpointer, checkpoint_interval, on_iteration)
            if self._rp > 1:
                return self._fit_combined(*args)
            return self._fit_problems(*args)
        m, f = xs.shape
        from repro.core.autotune import shard_shape
        m_local = shard_shape(m, est.n_clusters, f, self._rp)[0]
        key = (m_local, f, "row")
        if key not in self._steps:
            self._steps[key] = self._build_step(m_local, f)
        step = self._steps[key]
        shard_backend = self._shard_backend()
        if shard_backend.takes_injection:
            rng = est._campaign_rng()
            params = est._resolve_params(m_local, f)
        from repro.core.fault import no_step_injection

        def no_injection():
            return no_step_injection(shard_backend.kernel_kind)

        centroids = jnp.asarray(centroids)
        am = jnp.zeros((m,), jnp.int32)
        inertia = jnp.asarray(jnp.inf)
        total_det = jnp.zeros((), jnp.int32)
        k = est.n_clusters
        if self._compress:
            # per-host-group error-feedback residual, zero at fit start
            # and after every restart (the carry is transient by design:
            # EF bounds the accumulated error to one quantization step)
            res = jax.device_put(
                jnp.zeros((self.mesh.shape["host"], k, f), jnp.float32),
                NamedSharding(self.mesh, P("host", None, None)))
        else:
            res = jnp.zeros((1, k, f), jnp.float32)
        completed = start_iteration
        saved = False
        for it in range(start_iteration, max_iters):
            if on_iteration is not None:
                on_iteration(it)
            inj = no_injection()
            if shard_backend.takes_injection:
                inj = est._draw_injection(rng, m_local, f, params)
            if self._compress:
                am, centroids, inertia, shift, det, res = step(
                    xs, centroids, inj, res)
            else:
                am, centroids, inertia, shift, det = step(
                    xs, centroids, inj, res)
            total_det = total_det + det
            completed = it + 1
            saved = completed % checkpoint_interval == 0
            if checkpointer is not None and saved:
                checkpointer.save(
                    completed, self._checkpoint_state(centroids, completed))
            if float(_host_read(shift)) < est.tol:
                break
        if checkpointer is not None and not saved and \
                completed > start_iteration:
            # final durable snapshot: a run that converges (or crashes the
            # loop) between intervals must still be restartable
            checkpointer.save(
                completed, self._checkpoint_state(centroids, completed))
        return centroids, am, inertia, completed, total_det

    # -- elastic driver: survive fail-stop worker loss ------------------------

    def fit_elastic(self, x: jax.Array, centroids: jax.Array, *,
                    checkpointer, checkpoint_interval: int = 5,
                    max_iters: Optional[int] = None,
                    on_iteration: Optional[Callable] = None,
                    max_restarts: int = 8):
        """Row-mode fit that survives whole-worker loss (recovery ladder
        step 4) when the estimator's policy says ``worker_loss="shrink"``.

        On :class:`~repro.ft.elastic.WorkerLossError` — raised by the
        runtime, or in drills by a
        :class:`~repro.ft.elastic.FailureSchedule` passed as
        ``on_iteration`` — the driver removes the lost devices, replans
        the mesh with :func:`~repro.ft.elastic.plan_rescale_rows` (problem
        groups stay whole, rows shrink), rebinds and recompiles against
        the new per-shard shapes, restores the newest
        :class:`~repro.ft.Checkpointer` snapshot (the serialized
        ``get_state`` written by the fit loop) and resumes the trajectory
        from its iteration. A loss before the first durable snapshot
        restarts from the initial ``centroids``. With a policy of
        ``worker_loss="fail"`` (the default) the error propagates.

        ``x`` is the *unsharded* row matrix — each rescale reshards it.
        Returns ``(centroids, assign, inertia, iterations, detected,
        restarts)``.
        """
        assert not self.problem_axis, (
            "fit_elastic drives the row-sharded mode; problem-axis stacks "
            "restart whole (independent problems have no partial state to "
            "reshard)")
        est = self.est
        shrink = getattr(getattr(est, "fault", None), "worker_loss",
                         "fail") == "shrink"
        devices = list(self.mesh.devices.flat)
        problems = dict(self.mesh.shape).get("problem", 1)
        c = jnp.asarray(centroids)
        it0 = 0
        restarts = 0
        extra_det = 0
        while True:
            try:
                out = self.fit(
                    self.shard_data(x), c, max_iters=max_iters,
                    start_iteration=it0, checkpointer=checkpointer,
                    checkpoint_interval=checkpoint_interval,
                    on_iteration=on_iteration)
                c, am, inertia, completed, det = out
                return c, am, inertia, completed, det + extra_det, restarts
            except WorkerLossError as e:
                if not shrink or restarts >= max_restarts:
                    raise
                restarts += 1
                lost = set(e.lost)
                devices = [d for i, d in enumerate(devices)
                           if i not in lost]
                hosts = dict(self.mesh.shape).get("host", 1)
                plan = plan_rescale_rows(devices, problems=problems,
                                         hosts=hosts)
                self._bind_mesh(build_mesh(plan, devices))
                st = checkpointer.restore()
                if st is None:
                    # lost before the first durable snapshot: restart the
                    # whole trajectory from the initial seeds
                    it0 = 0
                    c = jnp.asarray(centroids)
                else:
                    c = jnp.asarray(st["centroids"])
                    it0 = int(st["iteration"])
