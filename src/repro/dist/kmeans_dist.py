"""Data-parallel FT K-means over a device mesh.

Rows of X shard over the data axes; centroids replicate. Each Lloyd step
runs the policy-resolved assignment backend on the local shard (the fused
ABFT kernel protects each shard independently — SEU detection is local by
construction) and ``psum``s per-cluster (sums, counts) across the mesh:
the distributed equality ``mean = psum(sums) / psum(counts)`` makes the
result bit-comparable to the single-device iteration.

One-pass FT backends extend the protection across the reduce: the shard's
verified update checksums are psum'd alongside its partial (sums, counts)
— the checksums are linear, so the global invariant holds — and re-checked
after the reduction, detecting corruption introduced by the cross-shard
psum itself (counted in the returned ``detected`` total).

Accepts either a ``repro.api.KMeans`` estimator (preferred), a
``repro.api.BatchedKMeans`` (problem-axis sharding — see below), or a
legacy ``KMeansConfig``.

Problem-axis mode: handing ``DistributedKMeans`` a
:class:`~repro.batch.BatchedKMeans` switches the sharded dimension from
rows to *problems* — each device runs the batched one-pass chunk on its
own slice of the (B, N, F) stack. Independent problems share nothing, so
the hot path has **no psum at all** (embarrassingly parallel; the only
cross-device traffic is the host's convergence check at chunk
boundaries), and per-problem results are bit-comparable to the
single-device batched fit because both drivers run the same
``make_batched_chunk`` body.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import data_axes


def _host_read(value):
    """The one sanctioned device->host sync of the distributed driver:
    chunk-boundary convergence state and detection counters (same funnel
    contract as ``repro.api.estimator._host_read``)."""
    return jax.device_get(value)


class DistributedKMeans:
    def __init__(self, config, mesh):
        from repro.api import BatchedKMeans, KMeans as ApiKMeans
        self.problem_axis = isinstance(config, BatchedKMeans)
        if isinstance(config, (ApiKMeans, BatchedKMeans)):
            self.est = config
        else:   # legacy KMeansConfig
            from repro.core.kmeans import _make_estimator
            self.est = _make_estimator(config, None)
        self.mesh = mesh
        self._daxes = data_axes(mesh)
        assert self._daxes, ("DistributedKMeans needs a mesh with at least "
                             "one data axis (got model-parallel-only mesh)")
        self._row = self._daxes if len(self._daxes) > 1 else self._daxes[0]
        self._dp = 1
        for a in self._daxes:
            self._dp *= mesh.shape[a]
        self._step = None

    # -- data placement -----------------------------------------------------

    def shard_data(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        if self.problem_axis:
            assert x.ndim == 3, (
                f"problem-axis mode shards stacked (B, N, F) problems, "
                f"got shape {x.shape}")
            assert x.shape[0] % self._dp == 0, (
                f"problems {x.shape[0]} must divide data parallelism "
                f"{self._dp}")
            return jax.device_put(
                x, NamedSharding(self.mesh, P(self._row, None, None)))
        assert x.shape[0] % self._dp == 0, (
            f"rows {x.shape[0]} must divide data parallelism {self._dp}")
        return jax.device_put(
            x, NamedSharding(self.mesh, P(self._row, None)))

    # -- one psum'd Lloyd step ----------------------------------------------

    def _shard_backend(self):
        """The per-shard assignment backend. Off-TPU, Pallas kernels run in
        interpret mode — Python-loop bound and far too slow to trace once
        per shard — so they resolve to their jnp analogues with the same
        protection level (fused_ft -> offline ABFT, fused -> XLA-fused)."""
        from repro.api import get_backend
        from repro.kernels.ops import on_tpu
        backend = self.est._backend
        if not on_tpu():
            backend = get_backend({
                "fused": "gemm_fused", "fused_ft": "abft_offline",
                "lloyd": "lloyd_xla", "lloyd_ft": "lloyd_ft_xla",
                "lloyd_batched": "lloyd_batched_xla",
            }.get(backend.name, backend.name))
        return backend

    def _build_step(self, m_local: int, f: int):
        est = self.est
        backend = self._shard_backend()
        k = est.n_clusters
        params = est._resolve_params(m_local, f) if backend.takes_params \
            else None
        daxes = self._daxes
        m_total = m_local * self._dp   # reduce-checksum threshold scale

        use_dmr = est.fault.dmr_enabled(backend)

        def local_step(x, c, inj):
            from repro.core.kmeans import means_from_sums, protected_sums
            # the estimator's compute dtype applies per shard, at the same
            # kernel boundary as the single-device fit (the tile selection
            # above is already keyed by it); centroids stay f32 across the
            # psum and the update
            x = est._cast(x)
            out = backend(
                x, est._cast(c), params=params,
                inj=inj if backend.takes_injection else None)
            checked = backend.fuses_update and backend.supports_ft
            if backend.fuses_update:
                # one-pass backend: the shard's (sums, counts) come out of
                # the kernel epilogue — psum them directly, no second pass
                am, md, det, sums, cnt = out
            else:
                am, md, det = out
                sums, cnt = protected_sums(x, am, k, use_dmr=use_dmr)
            if checked:
                # one-pass FT: the update checksums are linear in
                # (sums, counts), so psumming the shard-local *verified*
                # checksums alongside the partials extends the ABFT
                # invariant across the reduce — corruption introduced by
                # the cross-shard reduction itself is detected here, at
                # the boundary, not silently folded into the centroids.
                w_k = jnp.arange(1.0, k + 1.0, dtype=jnp.float32)
                exp = jnp.stack([jnp.sum(sums, axis=0), w_k @ sums])
                cexp = jnp.stack([jnp.sum(cnt), w_k @ cnt])
                exp = jax.lax.psum(exp, daxes)
                cexp = jax.lax.psum(cexp, daxes)
            sums = jax.lax.psum(sums, daxes)
            cnt = jax.lax.psum(cnt, daxes)
            inertia = jax.lax.psum(jnp.sum(md), daxes)
            det = jax.lax.psum(det, daxes)
            if checked:
                from repro.core.checksum import threshold_factor
                # each e1/e2 pair thresholds against its own clean-side
                # magnitude (the e2 row is ~K x larger; a shared scale
                # would raise the e1 detection floor by that factor)
                factor = threshold_factor(m_total, jnp.float32)
                thr1 = factor * jnp.maximum(jnp.max(jnp.abs(exp[0])), 1.0)
                thr2 = factor * jnp.maximum(jnp.max(jnp.abs(exp[1])), 1.0)
                reduce_bad = (
                    jnp.any(jnp.abs(jnp.sum(sums, axis=0) - exp[0]) > thr1)
                    | jnp.any(jnp.abs(w_k @ sums - exp[1]) > thr2)
                    | (jnp.abs(jnp.sum(cnt) - cexp[0])
                       > factor * jnp.maximum(cexp[0], 1.0))
                    | (jnp.abs(w_k @ cnt - cexp[1])
                       > factor * jnp.maximum(cexp[1], 1.0)))
                det = det + reduce_bad.astype(jnp.int32)
            new_c = means_from_sums(sums, cnt, c)
            shift = jnp.sqrt(jnp.sum((new_c - c) ** 2))
            return am, new_c, inertia, shift, det

        return jax.jit(shard_map(
            local_step, mesh=self.mesh,
            in_specs=(P(self._row, None), P(None, None), P(None)),
            out_specs=(P(self._row), P(None, None), P(), P(), P()),
            check_rep=False))

    # -- problem-axis mode: shard over B, no psum on the hot path -----------

    def _build_step_problems(self, b_local: int, n: int, f: int,
                             n_steps: int):
        """One ``n_steps``-iteration batched chunk per shard: each device
        runs :func:`~repro.batch.estimator.make_batched_chunk` — the exact
        body the single-device :class:`~repro.batch.BatchedKMeans` jits —
        on its own problems. No collective touches the iteration loop; the
        single psum folds the per-shard detected-error counters once per
        chunk (control plane, not hot path)."""
        from repro.batch.estimator import make_batched_chunk
        from repro.kernels import ops
        est = self.est
        backend = self._shard_backend()
        params = est._resolve_params(b_local, n, f) \
            if backend.takes_params else None
        chunk = make_batched_chunk(backend, params, est._cast, est.tol,
                                   n_steps)
        daxes = self._daxes

        def local_chunk(x, c, am, inertia, done, keys, it0):
            plan = ops.plan_data_batched(est._cast(x), params) \
                if backend.takes_params else est._cast(x)
            det0 = jnp.zeros((), jnp.int32)
            (c, am, inertia, done, det), live = chunk(
                plan, c, am, inertia, done, det0, keys, it0)
            return c, am, inertia, done, jax.lax.psum(det, daxes), live

        row = self._row
        return jax.jit(shard_map(
            local_chunk, mesh=self.mesh,
            in_specs=(P(row, None, None), P(row, None, None), P(row, None),
                      P(row), P(row), P(row, None), P()),
            out_specs=(P(row, None, None), P(row, None), P(row), P(row),
                       P(), P(None, row)),
            check_rep=False))

    def _fit_problems(self, xs: jax.Array, centroids: jax.Array,
                      max_iters: int, start_iteration: int,
                      checkpointer, checkpoint_interval: int):
        import numpy as np
        est = self.est
        bsz, n, f = xs.shape
        keys = est._problem_keys(bsz)     # problem b seeds from its global
        centroids = jnp.asarray(centroids, jnp.float32)     # index, so the
        am = jnp.zeros((bsz, n), jnp.int32)   # sharded fit matches the
        inertia = jnp.full((bsz,), jnp.inf, jnp.float32)   # single-device
        done = jnp.zeros((bsz,), jnp.bool_)                # one exactly
        iters = np.zeros((bsz,), np.int64)
        total_det = 0
        steps = {}
        it0 = start_iteration
        saved = False
        while it0 < max_iters:
            n_steps = min(est.sync_every, max_iters - it0)
            if n_steps not in steps:
                steps[n_steps] = self._build_step_problems(
                    bsz // self._dp, n, f, n_steps)
            centroids, am, inertia, done, det, live = steps[n_steps](
                xs, centroids, am, inertia, done, keys, jnp.int32(it0))
            done_h, live_h, det_h = _host_read((done, live, det))
            iters += live_h.sum(axis=0).astype(np.int64)
            total_det += int(det_h)
            it0 += n_steps
            saved = it0 % checkpoint_interval == 0
            if checkpointer is not None and saved:
                checkpointer.save(it0, {
                    "centroids": centroids,
                    "iteration": jnp.asarray(it0, jnp.int32)})
            if bool(done_h.all()):
                break
        if checkpointer is not None and not saved and it0 > start_iteration:
            checkpointer.save(it0, {
                "centroids": centroids,
                "iteration": jnp.asarray(it0, jnp.int32)})
        return centroids, am, inertia, np.maximum(iters, 1), total_det

    # -- driver --------------------------------------------------------------

    def fit(self, xs: jax.Array, centroids: jax.Array, *,
            max_iters: Optional[int] = None, start_iteration: int = 0,
            checkpointer=None, checkpoint_interval: int = 5):
        """Run Lloyd iterations on sharded data.

        Returns (centroids, assign, inertia, iterations, detected) —
        ``iterations`` counts completed iterations from zero, so a restart
        with ``start_iteration`` continues the same trajectory.

        Problem-axis mode (a :class:`~repro.batch.BatchedKMeans` was
        passed): ``xs`` is the (B, N, F) problem stack sharded over B,
        ``centroids`` the (B, K, F) stack, and the returned ``assign`` /
        ``inertia`` / ``iterations`` all carry the per-problem leading
        axis (``iterations`` is each problem's executed count).
        """
        import numpy as np
        est = self.est
        if self.problem_axis:
            return self._fit_problems(
                xs, centroids,
                max_iters if max_iters is not None else est.max_iter,
                start_iteration, checkpointer, checkpoint_interval)
        max_iters = max_iters if max_iters is not None else est.max_iter
        m, f = xs.shape
        if self._step is None:
            self._step = self._build_step(m // self._dp, f)
        shard_backend = self._shard_backend()
        if shard_backend.takes_injection:
            rng = est._campaign_rng()
            params = est._resolve_params(m // self._dp, f)
        from repro.core.fault import no_step_injection

        def no_injection():
            return no_step_injection(shard_backend.kernel_kind)

        centroids = jnp.asarray(centroids)
        am = jnp.zeros((m,), jnp.int32)
        inertia = jnp.asarray(jnp.inf)
        total_det = jnp.zeros((), jnp.int32)
        completed = start_iteration
        saved = False
        for it in range(start_iteration, max_iters):
            inj = no_injection()
            if shard_backend.takes_injection:
                inj = est._draw_injection(rng, m // self._dp, f, params)
            am, centroids, inertia, shift, det = self._step(
                xs, centroids, inj)
            total_det = total_det + det
            completed = it + 1
            saved = completed % checkpoint_interval == 0
            if checkpointer is not None and saved:
                checkpointer.save(completed, {
                    "centroids": centroids,
                    "iteration": jnp.asarray(completed, jnp.int32)})
            if float(_host_read(shift)) < est.tol:
                break
        if checkpointer is not None and not saved and \
                completed > start_iteration:
            # final durable snapshot: a run that converges (or crashes the
            # loop) between intervals must still be restartable
            checkpointer.save(completed, {
                "centroids": centroids,
                "iteration": jnp.asarray(completed, jnp.int32)})
        return centroids, am, inertia, completed, total_det
