"""minicpm-2b [dense] — llama-like, trained with the WSD schedule
(warmup-stable-decay; wired in train/optimizer.py via train_schedule)
[arXiv:2404.06395; hf]."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    layer_pattern=(ATTN,),
    mlp_act="silu",
)

TRAIN_SCHEDULE = "wsd"

SMOKE = ArchConfig(
    name="minicpm-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=509,   # deliberately unpadded (exercises vocab padding)
    layer_pattern=(ATTN,),
    mlp_act="silu",
    dtype="float32", param_dtype="float32",
)
