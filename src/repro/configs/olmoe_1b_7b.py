"""olmoe-1b-7b [moe] — 64 experts top-8, every layer MoE
[arXiv:2409.02060; hf]."""
from repro.configs.base import ArchConfig, MoEConfig, ATTN

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    layer_pattern=(ATTN,),
    moe=MoEConfig(num_experts=64, top_k=8),
    mlp_act="silu",
)

SMOKE = ArchConfig(
    name="olmoe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=32,
    vocab_size=512,
    layer_pattern=(ATTN,),
    moe=MoEConfig(num_experts=8, top_k=2),
    mlp_act="silu",
    dtype="float32", param_dtype="float32",
)
