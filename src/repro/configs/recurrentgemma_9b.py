"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2
[arXiv:2402.19427; unverified]. Pattern: (rglru, rglru, local-attn)
repeating; MQA (kv=1); sub-quadratic (RG-LRU state + bounded window)."""
from repro.configs.base import ArchConfig, ATTN_LOCAL, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    local_window=2048,
    rglru_width=4096,
    mlp_act="gelu",
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=4,  # one full period + remainder
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    local_window=16,
    rglru_width=64,
    mlp_act="gelu",
    subquadratic=True,
    dtype="float32", param_dtype="float32",
)
