"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, shared expert,
MoE on alternate layers (interleave 2, per HF config), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Pattern period = 2 ('attn','attn') so the MoE interleave aligns with the
scan slots (slot 0 dense FFN, slot 1 MoE — see models/model.py note)."""
from repro.configs.base import ArchConfig, MoEConfig, ATTN

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    layer_pattern=(ATTN, ATTN),
    moe=MoEConfig(num_experts=128, top_k=1, interleave=2,
                  shared_expert=True),
    mlp_act="silu",
    rope_theta=500_000.0,
    # 400B x (2+4+4)B/param does not fit 256 v5e chips; bf16 moments
    # (DESIGN.md §5 memory budget) bring params+opt to ~9.3 GiB/chip.
    opt_state_dtype="bfloat16",
    # 8-way microbatching: halves the remat activation stash again
    # (§Perf llama4 iteration 5) at the cost of more FSDP regathers.
    grad_accum_override=8,
)

SMOKE = ArchConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    layer_pattern=(ATTN, ATTN),
    moe=MoEConfig(num_experts=4, top_k=1, interleave=2, shared_expert=True),
    mlp_act="silu",
    dtype="float32", param_dtype="float32",
)
