"""Architecture registry: --arch <id> resolution for launchers/tests."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

_MODULES = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "whisper-medium": "repro.configs.whisper_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, *, smoke: bool = False, **overrides) -> ArchConfig:
    mod = importlib.import_module(_MODULES[arch_id])
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def train_schedule(arch_id: str) -> str:
    mod = importlib.import_module(_MODULES[arch_id])
    return getattr(mod, "TRAIN_SCHEDULE", "cosine")
