"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]. state N=128, expand 2, head dim 64.
Runs long_500k: O(1) state per token."""
from repro.configs.base import ArchConfig, SSM

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=(SSM,),
    ssm_state=128,
    ssm_expand=2,
    ssm_heads=64,          # inner 4096 / head dim 64
    conv_width=4,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    layer_pattern=(SSM,),
    ssm_state=16,
    ssm_expand=2,
    ssm_heads=2,
    conv_width=4,
    subquadratic=True,
    dtype="float32", param_dtype="float32",
)
