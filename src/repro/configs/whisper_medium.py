"""whisper-medium [audio] — encoder-decoder; conv frontend is a STUB
(input_specs supplies precomputed frame embeddings, 1500 frames)
[arXiv:2212.04356; unverified]. Decoder cells use the assigned shape's
seq_len structurally (whisper's real decoder caps at 448 — noted in
DESIGN.md); encoder positions are sinusoidal."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    layer_pattern=(ATTN,),
    mlp_act="gelu",
    encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    frontend="audio_stub",
)

SMOKE = ArchConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    layer_pattern=(ATTN,),
    mlp_act="gelu",
    encoder_decoder=True,
    encoder_layers=2,
    encoder_seq=30,
    frontend="audio_stub",
    dtype="float32", param_dtype="float32",
)
