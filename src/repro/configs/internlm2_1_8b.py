"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297; hf]."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_544,
    layer_pattern=(ATTN,),
    mlp_act="silu",
)

SMOKE = ArchConfig(
    name="internlm2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    layer_pattern=(ATTN,),
    mlp_act="silu",
    dtype="float32", param_dtype="float32",
)
