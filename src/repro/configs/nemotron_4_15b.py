"""nemotron-4-15b [dense] — GQA, squared-ReLU (ungated) MLP
[arXiv:2402.16819; unverified]."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256_000,
    layer_pattern=(ATTN,),
    mlp_act="relu2",
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="nemotron-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    layer_pattern=(ATTN,),
    mlp_act="relu2",
    tie_embeddings=False,
    dtype="float32", param_dtype="float32",
)
