"""gemma3-4b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]. Sub-quadratic-enough for the
long_500k decode cell: 5/6 of layers read a 1024-token ring buffer; the
global layers are linear-in-S cache reads at decode (DESIGN.md §4)."""
from repro.configs.base import ArchConfig, ATTN, ATTN_LOCAL

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262_144,
    head_dim=256,
    layer_pattern=(ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL,
                   ATTN_LOCAL, ATTN),
    local_window=1024,
    mlp_act="gelu",
    rope_theta=1_000_000.0,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=7,   # one period + 1 remainder layer
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    layer_pattern=(ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL,
                   ATTN_LOCAL, ATTN),
    local_window=16,
    mlp_act="gelu",
    subquadratic=True,
    dtype="float32", param_dtype="float32",
)
