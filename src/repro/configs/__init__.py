from repro.configs.base import (ArchConfig, MoEConfig, ShapeConfig, SHAPES,
                                input_specs, shape_applicable)
from repro.configs.registry import ARCH_IDS, get_config, train_schedule

__all__ = ["ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES", "input_specs",
           "shape_applicable", "ARCH_IDS", "get_config", "train_schedule"]
