"""Architecture + shape configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; every benchmark
shape is a ``ShapeConfig``. ``input_specs(arch, shape)`` produces the
ShapeDtypeStruct stand-ins the dry-run lowers against (weak-type-correct,
shardable, no allocation) — modality frontends are stubs per the
assignment: audio/vision cells receive precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# Block kinds the model factory understands.
ATTN = "attn"            # global self-attention
ATTN_LOCAL = "attn_local"
RGLRU = "rglru"          # RecurrentGemma recurrent block
SSM = "ssd"              # Mamba-2 SSD block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    interleave: int = 1          # MoE every `interleave` layers (1 = all)
    shared_expert: bool = False  # llama4-style shared expert


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    layer_pattern: tuple = (ATTN,)   # repeating block pattern
    local_window: int = 1024
    mlp_act: str = "silu"         # silu | gelu | relu2 (squared relu)
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0            # mamba2 state size N
    ssm_heads: int = 0
    ssm_expand: int = 2
    rglru_width: int = 0          # recurrence width (= d_model usually)
    conv_width: int = 4           # temporal conv for ssm/rglru blocks
    rope_theta: float = 10_000.0
    mrope_sections: tuple = ()    # qwen2-vl M-RoPE (t, h, w) split
    encoder_decoder: bool = False # whisper
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper frames after conv stub
    frontend: str = "none"        # none | vision_stub | audio_stub
    num_patches: int = 256        # vlm stub patch count
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # numerics / FT
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    abft: bool = False            # ABFT-protect dense projections
    # subquadratic? (decides long_500k applicability)
    subquadratic: bool = False
    # training
    remat: bool = True
    scan_layers: bool = True
    grad_accum_override: int = 0   # 0 = shape-based default

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 for clean 'model'-axis sharding + MXU lanes
        (MaxText-style padding; loss masks the padded slots)."""
        return ((self.vocab_size + 255) // 256) * 256

    def pattern_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6 N D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = (self.num_heads * hd + 2 * self.num_kv_heads * hd) * d \
            + self.num_heads * hd * d
        dense_mlp = 3 * d * f
        total = 0
        layers = self.num_layers
        for i in range(layers):
            kind = self.pattern_for_layer(i)
            if kind in (ATTN, ATTN_LOCAL):
                total += attn
            elif kind == RGLRU:
                w = self.rglru_width or d
                total += 2 * d * w + w * d + 3 * w + self.conv_width * w
            elif kind == SSM:
                inner = self.ssm_expand * d
                nheads = self.ssm_heads or (inner // 64)
                # in_proj d x (z, x, B, C, dt) + out_proj + conv (see ssm.py)
                total += d * (2 * inner + 2 * self.ssm_state + nheads) \
                    + inner * d \
                    + self.conv_width * (inner + 2 * self.ssm_state) \
                    + 3 * nheads + inner
            if kind in (ATTN, ATTN_LOCAL, RGLRU):
                if self.moe and (i % self.moe.interleave == self.moe.interleave - 1):
                    total += self.moe.num_experts * dense_mlp
                    if self.moe.shared_expert:
                        total += dense_mlp
                else:
                    total += dense_mlp
            total += 2 * d          # norms
        total += v * d              # embed
        if not self.tie_embeddings:
            total += v * d
        if self.encoder_decoder:
            enc = self.encoder_layers * (attn + dense_mlp + 2 * d)
            total += enc + self.encoder_layers * attn  # cross-attn in decoder
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE-aware) for 6 N_active D."""
        if not self.moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = 3 * d * f
        inactive = 0
        for i in range(self.num_layers):
            if self.pattern_for_layer(i) in (ATTN, ATTN_LOCAL, RGLRU):
                if self.moe and (i % self.moe.interleave == self.moe.interleave - 1):
                    inactive += (self.moe.num_experts - self.moe.top_k) * dense_mlp
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? Returns (ok, reason)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("pure full-attention arch: 500k-token decode has no "
                       "sub-quadratic path (DESIGN.md §4)")
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                *, batch_override: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    emb_dtype = jnp.dtype(arch.dtype)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if arch.frontend == "vision_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.num_patches, arch.d_model), emb_dtype)
        if arch.frontend == "audio_stub":
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.encoder_seq, arch.d_model), emb_dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if arch.frontend == "vision_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.num_patches, arch.d_model), emb_dtype)
        if arch.frontend == "audio_stub":
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.encoder_seq, arch.d_model), emb_dtype)
        return specs
    # decode: one new token against a cache of length s
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    if arch.frontend == "audio_stub":
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (b, arch.encoder_seq, arch.d_model), emb_dtype)
    return specs
