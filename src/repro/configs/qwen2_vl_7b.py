"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution
[arXiv:2409.12191; hf]. Vision frontend is a STUB per the assignment:
input_specs supplies precomputed patch embeddings (early fusion over the
first num_patches positions); M-RoPE sections (16, 24, 24) rotate the
temporal/height/width position streams."""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    layer_pattern=(ATTN,),
    mlp_act="silu",
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
    num_patches=256,
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2vl-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    layer_pattern=(ATTN,),
    mlp_act="silu",
    mrope_sections=(2, 3, 3),
    frontend="vision_stub",
    num_patches=16,
    dtype="float32", param_dtype="float32",
)
