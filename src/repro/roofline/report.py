"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run result cache.

    PYTHONPATH=src python -m repro.roofline.report [results/dryrun]
"""
from __future__ import annotations

import os
import sys

from repro.roofline.analysis import analyze, load_records


def gib(b):
    return f"{(b or 0) / 2**30:.2f}"


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | status | args/dev GiB | temp/dev GiB "
        "| flops/dev | HBM bytes/dev | coll bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| {r['status']}: {reason} | | | | | | |")
            continue
        m, c = r["memory"], r["cost"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {gib(m['argument_bytes'])} | {gib(m['temp_bytes'])} "
            f"| {c['flops']:.3g} | {c['bytes_accessed']:.3g} "
            f"| {r['collective_bytes']:.3g} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(records) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s "
        "| bottleneck | useful (6ND/HLO) | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        r = analyze(rec)
        if r is None:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | {rec['status']} | — | — |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3g} "
            f"| {r.memory_s:.3g} | {r.collective_s:.3g} | {r.bottleneck} "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.2f} |")
    return "\n".join(lines)


def main():
    results = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    results = os.path.abspath(results)
    for tag in ("pod16x16", "pod2x16x16"):
        records = load_records(results, tag)
        print(f"\n### Dry-run — mesh {tag}\n")
        print(dryrun_table(records))
        print(f"\n### Roofline — mesh {tag}\n")
        print(roofline_table(records))


if __name__ == "__main__":
    main()
