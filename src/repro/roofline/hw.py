"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 2
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
ICI_LINKS = 4                   # v5e: 4 ICI links per chip (2D torus x2)
HBM_BYTES = 16 * 2**30          # 16 GiB
VMEM_BYTES = 128 * 2**20
