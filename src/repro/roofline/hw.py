"""TPU v5e hardware constants for the roofline model (per chip).

The numbers live in :mod:`repro.hw` — the single copy shared with the
autotune selection model — and are re-exported here for the roofline
modules' historical import path.
"""
from repro.hw import (HBM_BW, HBM_BYTES, ICI_LINK_BW, ICI_LINKS,
                      PEAK_FLOPS_BF16, PEAK_FLOPS_F32, VMEM_BYTES)

__all__ = ["PEAK_FLOPS_BF16", "PEAK_FLOPS_F32", "HBM_BW", "ICI_LINK_BW",
           "ICI_LINKS", "HBM_BYTES", "VMEM_BYTES"]
