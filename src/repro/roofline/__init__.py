from repro.roofline import hw, hlo, analysis

__all__ = ["hw", "hlo", "analysis"]
