"""Three-term roofline from the dry-run artifacts (per arch x shape x mesh).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links x link_bw)

(cost_analysis() reports per-device, post-partitioning numbers on the SPMD
program — verified in tests/test_roofline.py.) The bottleneck is the max
term; "roofline fraction" = bottleneck / total-if-perfectly-overlapped =
max / sum, i.e. how close the step is to its own bound if compute, HBM and
ICI fully overlap.

MODEL_FLOPS = 6 * N * D (dense train) / 6 * N_active * D (MoE), or
2 * N * D for inference; the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Optional

from repro.roofline import hw


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_fraction: float     # max / sum of the three terms

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.compute_s:.3e} | {self.memory_s:.3e} "
                f"| {self.collective_s:.3e} | {self.bottleneck} "
                f"| {self.useful_ratio:.2f} | {self.roofline_fraction:.2f} |")


def tokens_of(shape_name: str, record: dict) -> int:
    from repro.configs import SHAPES
    s = SHAPES[shape_name]
    if s.kind == "train" or s.kind == "prefill":
        return s.seq_len * s.global_batch
    return s.global_batch           # decode: one token per sequence


def model_flops(record: dict) -> float:
    """6ND train / 2ND inference, with N = active params (from the live
    config, so param-count fixes don't require re-running the sweep)."""
    from repro.configs import SHAPES, get_config
    s = SHAPES[record["shape"]]
    try:
        n = get_config(record["arch"]).active_param_count()
    except Exception:
        n = record["active_params"]
    toks = tokens_of(record["shape"], record)
    mult = 6.0 if s.kind == "train" else 2.0
    return mult * n * toks


def analyze(record: dict, *, dtype_peak: float = hw.PEAK_FLOPS_BF16,
            ici_links: int = hw.ICI_LINKS) -> Optional[Roofline]:
    if record.get("status") != "ok":
        return None
    flops_dev = record["cost"]["flops"] or 0.0
    bytes_dev = record["cost"]["bytes_accessed"] or 0.0
    coll_dev = record["collective_bytes"] or 0.0
    compute_s = flops_dev / dtype_peak
    memory_s = bytes_dev / hw.HBM_BW
    coll_s = coll_dev / (ici_links * hw.ICI_LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    total = sum(terms.values()) or 1.0
    mf = model_flops(record)
    chips = record["chips"]
    useful = mf / max(flops_dev * chips, 1.0)
    return Roofline(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops=mf,
        hlo_flops=flops_dev * chips, useful_ratio=useful,
        roofline_fraction=max(terms.values()) / total)


def load_records(results_dir: str, mesh_tag: str = "pod16x16") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir,
                                              f"*__{mesh_tag}.json"))):
        with open(path) as fh:
            out.append(json.load(fh))
    return out


def markdown_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bottleneck | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        r = analyze(rec)
        if r is None:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                         f"| — | — | — | {rec['status']} | — | — |")
        else:
            lines.append(r.table_row())
    return "\n".join(lines)
