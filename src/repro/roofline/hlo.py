"""Optimized-HLO analysis: loop-aware flops / bytes / collective census.

``compiled.cost_analysis()`` counts every computation ONCE — a scan body
executed 24 times contributes 1/24 of its true cost (verified in
tests/test_roofline.py). This module re-derives the three roofline inputs
from ``compiled.as_text()`` with while-loop trip multiplication:

  * flops        — 2 * prod(dot output dims) * prod(contracted dims),
                   summed over `dot` ops, times the product of enclosing
                   while trip counts (``backend_config known_trip_count``);
                   elementwise flops are not counted (MXU roofline term).
  * bytes        — per top-level op: output + operand bytes (fusion
                   boundaries are materialization boundaries in optimized
                   HLO), same loop multiplication. Pure-aliasing ops
                   (bitcast, get-tuple-element, parameter, tuple, constant)
                   count zero.
  * collectives  — {kind: {count, bytes}} with loop multiplication;
                   bytes = per-device output payload of each op.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")

_ALIAS_OPS = ("bitcast", "get-tuple-element", "parameter", "tuple",
              "constant", "after-all", "copy-done", "copy-start")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"\(((?:%?[\w.\-]+(?:,\s*)?)*)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "shape", "op", "line")

    def __init__(self, name, shape, op, line):
        self.name = name
        self.shape = shape
        self.op = op
        self.line = line


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.defs: dict[str, Instr] = {}
        self.entry: str | None = None
        current = None
        for raw in text.splitlines():
            stripped = raw.strip()
            # computation header: '%name (params...) -> ret {' / 'ENTRY %...'
            if stripped.endswith("{") and "->" in stripped and \
                    (stripped.startswith("%") or stripped.startswith("ENTRY")):
                tok = stripped.split()[1 if stripped.startswith("ENTRY") else 0]
                current = tok.lstrip("%")
                self.computations[current] = []
                if stripped.startswith("ENTRY"):
                    self.entry = current
                continue
            m = _DEF_RE.match(raw)
            if m and current is not None:
                instr = Instr(m.group(1), m.group(2), m.group(3), raw)
                self.computations[current].append(instr)
                self.defs[instr.name] = instr

    # -- per-instruction costs ------------------------------------------------

    def _dot_flops(self, instr: Instr) -> float:
        out_elems = shape_elems(instr.shape)
        m = _LHS_CONTRACT_RE.search(instr.line)
        contract = 1
        if m:
            idxs = [int(i) for i in m.group(1).split(",") if i]
            lhs_name = self._operands(instr.line)
            if lhs_name:
                lhs = self.defs.get(lhs_name[0])
                if lhs is not None:
                    dims = shape_dims(lhs.shape)
                    for i in idxs:
                        if i < len(dims):
                            contract *= dims[i]
        return 2.0 * out_elems * contract

    def _operands(self, line: str) -> list[str]:
        # operands of the op: first (...) after the op name
        m = _DEF_RE.match(line)
        if not m:
            return []
        rest = line[m.end():]
        depth = 1
        args = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        # operand uses are %-prefixed in optimized dumps ("f32[8]{0} %x");
        # naive comma-splitting breaks on layout braces like {1,0}
        named = re.findall(r"%([\w.\-]+)", args)
        if named:
            return named
        return [a.strip() for a in args.split(",")
                if a.strip() and not a.strip()[0].isdigit()]

    def _trip_count(self, instr: Instr) -> float:
        """While trip count: backend_config known_trip_count when present
        (TPU), else recovered from the `i < N` condition of the canonical
        scan lowering (CPU dumps omit the annotation)."""
        t = _TRIP_RE.search(instr.line)
        if t:
            return float(t.group(1))
        cond = _COND_RE.search(instr.line)
        if cond:
            for ci in self.computations.get(cond.group(1), []):
                if ci.op != "compare" or "direction=LT" not in ci.line:
                    continue
                for name in self._operands(ci.line):
                    bound = self.defs.get(name)
                    if bound is not None and bound.op == "constant":
                        m = re.search(r"constant\((\d+)\)", bound.line)
                        if m:
                            return float(m.group(1))
        return 1.0

    def _instr_bytes(self, instr: Instr) -> int:
        """Materialization-traffic model: every non-alias op's output is
        written once and read ~once by its consumers -> 2x output bytes.
        (Counting operands too would double-count every intermediate —
        validated against analytic traffic in tests/test_roofline.py.)"""
        if instr.op in _ALIAS_OPS:
            return 0
        return 2 * shape_bytes(instr.shape)

    # -- recursive, loop-aware traversal ---------------------------------------

    def analyze(self) -> dict:
        memo: dict[str, tuple] = {}

        def visit(comp_name: str):
            if comp_name in memo:
                return memo[comp_name]
            flops = 0.0
            bytes_ = 0.0
            coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
            for instr in self.computations.get(comp_name, []):
                mult = 1.0
                if instr.op == "while":
                    mult = self._trip_count(instr)
                    body = _CALL_RE.search(instr.line)
                    if body:
                        f, b, c = visit(body.group(1))
                        flops += mult * f
                        bytes_ += mult * b
                        for k, v in c.items():
                            coll[k]["count"] += mult * v["count"]
                            coll[k]["bytes"] += mult * v["bytes"]
                    cond = _COND_RE.search(instr.line)
                    if cond:
                        f, b, c = visit(cond.group(1))
                        flops += mult * f
                        bytes_ += mult * b
                    continue
                if instr.op in ("fusion", "call", "conditional", "map"):
                    callee = _CALL_RE.search(instr.line)
                    if callee:
                        f, b, c = visit(callee.group(1))
                        flops += f
                        for k, v in c.items():
                            coll[k]["count"] += v["count"]
                            coll[k]["bytes"] += v["bytes"]
                    bytes_ += self._instr_bytes(instr)
                    continue
                base = instr.op.replace("-start", "")
                if base in COLL_KINDS and not instr.op.endswith("-done"):
                    coll[base]["count"] += 1
                    coll[base]["bytes"] += shape_bytes(instr.shape)
                    bytes_ += self._instr_bytes(instr)
                    continue
                if instr.op in ("dot", "convolution"):
                    flops += self._dot_flops(instr)
                bytes_ += self._instr_bytes(instr)
            memo[comp_name] = (flops, bytes_, dict(coll))
            return memo[comp_name]

        # fusions called from the entry are visited through their call sites;
        # start at entry.
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
        f, b, c = visit(self.entry)
        return {"flops": f, "bytes": b,
                "collectives": {k: dict(v) for k, v in c.items()}}


def analyze_hlo(text: str) -> dict:
    return HloModule(text).analyze()


def count_collectives(hlo_text: str) -> dict:
    """Loop-aware collective census {kind: {count, bytes}}."""
    return analyze_hlo(hlo_text)["collectives"]


def collective_bytes(census: dict) -> float:
    return sum(v["bytes"] for v in census.values())
