"""The assembled k-means serving stack: compiler + batcher + store.

:class:`KMeansService` is the estimator -> serving handoff
(``KMeans.to_service()``): it freezes the fitted model's predict backend
into AOT-compiled bucketed cells (:class:`ServeCompiler`), funnels
requests through a :class:`MicroBatcher`, and reads centroids from a
versioned :class:`CodebookStore` so background refinement can
``publish`` without pausing inference. Each micro-batch captures one
codebook at flush time — every request in the batch is answered from a
single consistent centroid version, recorded on its result.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve.batcher import MicroBatcher
from repro.serve.compiler import DEFAULT_BUCKETS, ServeCompiler
from repro.serve.store import CodebookStore


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One request's answer: per-row labels and true squared distances
    (host numpy views of the micro-batch readback — see the batcher's
    ``_host_read``), the backend's fault-detection counter for the
    micro-batch this request shared, and the codebook version it was
    served from."""

    labels: np.ndarray
    sq_dists: np.ndarray
    detected: np.ndarray
    version: int


class KMeansService:
    """Online predict service over a fitted k-means model.

    ``predict`` enqueues a ``(rows, F)`` request and blocks on its
    ticket; with the background window loop running (``start``) requests
    from many threads coalesce into shared launches, otherwise each
    ``predict`` flushes synchronously (deterministic — the test and
    benchmark mode). ``publish`` hot-swaps centroids; ``refine`` runs one
    ``partial_fit`` step on recent traffic and publishes the result.
    """

    def __init__(self, compiler: ServeCompiler, store: CodebookStore, *,
                 window_s: float = 0.0,
                 estimator: Optional[Any] = None,
                 on_dispatch: Optional[Callable] = None) -> None:
        if compiler.n_clusters != store.current().shape[0] \
                or compiler.n_features != store.current().shape[1]:
            raise ValueError(
                f"compiler cells are ({compiler.n_clusters}, "
                f"{compiler.n_features}), store serves "
                f"{store.current().shape}")
        self.compiler = compiler
        self.store = store
        self.estimator = estimator
        # observation seam: called with the captured codebook after each
        # flush pins its version, before the kernel launch — the hook the
        # hot-swap tests use to publish mid-flight, and a metrics
        # tap-in point in production
        self._on_dispatch = on_dispatch
        self.batcher = MicroBatcher(self._dispatch, window_s=window_s)

    @classmethod
    def from_estimator(cls, estimator: Any, *,
                       buckets: Optional[tuple[int, ...]] = None,
                       window_s: Optional[float] = None,
                       on_dispatch: Optional[Callable] = None,
                       ) -> "KMeansService":
        """Build the serving stack from a fitted :class:`~repro.api.KMeans`
        (the usual entry is ``KMeans.to_service()``). Bucket ladder and
        window default to the tuned plan persisted in the estimator's
        autotune cache (``tuning.plan_ladder``), falling back to
        ``DEFAULT_BUCKETS`` and a zero window."""
        centroids = estimator.cluster_centers_
        k, f = centroids.shape
        backend = estimator._predict_backend()
        if buckets is None or window_s is None:
            plan = estimator.autotune.lookup_ladder(
                k, f, dtype=estimator.compute_dtype)
            if buckets is None:
                buckets = plan[0] if plan else DEFAULT_BUCKETS
            if window_s is None:
                window_s = plan[1] * 1e-6 if plan else 0.0
        compiler = ServeCompiler(backend, k, f, buckets=buckets,
                                 dtype=estimator.compute_dtype,
                                 autotune=estimator.autotune,
                                 params=estimator.params)
        return cls(compiler, CodebookStore(centroids), window_s=window_s,
                   estimator=estimator, on_dispatch=on_dispatch)

    # -- request path ------------------------------------------------------

    def _dispatch(self, batch: Any) -> tuple:
        cb = self.store.current()   # pin the version for this whole batch
        if self._on_dispatch is not None:
            self._on_dispatch(cb)
        am, md, det = self.compiler.dispatch(batch, cb.centroids)
        return am, md, det, cb.version

    def predict(self, x: Any) -> ServeResult:
        """Serve one ``(rows, F)`` request (rows may be zero)."""
        ticket = self.batcher.submit(x)
        if not self.batcher.running:
            self.batcher.flush()
        am, md, det, version = ticket.result()
        return ServeResult(am, md, det, version)

    def start(self) -> None:
        """Run the micro-batch window loop (concurrent serving mode)."""
        self.batcher.start()

    def stop(self) -> None:
        self.batcher.stop()

    # -- refinement / hot-swap ---------------------------------------------

    def publish(self, centroids: Any) -> int:
        """Hot-swap: make ``centroids`` the current codebook. In-flight
        micro-batches finish on the version they captured; returns the
        new version."""
        return self.store.publish(centroids).version

    def refine(self, x: Any) -> int:
        """One background refinement step: ``partial_fit`` the wrapped
        estimator on recent traffic ``x`` and publish the moved
        centroids. Inference never pauses — this runs concurrently with
        ``predict`` by construction of the store."""
        if self.estimator is None:
            raise ValueError(
                "service was built without an estimator (plain "
                "ServeCompiler + CodebookStore); publish() refined "
                "centroids directly instead")
        self.estimator.partial_fit(x)
        return self.publish(self.estimator.cluster_centers_)

    # -- serialization boundary --------------------------------------------

    def get_state(self) -> dict:
        """Host-side snapshot: the codebook store (bit-identical round
        trip) plus the serving configuration; the wrapped estimator
        serializes through its own ``get_state`` when present."""
        return {
            "store": self.store.get_state(),
            "config": {
                "backend": self.compiler.backend.name,
                "n_clusters": self.compiler.n_clusters,
                "n_features": self.compiler.n_features,
                "buckets": list(self.compiler.buckets),
                "dtype": self.compiler.dtype.name,
                "window_us": self.batcher.window_s * 1e6,
            },
            "estimator": (None if self.estimator is None
                          else self.estimator.get_state()),
        }

    @classmethod
    def from_state(cls, state: dict) -> "KMeansService":
        from repro.api.registry import get_backend
        cfg = state["config"]
        estimator = None
        if state.get("estimator") is not None:
            from repro.api.estimator import KMeans
            estimator = KMeans.from_state(state["estimator"])
        store = CodebookStore.from_state(state["store"])
        compiler = ServeCompiler(
            get_backend(cfg["backend"]), cfg["n_clusters"],
            cfg["n_features"], buckets=tuple(cfg["buckets"]),
            dtype=jnp.dtype(cfg["dtype"]),
            autotune=None if estimator is None else estimator.autotune,
            params=None if estimator is None else estimator.params)
        return cls(compiler, store, window_s=cfg["window_us"] * 1e-6,
                   estimator=estimator)


__all__ = ["KMeansService", "ServeResult"]
