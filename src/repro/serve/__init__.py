"""Online serving layer: AOT-compiled bucketed predict, dynamic
micro-batching, versioned centroid hot-swap.

The ROADMAP's production framing (assignment-heavy traffic against
slowly-evolving centroids — the Flash-KMeans regime) needs three things
the training-side library does not provide: requests must never pay
trace-or-compile latency (:class:`ServeCompiler` — every ``(bucket,
variant)`` predict cell is compiled ahead of time and the recompile gate
proves zero warm compiles), concurrent small requests must share kernel
launches (:class:`MicroBatcher` — dispatch overhead is per launch, not
per request), and centroid refreshes must not pause inference
(:class:`CodebookStore` — immutable versioned codebooks, captured per
micro-batch). :class:`KMeansService` assembles the three behind
``KMeans.to_service()``; ``tuning.plan_ladder`` picks the bucket ladder
and batching window from the autotune model (``serve`` kind, cache
schema v7). See docs/serving.md.
"""
from repro.serve.batcher import MicroBatcher, Ticket
from repro.serve.compiler import DEFAULT_BUCKETS, ServeCompiler
from repro.serve.service import KMeansService, ServeResult
from repro.serve.store import Codebook, CodebookStore
from repro.serve.tuning import ServePlan, plan_ladder

__all__ = [
    "Codebook", "CodebookStore", "DEFAULT_BUCKETS", "KMeansService",
    "MicroBatcher", "ServeCompiler", "ServePlan", "ServeResult", "Ticket",
    "plan_ladder",
]
