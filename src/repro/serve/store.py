"""Versioned centroid store — the serving side of the hot-swap protocol.

A serving process has two loops touching the centroids: the inference
path reads them on every micro-batch, and a background refinement loop
(``KMeans.partial_fit`` on recent traffic) wants to move them. Guarding a
mutable array with a lock would stall every request behind a refinement
step; instead the store holds *immutable* versioned codebooks and
``publish`` swaps an atomic reference. Readers capture one
:class:`Codebook` at micro-batch flush time and finish on it — an
in-flight batch never sees a torn or half-updated centroid set, and the
next batch picks up the new version without any pause (docs/serving.md,
"hot-swap protocol").
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Codebook:
    """One immutable published centroid set: ``(K, F)`` f32 on device,
    tagged with its monotonically increasing version."""

    version: int
    centroids: jax.Array

    @property
    def shape(self) -> tuple[int, int]:
        return (self.centroids.shape[0], self.centroids.shape[1])


class CodebookStore:
    """Thread-safe history of published codebooks.

    ``publish`` is the only mutation: it freezes the given centroids as a
    new :class:`Codebook` under the next version and makes it current.
    ``current()`` is a lock-protected reference read — O(1), never blocks
    on the device — so the inference path can call it per flush. A bounded
    window of past versions (``keep``) stays retrievable for batches that
    captured them mid-swap; serving state round-trips bit-identically
    through ``get_state``/``from_state``.
    """

    def __init__(self, centroids: Any, *, keep: int = 8,
                 _version: int = 1) -> None:
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self._keep = keep
        self._lock = threading.Lock()
        self._history: dict[int, Codebook] = {}
        self._current = Codebook(_version,
                                 jnp.asarray(centroids, jnp.float32))
        self._history[_version] = self._current

    def publish(self, centroids: Any) -> Codebook:
        """Freeze ``centroids`` as the next version and make it current.
        Batches already in flight keep the codebook they captured; the
        next flush serves this one."""
        frozen = jnp.asarray(centroids, jnp.float32)
        cur = self._current
        if frozen.shape != cur.centroids.shape:
            raise ValueError(
                f"published centroids have shape {frozen.shape}, store "
                f"serves {cur.centroids.shape}; the predict cells are "
                f"AOT-compiled for one (K, F) — a model-shape change is a "
                f"new service, not a hot-swap")
        with self._lock:
            cb = Codebook(self._current.version + 1, frozen)
            self._history[cb.version] = cb
            self._current = cb
            while len(self._history) > self._keep:
                del self._history[min(self._history)]
            return cb

    def current(self) -> Codebook:
        """The codebook new batches should capture."""
        with self._lock:
            return self._current

    def get(self, version: int) -> Codebook:
        """A specific retained version (KeyError once evicted)."""
        with self._lock:
            try:
                return self._history[version]
            except KeyError:
                raise KeyError(
                    f"codebook version {version} not retained (window "
                    f"keeps {self._keep}; have "
                    f"{sorted(self._history)})") from None

    @property
    def versions(self) -> tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._history))

    # -- serialization boundary (host transfer is the job here) ------------

    def get_state(self) -> dict:
        """Host-side snapshot: every retained version's centroids as f32
        numpy arrays plus the current version. ``from_state`` rebuilds a
        store whose codebooks are bit-identical."""
        with self._lock:
            history = dict(self._history)
            cur = self._current.version
        return {
            "keep": self._keep,
            "current": cur,
            "codebooks": {str(v): np.asarray(cb.centroids, np.float32)
                          for v, cb in history.items()},
        }

    @classmethod
    def from_state(cls, state: dict) -> "CodebookStore":
        versions = sorted(int(v) for v in state["codebooks"])
        if not versions:
            raise ValueError("store state holds no codebooks")
        store = cls(state["codebooks"][str(versions[0])],
                    keep=state["keep"], _version=versions[0])
        for v in versions[1:]:
            cb = Codebook(v, jnp.asarray(state["codebooks"][str(v)],
                                         jnp.float32))
            store._history[v] = cb
        cur = int(state["current"])
        store._current = store._history[cur]
        return store


__all__ = ["Codebook", "CodebookStore"]
