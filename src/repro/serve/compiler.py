"""AOT-compiled bucketed predict cells.

Online predict traffic has arbitrary per-request row counts, but jit
caches are keyed by exact shapes: serving raw request shapes means a
trace-and-compile stall on every new row count — seconds of latency on a
microsecond request. The fix is the same discretization the autotune
table uses for problem shapes: requests are padded up to a small ladder
of row-count *buckets*, and every ``(bucket, variant)`` predict cell is
lowered and compiled ahead of time via ``jax.jit(...).lower().compile()``
before the service accepts traffic. A request can only ever hit a
precompiled executable, so no request pays trace-or-compile latency —
the invariant ``repro.analysis.recompile`` verifies with zero warm (and
cold!) compiles across the registered cell set.

Centroids enter each cell as a runtime argument, not a compile-time
constant: a :class:`~repro.serve.store.CodebookStore` hot-swap therefore
never triggers recompilation — the new codebook just flows into the same
executables.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

# Default row-count ladder: geometric with ratio 4 — adjacent-bucket
# padding wastes at most 4x rows while keeping the compiled-cell count
# (and AOT compile time) small. ``repro.serve.tuning.plan_ladder`` tunes
# this per model shape.
DEFAULT_BUCKETS: tuple[int, ...] = (128, 512, 2048)


class ServeCompiler:
    """Ahead-of-time compiled predict cells for one model shape.

    Given a model's ``(K, F)``, compute dtype and assignment backend (the
    backend-variant axis: the same registry objects the estimator
    dispatches through), compiles one executable per row bucket at
    construction. ``dispatch`` routes a request to the smallest bucket
    that fits, padding with zero rows and slicing the pad back off;
    requests larger than the top bucket are chunked through it, so device
    allocation is bounded by the largest bucket regardless of request
    size.
    """

    def __init__(self, backend: Any, n_clusters: int, n_features: int, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 dtype: Any = jnp.float32,
                 autotune: Optional[Any] = None,
                 params: Optional[ops.KernelParams] = None,
                 in_dtype: Any = jnp.float32) -> None:
        if not buckets:
            raise ValueError("need at least one row bucket")
        sizes = tuple(sorted(
            {int(b) for b in buckets}))  # analysis: allow=host-sync — config
        if sizes[0] < 1:
            raise ValueError(f"buckets must be positive, got {buckets}")
        self.backend = backend
        self.n_clusters = int(n_clusters)  # analysis: allow=host-sync
        self.n_features = int(n_features)  # analysis: allow=host-sync
        self.buckets = sizes
        self.dtype = jnp.dtype(dtype)
        self.in_dtype = jnp.dtype(in_dtype)
        self._autotune = autotune
        self._params = params
        self._cells: dict[int, Any] = {}
        for b in sizes:
            self._cells[b] = self._compile_cell(b)

    # -- compilation (construction time only) ------------------------------

    def _bucket_params(self, bucket: int) -> Optional[ops.KernelParams]:
        """Tile winner for one bucket shape from the ``serve`` autotune
        kind — bucket-shaped cells are their own tuning regime (the
        dispatch constant in their score is first-order at these sizes)."""
        if not self.backend.takes_params:
            return None
        p = self._params
        if p is None:
            if self._autotune is None:
                from repro.api.cache import default_cache
                cache = default_cache()
            else:
                cache = self._autotune
            _, p = cache.lookup(bucket, self.n_clusters, self.n_features,
                                kind="serve", dtype=self.dtype)
        return ops.clamp_params(bucket, self.n_clusters, self.n_features,
                                p, dtype=self.dtype)

    def _cell_fn(self, p: Optional[ops.KernelParams]) -> Callable:
        backend, dtype = self.backend, self.dtype

        def cell(x: jax.Array, c: jax.Array) -> tuple:
            return backend(x.astype(dtype), c.astype(dtype), params=p)

        return cell

    def _compile_cell(self, bucket: int) -> Any:
        """``jit -> lower -> compile`` one predict cell at the bucket's
        exact input shapes. The returned executable accepts only those
        shapes — the discretization that makes zero-compile serving
        checkable rather than hoped-for."""
        p = self._bucket_params(bucket)
        x_s = jax.ShapeDtypeStruct((bucket, self.n_features), self.in_dtype)
        c_s = jax.ShapeDtypeStruct((self.n_clusters, self.n_features),
                                   jnp.float32)
        return jax.jit(self._cell_fn(p)).lower(x_s, c_s).compile()

    def cell(self, bucket: int) -> Any:
        """The compiled executable for one registered bucket."""
        return self._cells[bucket]

    # -- request routing ---------------------------------------------------

    def bucket_for(self, rows: int) -> int:
        """Smallest bucket holding ``rows`` (callers chunk above the top
        bucket; see ``dispatch``)."""
        for b in self.buckets:
            if rows <= b:
                return b
        return self.buckets[-1]

    def _pad_rows(self, x: Any, rows: int) -> Any:
        """Pad with zero rows up to the bucket. Host-side (numpy) inputs
        pad on the host — one memcpy, no device round-trip before the
        single device transfer the compiled call itself performs."""
        if x.dtype != self.in_dtype:
            x = x.astype(self.in_dtype)
        pad = rows - x.shape[0]
        if pad == 0:
            return x
        if isinstance(x, np.ndarray):
            return np.concatenate(
                [x, np.zeros((pad, x.shape[1]), x.dtype)], axis=0)
        return jnp.concatenate(
            [x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)

    def dispatch(self, x: Any, centroids: jax.Array) -> tuple:
        """Route one ``(m, F)`` request batch through the compiled cells.

        Returns ``(assign (m,) i32, sq-dist (m,) f32, detected i32)`` —
        the backend's uniform predict triple. ``m = 0`` returns empty
        outputs without touching the device; ``m`` beyond the top bucket
        runs bounded chunks of it.
        """
        m, f = x.shape
        if f != self.n_features:
            raise ValueError(f"request has {f} features, cells are "
                             f"compiled for {self.n_features}")
        if m == 0:
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32),
                    jnp.zeros((), jnp.int32))
        top = self.buckets[-1]
        if m > top:
            outs = [self.dispatch(x[i:i + top], centroids)
                    for i in range(0, m, top)]
            am = jnp.concatenate([o[0] for o in outs])
            md = jnp.concatenate([o[1] for o in outs])
            det = jnp.sum(jnp.stack([o[2] for o in outs]), axis=0)
            return am, md, det
        bucket = self.bucket_for(m)
        am, md, det = self._cells[bucket](self._pad_rows(x, bucket),
                                          centroids)
        return am[:m], md[:m], det


__all__ = ["ServeCompiler", "DEFAULT_BUCKETS"]
