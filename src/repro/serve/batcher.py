"""Dynamic micro-batching request queue.

At serving row counts the per-launch dispatch cost
(``hw.DISPATCH_OVERHEAD_S``) rivals the kernel itself, so N concurrent
requests dispatched naively pay it N times — the same failure mode
``repro.batch`` fixes for many-problem *fits*, here fixed for predict
*requests*. The :class:`MicroBatcher` coalesces whatever arrived inside
the batching window into one row-concatenated batch, hands it to a
single dispatch call (one padded-bucket kernel launch through
:class:`~repro.serve.compiler.ServeCompiler`), and scatters the result
rows back to each caller's ticket.

The batcher is generic over the dispatch function: any callable taking
the concatenated ``(rows, ...)`` batch and returning a tuple whose
row-shaped entries scatter per request (other entries — version tags,
detection counters — fan out to every ticket unchanged). That is what
lets the LM demo launcher (``repro.launch.serve``) and the k-means
service share one queue implementation.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _host_read(value: Any) -> Any:
    """The batcher's one sanctioned device->host sync: reading the
    *completed* batch back before the scatter. Results are leaving for
    the callers anyway, and per-ticket device-side slicing would pay one
    eager dispatch per request per output — more dispatches than the
    naive path micro-batching exists to avoid. Host-side numpy slices
    are views: the whole scatter costs one transfer."""
    return jax.device_get(value)


class Ticket:
    """One submitted request's future result (thread-safe)."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._value: Optional[tuple] = None
        self._error: Optional[BaseException] = None

    def _resolve(self, value: tuple) -> None:
        self._value = value
        self._event.set()

    def _reject(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> tuple:
        """Block until the request's micro-batch flushed; returns the
        scattered dispatch tuple for this request's rows."""
        if not self._event.wait(timeout):
            raise TimeoutError("micro-batch not flushed within timeout")
        if self._error is not None:
            raise self._error
        assert self._value is not None
        return self._value


def _concat_rows(xs: Sequence[Any]) -> Any:
    """Row-concatenate request payloads. All-host (numpy) requests
    assemble on the host — one memcpy, leaving the single device transfer
    to the compiled cell call — mixed/device requests concatenate on
    device."""
    if len(xs) == 1:
        return xs[0]
    if all(isinstance(x, np.ndarray) for x in xs):
        return np.concatenate(xs, axis=0)
    return jnp.concatenate([jnp.asarray(x) for x in xs], axis=0)


class MicroBatcher:
    """Coalesce concurrent requests into one dispatch call.

    ``submit`` enqueues a ``(rows, ...)`` payload and returns a
    :class:`Ticket`. ``flush`` drains the queue: one ``dispatch`` call on
    the concatenation, one host readback, then per-request numpy row
    views resolve the tickets.
    Call ``flush`` directly for deterministic single-threaded serving
    (tests, benchmarks), or ``start()`` a background loop that flushes
    ``window_s`` after each first arrival — the window is the latency the
    slowest-arriving request pays to share a launch, tuned alongside the
    bucket ladder by ``repro.serve.tuning.plan_ladder``.
    """

    def __init__(self, dispatch: Callable[[Any], tuple], *,
                 window_s: float = 0.0) -> None:
        self._dispatch = dispatch
        self.window_s = window_s
        self._cond = threading.Condition()
        self._pending: list[tuple[Any, Ticket]] = []
        self._thread: Optional[threading.Thread] = None
        self._stopping = False

    # -- queue -------------------------------------------------------------

    def submit(self, x: Any) -> Ticket:
        if x.ndim != 2:
            raise ValueError(f"requests are (rows, features) batches, got "
                             f"shape {tuple(x.shape)}")
        ticket = Ticket()
        with self._cond:
            self._pending.append((x, ticket))
            self._cond.notify_all()
        return ticket

    def flush(self) -> int:
        """Serve everything queued right now; returns the request count."""
        with self._cond:
            pending, self._pending = self._pending, []
        if not pending:
            return 0
        xs = [x for x, _ in pending]
        rows = [x.shape[0] for x in xs]
        total = sum(rows)
        try:
            out_h = _host_read(tuple(self._dispatch(_concat_rows(xs))))
        except BaseException as e:
            for _, ticket in pending:
                ticket._reject(e)
            raise
        offset = 0
        for (_, ticket), n in zip(pending, rows):
            ticket._resolve(tuple(
                o[offset:offset + n]
                if getattr(o, "ndim", 0) >= 1 and o.shape[0] == total
                else o
                for o in out_h))
            offset += n
        return len(pending)

    # -- background window loop --------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> None:
        if self.running:
            return
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-serve-microbatch",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the window loop, flushing anything still queued."""
        thread = self._thread
        if thread is None:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        thread.join()
        self._thread = None
        self.flush()

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._stopping:
                    self._cond.wait()
                if self._stopping:
                    return
            if self.window_s > 0:
                time.sleep(self.window_s)   # coalescing horizon
            try:
                self.flush()
            except Exception:
                # the tickets of the failed batch carry the error; the
                # loop keeps serving subsequent batches
                pass


__all__ = ["MicroBatcher", "Ticket"]
