"""Serving-plan autotuning: bucket ladder + micro-batch window.

The serving layer has two knobs the kernel autotuner does not cover: the
row-count *bucket ladder* (more buckets = less padding waste but more
compiled cells, and more of the small launches whose dispatch cost the
``serve`` model charges per call) and the *batching window* (the latency
a request pays to share a launch). Both are planned from the same
analytical model the tile selection uses — ``model_score(kind="serve")``
is the assign score plus ``hw.DISPATCH_OVERHEAD_S`` — by minimizing the
expected per-row cost over a log-uniform request-size distribution, the
same "discrete sizes share a winner" granularity as the paper's shape
table. Plans persist as schema-v7 ladder pseudo-entries of the autotune
cache (``AutotuneCache.put_ladder``) next to the per-bucket tile winners
they were scored with.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import jax.numpy as jnp

from repro.core import autotune
from repro.kernels import ops
from repro.serve.compiler import DEFAULT_BUCKETS


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """One tuned serving configuration for a model shape: the row-bucket
    ladder, the micro-batch window (µs), and the per-bucket tile winners
    the ladder was scored with."""

    buckets: tuple[int, ...]
    window_us: float
    winners: dict[int, tuple[str, ops.KernelParams]]


def _ladder_cost(ladder: tuple[int, ...], cost: dict[int, float],
                 sizes: tuple[int, ...]) -> float:
    """Expected one-request cost over the size distribution: each request
    runs the smallest bucket that fits it, oversize requests chunk
    through the top bucket."""
    total = 0.0
    top = ladder[-1]
    for r in sizes:
        if r > top:
            total += -(-r // top) * cost[top]
            continue
        total += cost[min(b for b in ladder if b >= r)]
    return total / len(sizes)


def plan_ladder(n_clusters: int, n_features: int, *,
                dtype: Any = jnp.float32,
                cache: Optional[Any] = None,
                min_rows: int = 64, max_rows: int = 4096,
                max_cells: int = 4,
                mode: str = "model") -> ServePlan:
    """Pick the bucket ladder and window for one model shape.

    Candidates are the powers of two in ``[min_rows, max_rows]``; every
    ladder of at most ``max_cells`` of them containing the top candidate
    (so oversize chunking stays bounded) is scored with
    :func:`_ladder_cost` and the cheapest wins. The window is set to the
    modeled launch time of the ladder's top bucket — coalescing longer
    than one full launch adds latency the launch can no longer amortize.
    If ``cache`` is given, the plan and its per-bucket winners persist
    (``serve`` kind, schema v7) for ``KMeansService.from_estimator`` to
    find.
    """
    candidates = []
    b = 1
    while b < min_rows:
        b *= 2
    while b <= max_rows:
        candidates.append(b)
        b *= 2
    if not candidates:
        raise ValueError(f"no power-of-two buckets in "
                         f"[{min_rows}, {max_rows}]")
    winners: dict[int, tuple[str, ops.KernelParams]] = {}
    cost: dict[int, float] = {}
    for size in candidates:
        variant, p = autotune.select_params(size, n_clusters, n_features,
                                            mode=mode, dtype=dtype,
                                            kind="serve")
        winners[size] = (variant, p)
        cost[size] = autotune.model_score(size, n_clusters, n_features, p,
                                          dtype=dtype, kind="serve",
                                          variant=variant)
    # log-uniform request sizes: serving traffic spans single-row probes
    # to bulk scoring; a linear grid would drown the small sizes that
    # make ladders matter
    sizes = []
    r = 1
    while r <= 2 * max_rows:
        sizes.append(r)
        r *= 2
    top = candidates[-1]
    best: Optional[tuple[int, ...]] = None
    best_cost = float("inf")
    for n in range(1, max_cells + 1):
        for combo in itertools.combinations(candidates, n):
            if combo[-1] != top:
                continue
            c = _ladder_cost(combo, cost, tuple(sizes))
            if c < best_cost:
                best, best_cost = combo, c
    assert best is not None
    ladder = best
    window_us = cost[ladder[-1]] * 1e6
    plan = ServePlan(ladder, window_us,
                     {size: winners[size] for size in ladder})
    if cache is not None:
        for size in ladder:
            variant, p = winners[size]
            cache.put(size, n_clusters, n_features, p, kind="serve",
                      dtype=dtype, variant=variant)
        cache.put_ladder(n_clusters, n_features, buckets=ladder,
                         window_us=window_us, dtype=dtype)
    return plan


__all__ = ["ServePlan", "plan_ladder", "DEFAULT_BUCKETS"]
