"""General ABFT-protected GEMM kernel (paper §IV applied to plain matmul).

D = X @ Y with the dual-checksum invariant fused into the tile loop, the
same scheme as ``distance_argmin_ft`` but writing the full (corrected)
product — this is the kernel behind ``repro.ft.abft_dense`` (fault-tolerant
projections inside the LM stack) and the paper's standalone ABFT-GEMM
comparison (Wu et al. [41] baseline modernized for asynchronous-copy-era
hardware).

Grid: (M/bm, N/bn, K/bk), contraction innermost, VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.kernels.distance_argmin_ft import (INJ_LEN, make_injection,  # noqa: F401 — re-export
                                              no_injection,
                                              threshold_factor)


def _kernel(inj_ref, x_ref, y_ref, out_ref, det_ref,
            acc_ref, col1_ref, col2_ref, row1_ref, row2_ref):
    m_idx = pl.program_id(0)
    n_idx = pl.program_id(1)
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)
    bm, bn = acc_ref.shape
    bk = x_ref.shape[1]

    @pl.when(jnp.logical_and(n_idx == 0, k_idx == 0))
    def _init_det():
        det_ref[...] = jnp.zeros_like(det_ref)

    @pl.when(k_idx == 0)
    def _init_scratch():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        col1_ref[...] = jnp.zeros_like(col1_ref)
        col2_ref[...] = jnp.zeros_like(col2_ref)
        row1_ref[...] = jnp.zeros_like(row1_ref)
        row2_ref[...] = jnp.zeros_like(row2_ref)

    x = x_ref[...]
    y = y_ref[...]
    acc_ref[...] += jnp.dot(x, y, preferred_element_type=jnp.float32)

    w_m = jax.lax.broadcasted_iota(jnp.float32, (bm, 1), 0) + 1.0
    w_n = jax.lax.broadcasted_iota(jnp.float32, (1, bn), 1) + 1.0
    e1x = jnp.sum(x, axis=0, keepdims=True)                 # (1, bk)
    e2x = jnp.sum(w_m * x, axis=0, keepdims=True)           # (1, bk)
    ye1 = jnp.sum(y, axis=1, keepdims=True)                 # (bk, 1)
    ye2 = jnp.sum(y * w_n, axis=1, keepdims=True)           # (bk, 1)
    col1_ref[...] += jnp.dot(e1x, y, preferred_element_type=jnp.float32)
    col2_ref[...] += jnp.dot(e2x, y, preferred_element_type=jnp.float32)
    row1_ref[...] += jnp.dot(x, ye1, preferred_element_type=jnp.float32)
    row2_ref[...] += jnp.dot(x, ye2, preferred_element_type=jnp.float32)

    hit = jnp.logical_and(
        inj_ref[0] > 0,
        jnp.logical_and(
            jnp.logical_and(m_idx == inj_ref[1], n_idx == inj_ref[2]),
            k_idx == inj_ref[3]))

    @pl.when(hit)
    def _inject():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        mask = jnp.logical_and(rows == inj_ref[4], cols == inj_ref[5])
        delta = jax.lax.bitcast_convert_type(inj_ref[6], jnp.float32)
        acc_ref[...] += jnp.where(mask, delta, 0.0)

    @pl.when(k_idx == nk - 1)
    def _verify_and_write():
        acc = acc_ref[...]
        obs_col1 = jnp.sum(acc, axis=0, keepdims=True)
        obs_col2 = jnp.sum(w_m * acc, axis=0, keepdims=True)
        obs_row1 = jnp.sum(acc, axis=1, keepdims=True)
        obs_row2 = jnp.sum(w_n * acc, axis=1, keepdims=True)

        res_col1 = obs_col1 - col1_ref[...]
        res_col2 = obs_col2 - col2_ref[...]
        res_row1 = obs_row1 - row1_ref[...]
        res_row2 = obs_row2 - row2_ref[...]

        # static grid -> trace-time constant factor; eps is dtype-aware
        # (input rounding of the main accumulator for bf16/fp16 tiles).
        # Scale from the expected checksums (clean invariant side), never
        # the possibly-corrupted accumulator — see distance_argmin_ft.
        scale = jnp.maximum(jnp.maximum(jnp.max(jnp.abs(col1_ref[...])),
                                        jnp.max(jnp.abs(row1_ref[...]))), 1.0)
        thr = jnp.float32(threshold_factor(nk * bk, x_ref.dtype)) * scale

        detected = jnp.logical_or(jnp.max(jnp.abs(res_col1)) > thr,
                                  jnp.max(jnp.abs(res_row1)) > thr)

        j = jnp.argmax(jnp.abs(res_col1[0, :])).astype(jnp.int32)
        delta_col = res_col1[0, j]
        i_direct = jnp.argmax(jnp.abs(res_row1[:, 0])).astype(jnp.int32)
        safe = jnp.where(delta_col == 0.0, 1.0, delta_col)
        i_ratio = (jnp.round(res_col2[0, j] / safe) - 1.0).astype(jnp.int32)
        use_ratio = jnp.abs(delta_col) > thr
        i = jnp.clip(jnp.where(use_ratio, i_ratio, i_direct), 0, bm - 1)
        delta_row = res_row1[i, 0]
        delta = jnp.where(jnp.abs(delta_col) > jnp.abs(delta_row),
                          delta_col, delta_row)
        safe_r = jnp.where(delta_row == 0.0, 1.0, delta_row)
        j_ratio = (jnp.round(res_row2[i, 0] / safe_r) - 1.0).astype(jnp.int32)
        j = jnp.where(use_ratio, j, jnp.clip(j_ratio, 0, bn - 1))

        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        corrected = acc - jnp.where(
            jnp.logical_and(rows == i, cols == j), delta, 0.0)
        out_ref[...] = jnp.where(detected, corrected, acc).astype(out_ref.dtype)
        det_ref[...] += detected.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def matmul_abft(
    x: jax.Array,
    y: jax.Array,
    inj: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """ABFT GEMM. Returns (D corrected (M, N), det counts (m_tiles, 1))."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n, k // block_k)

    kernel = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_n), lambda i, j, t: (t, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m // block_m, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((1, block_n), jnp.float32),
            pltpu.VMEM((1, block_n), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(inj, x, y)
