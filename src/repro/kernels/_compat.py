"""Version compatibility for the Pallas TPU surface.

The TPU compiler-parameter dataclass was renamed between JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this
installation provides so the kernels lower on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # pragma: no cover - depends on installed jax
    CompilerParams = pltpu.TPUCompilerParams
