"""Tile-granular triangle-inequality pruned one-pass Lloyd kernel.

Late Lloyd iterations reassign almost nothing: once clusters separate, a
row's nearest centroid rarely changes, yet the unpruned one-pass kernel
(:mod:`lloyd_step`) still pays the full distance GEMM against every
centroid tile every iteration. This variant carries Hamerly-style bounds
between iterations and skips whole ``block_k`` centroid tiles that
provably cannot change any assignment in the row tile:

  * per row ``r``: an upper bound ``ub_r`` on the Euclidean distance to
    its currently assigned centroid (refreshed exactly each computed
    iteration, grown by the assigned centroid's drift otherwise);
  * per (row tile ``i``, centroid tile ``j``): ``tmin[i, j]``, the
    minimum over valid rows of the row's Euclidean distance to its
    nearest centroid *in that tile* — a weak lower bound that holds for
    every row of the tile simultaneously, which is what makes
    tile-granular (rather than per-row) skipping sound;
  * per centroid tile ``j``: the maximum drift of its centroids since
    the bounds were recorded.

The host-side wrapper (``ops.fused_lloyd_pruned``) decays ``tmin`` by the
tile drift into a lower bound ``tlb`` and compares it against the row
tile's worst-case upper bound ``maxub[i] = max_r (ub_r +
drift[assign_r])``. A tile is skipped iff ``tlb[i, j] > maxub[i]`` (plus
a small fp-safety slack): every row's distance to every centroid of the
tile is then *strictly* greater than that row's distance to its current
centroid, so the tile can neither win the min nor tie it — the fold is
bit-identical to the unpruned kernel's by omission. The tile containing
a row's assigned centroid always satisfies ``tlb <= maxub`` and is never
skipped, so the min/argmin is always grounded.

The kernel itself receives the precomputed ``skip`` mask as a (1, 1)
block per (row tile, centroid tile) grid cell and gates the MXU product
and the min epilogue on it; the X stash and the fused one-hot update
epilogue (shared with :mod:`lloyd_step`) run unconditionally, so sums and
counts are produced exactly as before. For computed tiles the kernel
refreshes ``tmin`` from the freshly accumulated distances; for skipped
tiles the wrapper substitutes the decayed bound.

Tile granularity, not row granularity: the MXU consumes (bm, bk) tiles —
masking individual rows would still issue the full tile product, so the
only skip the TPU can actually exploit is a whole centroid tile per row
tile. That is also why bounds are reduced to per-tile scalars: the skip
decision must be uniform across the tile.

``"smallk"`` shapes (padded K == one centroid tile) cannot prune — the
sole tile always contains every assigned centroid — so the smallk
variant computes everything and only emits the ``tmin`` refresh to keep
the bounds state warm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.distance_argmin import MIN_INIT, fold_min, tile_min_argmin
from repro.kernels.lloyd_step import (STASH_SLOTS, _emit_update,
                                      _stash_dma_start, _stash_dma_wait_last)


def _tile_bound(meta_ref, xn_ref, local_min, m_idx, bm):
    """Euclidean group bound for one computed tile: min over *valid* rows
    of sqrt(max(partial_min + ||x||^2, 0)). Padded rows are excluded so a
    zero padding row cannot poison the bound downward (that would only
    cost prune rate, never correctness, but it costs a lot of it)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + m_idx * bm
    valid = rows < meta_ref[0]
    row_e = jnp.sqrt(jnp.maximum(local_min + xn_ref[...], 0.0))
    return jnp.min(jnp.where(valid, row_e, MIN_INIT), axis=0, keepdims=True)


def _kernel_pruned(meta_ref, x_ref, c_ref, cn_ref, xn_ref, skip_ref,
                   mind_ref, argmin_ref, sums_ref, counts_ref, tmin_ref,
                   acc_ref, xbuf_ref, sem_ref):
    """One (bm, bk) tile of the pruned one-pass iteration.

    meta_ref  : (1,)        SMEM — [true_m]
    x_ref     : (bm, bf)    sample tile
    c_ref     : (bk, bf)    centroid tile
    cn_ref    : (1, bk)     centroid squared norms (+inf for padded slots)
    xn_ref    : (bm, 1)     row squared norms (0 for padded rows)
    skip_ref  : (1, 1)      i32 — 1 iff this (row tile, centroid tile)
                            cell is pruned this iteration
    mind_ref  : (bm, 1)     running minimum of d_ij  (output, revisited)
    argmin_ref: (bm, 1)     running argmin           (output, revisited)
    sums_ref  : (1, kp, fp) per-row-tile partial cluster sums (output)
    counts_ref: (1, kp)     per-row-tile partial cluster counts (output)
    tmin_ref  : (1, 1)      refreshed Euclidean group bound (output)
    acc_ref   : (bm, bk)    VMEM scratch accumulator for X C^T
    xbuf_ref  : (bm, fp)    VMEM stash of the row tile's feature chunks
    sem_ref   : (2,)        DMA semaphores for the double-buffered stash
    """
    m_idx = pl.program_id(0)
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nk = pl.num_programs(1)
    nf = pl.num_programs(2)
    bm = acc_ref.shape[0]
    bf = x_ref.shape[1]
    live = skip_ref[0, 0] == 0

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # Skipped tiles never reach the epilogue; the wrapper substitutes
        # the decayed bound, so the placeholder value is never read.
        tmin_ref[...] = jnp.full_like(tmin_ref, MIN_INIT)

    # The stash is unconditional: the fused update epilogue needs every
    # feature chunk regardless of which centroid tiles were pruned. Async,
    # overlapping whatever this step computes (even a fully pruned step
    # still pays the stash — it is the update's data, not the GEMM's).
    @pl.when(c_idx == 0)
    def _stash_x():
        _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf)

    # The entire point: no MXU product for pruned tiles.
    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(live, f_idx == nf - 1))
    def _min_epilogue():
        local_min, local_arg = tile_min_argmin(
            acc_ref[...], cn_ref[...], c_idx * acc_ref.shape[1])
        fold_min(mind_ref, argmin_ref, local_min, local_arg)
        tmin_ref[...] = _tile_bound(meta_ref, xn_ref, local_min, m_idx, bm)

    # The update epilogue is unconditional: a skipped last tile still
    # finalizes the row tile's argmin (skipping only omits losing folds).
    @pl.when(jnp.logical_and(c_idx == nk - 1, f_idx == nf - 1))
    def _update_epilogue():
        _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf)
        _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                     m_idx, bm)


def _kernel_smallk_pruned(meta_ref, x_ref, c_ref, cn_ref, xn_ref, skip_ref,
                          mind_ref, argmin_ref, sums_ref, counts_ref,
                          tmin_ref, acc_ref, xbuf_ref, sem_ref):
    """Small-K pruned path: padded K is one centroid tile, grid (M/bm,
    F/bf). A single tile always contains every row's assigned centroid,
    so it can never be skipped — the wrapper forces ``skip`` to zero and
    this kernel ignores it, computing the full smallk sweep plus the
    ``tmin`` refresh that keeps the bounds state warm."""
    del skip_ref  # single-tile shapes cannot prune (see module docstring)
    m_idx = pl.program_id(0)
    f_idx = pl.program_id(1)
    nf = pl.num_programs(1)
    bm = acc_ref.shape[0]
    bf = x_ref.shape[1]

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(acc_ref[...], cn_ref[...], 0)
        mind_ref[...] = local_min       # single visit: direct write
        argmin_ref[...] = local_arg
        tmin_ref[...] = _tile_bound(meta_ref, xn_ref, local_min, m_idx, bm)
        _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf)
        _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                     m_idx, bm)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "variant", "interpret"))
def lloyd_step_pruned(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    xn: jax.Array,
    meta: jax.Array,
    skip: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    variant: str = "generic",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Raw pruned one-pass kernel entry. Shapes pre-padded to the grid.

    x (M, F) samples, c (K, F) centroids (f32/bf16/fp16), cn (1, K) f32
    centroid sq-norms with +inf in padded slots, xn (M, 1) f32 row
    sq-norms (0 in padded rows), meta (1,) int32 = [true_m], skip
    (M/bm, K/bk) int32 tile mask (1 = prune this cell; must be all zero
    for the ``"smallk"`` variant, whose skip shape is (M/bm, 1)).
    Returns (min_d (M, 1), argmin (M, 1), sums (M/bm, K, F), counts
    (M/bm, K), tmin (M/bm, K/bk)); tmin entries of skipped cells are a
    MIN_INIT placeholder — the caller substitutes the decayed bound.
    """
    m, f = x.shape
    k = c.shape[0]
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0, (
        f"unpadded shapes {(m, k, f)} vs blocks {(block_m, block_k, block_f)}")
    num_m = m // block_m
    num_k = k // block_k if variant == "generic" else 1

    out_shape = [
        jax.ShapeDtypeStruct((m, 1), jnp.float32),
        jax.ShapeDtypeStruct((m, 1), jnp.int32),
        jax.ShapeDtypeStruct((num_m, k, f), jnp.float32),
        jax.ShapeDtypeStruct((num_m, k), jnp.float32),
        jax.ShapeDtypeStruct((num_m, num_k), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((block_m, block_k), jnp.float32),
        pltpu.VMEM((block_m, f), x.dtype),   # stash in the input dtype
        pltpu.SemaphoreType.DMA((STASH_SLOTS,)),
    ]

    if variant == "smallk":
        assert k == block_k, (
            f"smallk variant needs padded K ({k}) == block_k ({block_k})")
        assert skip.shape == (num_m, 1), (
            f"smallk skip shape {skip.shape} != {(num_m, 1)}")
        kernel = pl.pallas_call(
            _kernel_smallk_pruned,
            grid=(m // block_m, f // block_f),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((block_m, block_f), lambda i, t: (i, t)),
                pl.BlockSpec((block_k, block_f), lambda i, t: (0, t)),
                pl.BlockSpec((1, block_k), lambda i, t: (0, 0)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((1, k, f), lambda i, t: (i, 0, 0)),
                pl.BlockSpec((1, k), lambda i, t: (i, 0)),
                pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )
        return kernel(meta, x, c, cn, xn, skip)

    assert variant == "generic", f"unknown kernel variant {variant!r}"
    assert skip.shape == (num_m, num_k), (
        f"skip shape {skip.shape} != {(num_m, num_k)}")
    kernel = pl.pallas_call(
        _kernel_pruned,
        grid=(m // block_m, k // block_k, f // block_f),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, k, f), lambda i, j, t: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, j)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(meta, x, c, cn, xn, skip)
