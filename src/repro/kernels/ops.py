"""Public jit'd wrappers around the Pallas kernels.

Handles: padding to the block grid (masked so results are exact), parameter
selection via the autotune table (the paper's code-generation/selection
pipeline), interpret-mode fallback on non-TPU backends, and injection
planning helpers for fault campaigns.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import distance_argmin as _da
from repro.kernels import distance_argmin_ft as _daft
from repro.kernels import matmul_abft as _mma


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Tile parameters — the analogue of the paper's (threadblock, warp)
    CUTLASS parameter group. Thread-level tiles are Mosaic's job on TPU."""

    block_m: int = 256
    block_k: int = 128   # centroid tile (paper's Threadblock.N)
    block_f: int = 512   # contraction tile (paper's Threadblock.K)

    def vmem_bytes(self) -> int:
        """Working-set estimate: x + c tiles (double-buffered) + acc + sums."""
        tile = (self.block_m * self.block_f + self.block_k * self.block_f) * 4
        acc = self.block_m * self.block_k * 4
        sums = 2 * (self.block_m + self.block_k) * 4
        return 2 * tile + acc + sums


DEFAULT_PARAMS = KernelParams()


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_inputs(x, c, params: KernelParams):
    m, f = x.shape
    k = c.shape[0]
    mp = _round_up(m, params.block_m)
    kp = _round_up(k, params.block_k)
    fp = _round_up(f, params.block_f)
    xpad = jnp.pad(x, ((0, mp - m), (0, fp - f)))
    cpad = jnp.pad(c, ((0, kp - k), (0, fp - f)))
    cn = jnp.sum(cpad.astype(jnp.float32) ** 2, axis=1)
    # padded centroid slots must never win the argmin
    slot = jnp.arange(kp)
    cn = jnp.where(slot < k, cn, jnp.inf)[None, :]
    return xpad, cpad, cn


def clamp_params(m: int, k: int, f: int, params: KernelParams) -> KernelParams:
    """Shrink blocks that exceed the (padded) problem so tiny shapes work."""
    def shrink(block, dim, align):
        while block > align and block > _round_up(dim, align):
            block //= 2
        return max(block, align)
    return KernelParams(
        block_m=shrink(params.block_m, m, 8),
        block_k=shrink(params.block_k, k, 128),
        block_f=shrink(params.block_f, f, 128),
    )


def fused_assign(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment via the fused kernel.

    Returns (assign (M,) int32, partial min distance (M,) f32). Add
    ``sum(x**2, -1)`` for true squared distances.
    """
    if params is None:
        from repro.api.cache import default_cache
        params = default_cache().lookup(x.shape[0], c.shape[0], x.shape[1])
    params = clamp_params(x.shape[0], c.shape[0], x.shape[1], params)
    if interpret is None:
        interpret = not on_tpu()
    m = x.shape[0]
    xp, cp, cn = _pad_inputs(x, c, params)
    mind, am = _da.distance_argmin(
        xp, cp, cn, block_m=params.block_m, block_k=params.block_k,
        block_f=params.block_f, interpret=interpret)
    return am[:m, 0], mind[:m, 0]


def fused_assign_ft(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    inj: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FT assignment: fused ABFT detect+locate+correct inside the kernel.

    Returns (assign, partial min distance, corrected_error_count).
    """
    if params is None:
        from repro.api.cache import default_cache
        params = default_cache().lookup(x.shape[0], c.shape[0], x.shape[1])
    params = clamp_params(x.shape[0], c.shape[0], x.shape[1], params)
    if interpret is None:
        interpret = not on_tpu()
    if inj is None:
        inj = _daft.no_injection()
    m = x.shape[0]
    xp, cp, cn = _pad_inputs(x, c, params)
    mind, am, det = _daft.distance_argmin_ft(
        xp, cp, cn, inj, block_m=params.block_m, block_k=params.block_k,
        block_f=params.block_f, interpret=interpret)
    return am[:m, 0], mind[:m, 0], jnp.sum(det)


def abft_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    inj: Optional[jax.Array] = None,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """ABFT GEMM D = X @ Y with in-kernel correction. Returns (D, det_count)."""
    if interpret is None:
        interpret = not on_tpu()
    m, k = x.shape
    n = y.shape[1]
    p = clamp_params(m, n, k, KernelParams(block_m, block_n, block_k))
    bm, bn, bk = p.block_m, p.block_k, p.block_f
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    if inj is None:
        inj = _mma.no_injection()
    d, det = _mma.matmul_abft(
        xp, yp, inj, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return d[:m, :n], jnp.sum(det)


def plan_injection_tile(m: int, k: int, f: int, params: KernelParams,
                        row: int, col: int, f_step: int,
                        delta: float) -> jax.Array:
    """Translate a global (row, col) error position into tile coordinates."""
    params = clamp_params(m, k, f, params)
    return _daft.make_injection(
        row // params.block_m, col // params.block_k,
        f_step % max(f // params.block_f, 1),
        row % params.block_m, col % params.block_k, delta)
