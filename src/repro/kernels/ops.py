"""Public jit'd wrappers around the Pallas kernels.

Handles: padding to the block grid (masked so results are exact), parameter
selection via the autotune table (the paper's code-generation/selection
pipeline), interpret-mode fallback on non-TPU backends, and injection
planning helpers for fault campaigns.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.extend import core as jex_core

from repro.dist.compression import quantize_rows as _quantize_rows
from repro.kernels import distance_argmin as _da
from repro.kernels import distance_argmin_ft as _daft
from repro.kernels import distance_argmin_int8 as _dai
from repro.kernels import kmeanspp_init as _kpi
from repro.kernels import lloyd_step as _ll
from repro.kernels import lloyd_step_ft as _llft
from repro.kernels import lloyd_step_pruned as _llp
from repro.kernels import matmul_abft as _mma


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Kernel template variants (paper §III-B template family). "generic" keeps
# the centroid grid dimension and accumulates min/argmin in the revisited
# output block; "smallk" drops it when padded K fits one block_k tile.
VARIANTS = ("generic", "smallk")


def sublane_align(dtype: Any) -> int:
    """Minimum second-to-last-dimension tile multiple for a dtype: TPU
    packs 2-byte dtypes two-per-sublane (bf16/fp16 tiles need 16 rows where
    f32 needs 8) and 1-byte dtypes four-per-sublane (int8 needs 32)."""
    size = jnp.dtype(dtype).itemsize
    if size == 1:
        return 32
    return 16 if size == 2 else 8


def _itemsize(dtype: Any) -> int:
    return jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Tile parameters — the analogue of the paper's (threadblock, warp)
    CUTLASS parameter group. Thread-level tiles are Mosaic's job on TPU."""

    block_m: int = 256
    block_k: int = 128   # centroid tile (paper's Threadblock.N)
    block_f: int = 512   # contraction tile (paper's Threadblock.K)

    def vmem_bytes(self, dtype: Any = jnp.float32) -> int:
        """Working-set estimate: x + c tiles (double-buffered, input dtype)
        + f32 accumulator + f32 norm/checksum vectors."""
        b = _itemsize(dtype)
        tile = (self.block_m * self.block_f + self.block_k * self.block_f) * b
        acc = self.block_m * self.block_k * 4
        sums = 2 * (self.block_m + self.block_k) * 4
        return 2 * tile + acc + sums


DEFAULT_PARAMS = KernelParams()


def lloyd_vmem_bytes(params: KernelParams, k: int, f: int,
                     dtype: Any = jnp.float32) -> int:
    """Working-set estimate for the one-pass Lloyd kernel: the assignment
    kernel's tiles plus the stashed X row tile (input dtype) and the f32
    per-row-tile sums/counts output blocks (resident across the sweep)."""
    kp = _round_up(k, params.block_k)
    fp = _round_up(f, params.block_f)
    xbuf = params.block_m * fp * _itemsize(dtype)
    out_blocks = (kp * fp + kp) * 4
    return params.vmem_bytes(dtype) + xbuf + out_blocks


def lloyd_ft_vmem_bytes(params: KernelParams, k: int, f: int,
                        dtype: Any = jnp.float32) -> int:
    """Working-set estimate for the one-pass FT kernel: the one-pass
    kernel's footprint (``KernelParams.vmem_bytes`` already budgets the
    e1/e2 checksum vectors) plus the resident expected-checksum output
    blocks of the update epilogue."""
    fp = _round_up(f, params.block_f)
    return lloyd_vmem_bytes(params, k, f, dtype) + (2 * fp + 2) * 4


def lloyd_batched_vmem_bytes(params: KernelParams, k: int, f: int,
                             dtype: Any = jnp.float32) -> int:
    """Working-set estimate for the batched one-pass kernel: one problem's
    tiles are resident at a time (the problem axis is the outermost grid
    dimension), so the footprint is the smallk one-pass working set with
    padded K as the single centroid tile — ``block_k`` is not a knob."""
    b = _itemsize(dtype)
    kp = _round_up(k, 128)
    fp = _round_up(f, params.block_f)
    tile = (params.block_m * params.block_f + kp * params.block_f) * b
    acc = params.block_m * kp * 4
    xbuf = params.block_m * fp * b
    out_blocks = (kp * fp + kp) * 4
    sums = 2 * (params.block_m + kp) * 4
    return 2 * tile + acc + xbuf + out_blocks + sums


def pruned_vmem_bytes(params: KernelParams, k: int, f: int,
                      dtype: Any = jnp.float32) -> int:
    """Working-set estimate for the pruned one-pass kernel: the one-pass
    footprint plus the double-buffered (bm, 1) f32 row-norm input block
    and the scalar skip/tmin blocks (a (1, 1) i32 input and a (1, 1) f32
    output per grid cell)."""
    return (lloyd_vmem_bytes(params, k, f, dtype)
            + 2 * params.block_m * 4 + 3 * 4)


def int8_vmem_bytes(params: KernelParams) -> int:
    """Working-set estimate for the int8 distance template: 1-byte x/c
    tiles plus the f32 scale vectors and centroid norms (double-buffered
    inputs), the f32/i32 min/argmin output blocks and the int32 accumulator
    scratch. Input dtype is fixed (int8 tiles, f32 epilogue operands), so
    unlike the f32 family this model takes no dtype."""
    bm, bk, bf = params.block_m, params.block_k, params.block_f
    ins = bm * bf + bk * bf + 4 * bm + 8 * bk   # x, c int8; sx, sc, cn f32
    outs = 8 * bm                               # mind f32 + argmin i32
    scr = 4 * bm * bk                           # int32 accumulator
    return 2 * ins + outs + scr


def init_vmem_bytes(params: KernelParams, f: int) -> int:
    """Working-set estimate for the fused k-means++ round kernel: one
    (bn, fp) f32 sample tile with its norms and d² vectors plus the single
    resident centroid row (double-buffered inputs), and the updated d² and
    tile-sum output blocks. Features are lane-padded and fully resident
    (F is not a grid axis), so the model depends on ``f``; ``block_k`` and
    ``block_f`` are not axes of this kernel at all."""
    bn = max(128, params.block_m)
    fp = _round_up(f, 128)
    ins = 4 * (bn * fp + bn + fp + bn)          # x tile, xn, c row, d2
    outs = 4 * (bn + 1)                         # updated d2 + tile sum
    return 2 * ins + outs


def resolve_variant(k: int, params: KernelParams,
                    variant: Optional[str] = None) -> str:
    """Template dispatch rule shared with the autotuner: the small-K fast
    path applies exactly when padded K fits one centroid tile. An explicit
    ``variant`` overrides (tests / benchmarks); ``"smallk"`` is validated
    against the tile so an impossible request fails here, not in Mosaic."""
    fits = _round_up(k, params.block_k) == params.block_k
    if variant is None:
        return "smallk" if fits else "generic"
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    if variant == "smallk" and not fits:
        raise ValueError(
            f"smallk variant needs K ({k}) to fit one centroid tile "
            f"(block_k={params.block_k})")
    return variant


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class DataPlan:
    """Per-fit data plan: X padded to the block grid and its row squared
    norms, computed exactly once and reused across every Lloyd iteration
    (the seed pipeline re-padded and re-normed X inside every kernel call).

    x      : (m, f)   the original samples (update pass / reseeding)
    xp     : (mp, fp) X padded to the block grid (== x when params is None)
    xn     : (m,)     row squared norms, f32
    m, f   : true (unpadded) dimensions
    params : the KernelParams the padding was laid out for (None = no
             Pallas backend in play; xp is x unpadded)
    """

    x: jax.Array
    xp: jax.Array
    xn: jax.Array
    m: int
    f: int
    params: Optional[KernelParams]


jax.tree_util.register_pytree_node(
    DataPlan,
    lambda p: ((p.x, p.xp, p.xn), (p.m, p.f, p.params)),
    lambda aux, kids: DataPlan(kids[0], kids[1], kids[2], *aux))


def plan_data(x: jax.Array, params: Optional[KernelParams] = None) -> DataPlan:
    """Build the per-fit :class:`DataPlan` (pad + row norms, once)."""
    m, f = x.shape
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    if params is None:
        return DataPlan(x=x, xp=x, xn=xn, m=m, f=f, params=None)
    mp = _round_up(m, params.block_m)
    fp = _round_up(f, params.block_f)
    xp = jnp.pad(x, ((0, mp - m), (0, fp - f)))
    return DataPlan(x=x, xp=xp, xn=xn, m=m, f=f, params=params)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Per-fit data plan for the int8 distance template: X quantized per
    row (scale = max|row|/127, the :mod:`repro.dist.compression` scheme),
    padded to the block grid, with exact f32 row norms of the *unquantized*
    samples — quantization, like padding, happens once per fit.

    The quantized values are stored in a *carrier* dtype: int8 on TPU
    (feeds the MXU int8 path directly), float32 off TPU where XLA's int8
    matmul is several times slower than f32 — the f32 carrier holds the
    same integers, and int8 dot products are bit-exact in f32 for any
    F <= 1040 (F * 127^2 < 2^24).

    x      : (m, f)   the original samples (update pass / reseeding)
    xq     : (mp, fp) quantized X in the carrier dtype, zero padded
    sx     : (mp, 1)  f32 per-row scales (1.0 in padded rows)
    xn     : (m,)     exact row squared norms of the unquantized x, f32
    m, f   : true (unpadded) dimensions
    params : the KernelParams the padding was laid out for (None = no
             Pallas backend in play; xq/sx are unpadded, for the XLA
             analogue)
    """

    x: jax.Array
    xq: jax.Array
    sx: jax.Array
    xn: jax.Array
    m: int
    f: int
    params: Optional[KernelParams]


jax.tree_util.register_pytree_node(
    QuantPlan,
    lambda p: ((p.x, p.xq, p.sx, p.xn), (p.m, p.f, p.params)),
    lambda aux, kids: QuantPlan(kids[0], kids[1], kids[2], kids[3], *aux))


def plan_data_int8(x: jax.Array, params: Optional[KernelParams] = None, *,
                   carrier: Any = None) -> QuantPlan:
    """Build the per-fit :class:`QuantPlan` (quantize + pad + norms, once).

    ``carrier=None`` picks the natural carrier for the backend: int8 on
    TPU, float32 elsewhere (see :class:`QuantPlan`). Tests pin
    ``carrier=jnp.int8`` to exercise the Pallas template in interpret mode.
    ``params=None`` skips padding (the XLA-analogue backend consumes the
    quantized rows unpadded); the resulting plan cannot feed the Pallas
    template.
    """
    if carrier is None:
        carrier = jnp.int8 if on_tpu() else jnp.float32
    m, f = x.shape
    xf = x.astype(jnp.float32)
    xn = jnp.sum(xf ** 2, axis=1)
    q, sx = _quantize_rows(xf)
    if params is None:
        return QuantPlan(x=x, xq=q.astype(carrier), sx=sx, xn=xn,
                         m=m, f=f, params=None)
    mp = _round_up(m, params.block_m)
    fp = _round_up(f, params.block_f)
    xq = jnp.pad(q.astype(carrier), ((0, mp - m), (0, fp - f)))
    sxp = jnp.pad(sx, ((0, mp - m), (0, 0)), constant_values=1.0)
    return QuantPlan(x=x, xq=xq, sx=sxp, xn=xn, m=m, f=f, params=params)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Per-fit data plan for B stacked problems: the (B, N, F) block padded
    to the kernel grid and its per-problem row squared norms, computed
    exactly once and reused across every batched Lloyd iteration.

    x      : (b, n, f)   the original stacked samples
    xp     : (b, np, fp) X padded to the block grid (== x when params is
             None)
    xn     : (b, n)      per-problem row squared norms, f32
    b, n, f: true (unpadded) dimensions
    params : the KernelParams the padding was laid out for (None = no
             Pallas backend in play; xp is x unpadded)
    """

    x: jax.Array
    xp: jax.Array
    xn: jax.Array
    b: int
    n: int
    f: int
    params: Optional[KernelParams]


jax.tree_util.register_pytree_node(
    BatchPlan,
    lambda p: ((p.x, p.xp, p.xn), (p.b, p.n, p.f, p.params)),
    lambda aux, kids: BatchPlan(kids[0], kids[1], kids[2], *aux))


def plan_data_batched(x: jax.Array,
                      params: Optional[KernelParams] = None) -> BatchPlan:
    """Build the per-fit :class:`BatchPlan` (pad + row norms, once).

    Padding happens on the whole (B, N, F) block in one op — the stacked
    layout means every problem shares N and F, so one pad covers all B
    problems (a per-problem loop of pads is exactly the dispatch overhead
    the batched path exists to remove)."""
    b, n, f = x.shape
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=2)
    if params is None:
        return BatchPlan(x=x, xp=x, xn=xn, b=b, n=n, f=f, params=None)
    np_ = _round_up(n, params.block_m)
    fp = _round_up(f, params.block_f)
    xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, fp - f)))
    return BatchPlan(x=x, xp=xp, xn=xn, b=b, n=n, f=f, params=params)


def _pad_centroids_batched(c: jax.Array, k: int, kp: int,
                           fp: int) -> tuple[jax.Array, jax.Array]:
    """Pad per-problem centroids to (B, kp, fp) and build +inf-masked
    squared norms (B, 1, kp) so padded slots never win any problem's
    argmin."""
    cpad = jnp.pad(c, ((0, 0), (0, kp - c.shape[1]), (0, fp - c.shape[2])))
    cn = jnp.sum(cpad.astype(jnp.float32) ** 2, axis=2)        # (B, kp)
    slot = jnp.arange(kp)
    cn = jnp.where(slot[None, :] < k, cn, jnp.inf)[:, None, :]
    return cpad, cn


def _pad_centroids(c: jax.Array, k: int, kp: int,
                   fp: int) -> tuple[jax.Array, jax.Array]:
    """Pad centroids to (kp, fp) and build +inf-masked squared norms so
    padded centroid slots never win the argmin."""
    cpad = jnp.pad(c, ((0, kp - c.shape[0]), (0, fp - c.shape[1])))
    cn = jnp.sum(cpad.astype(jnp.float32) ** 2, axis=1)
    slot = jnp.arange(kp)
    cn = jnp.where(slot < k, cn, jnp.inf)[None, :]
    return cpad, cn


def clamp_params(m: int, k: int, f: int, params: KernelParams,
                 dtype: Any = jnp.float32) -> KernelParams:
    """Shrink blocks that exceed the (padded) problem so tiny shapes work.
    Alignment is dtype-aware: 2-byte dtypes keep 16-row sublane tiles."""
    def shrink(block: int, dim: int, align: int) -> int:
        while block > align and block > _round_up(dim, align):
            block //= 2
        return max(block, align)
    return KernelParams(
        block_m=shrink(params.block_m, m, sublane_align(dtype)),
        block_k=shrink(params.block_k, k, 128),
        block_f=shrink(params.block_f, f, 128),
    )


def _resolve_padded(x: Any, c: jax.Array, params: Optional[KernelParams],
                    kind: str) -> tuple:
    """Common front end: accept a raw X or a prebuilt :class:`DataPlan` and
    return (plan, padded centroids, masked centroid norms, params). The
    centroids are cast to the plan's dtype — the kernels' MXU product wants
    one input dtype, and X's dtype is the template's compute dtype."""
    k = c.shape[0]
    if isinstance(x, DataPlan):
        plan = x
        params = plan.params
        if params is None:
            raise ValueError(
                "DataPlan was built without KernelParams (plan_data(x) with "
                "params=None pads nothing); build it with the kernel's tile "
                "selection — plan_data(x, params) — before feeding a Pallas "
                "kernel")
    else:
        if params is None:
            from repro.api.cache import default_cache
            _, params = default_cache().lookup(x.shape[0], k, x.shape[1],
                                               kind=kind, dtype=x.dtype)
        params = clamp_params(x.shape[0], k, x.shape[1], params,
                              dtype=x.dtype)
        plan = plan_data(x, params)
    c = c.astype(plan.xp.dtype)
    kp = _round_up(k, params.block_k)
    cp, cn = _pad_centroids(c, k, kp, plan.xp.shape[1])
    return plan, cp, cn, params


def fused_assign(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    variant: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment via the fused kernel.

    ``x`` may be a raw (M, F) array or a prebuilt :class:`DataPlan` (then
    ``params`` comes from the plan); f32, bf16 and fp16 inputs all lower
    (f32 accumulate). ``variant=None`` auto-selects the small-K fast path
    whenever K fits one centroid tile — the same rule the autotuner models.
    Returns (assign (M,) int32, partial min distance (M,) f32). Add
    ``sum(x**2, -1)`` for true squared distances.
    """
    if not isinstance(x, DataPlan) and x.shape[0] == 0:
        # zero-row request (serving edge case): nothing to assign, and
        # padding up to a tile would still launch a full grid — and worse,
        # a params=None call would ask the autotuner to model an M=0 shape
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32)
    plan, cp, cn, params = _resolve_padded(x, c, params, "assign")
    variant = resolve_variant(c.shape[0], params, variant)
    if interpret is None:
        interpret = not on_tpu()
    mind, am = _da.distance_argmin(
        plan.xp, cp, cn, block_m=params.block_m, block_k=params.block_k,
        block_f=params.block_f, variant=variant, interpret=interpret)
    m = plan.m
    return am[:m, 0], mind[:m, 0]


def _resolve_padded_int8(x: Any, c: jax.Array,
                         params: Optional[KernelParams]) -> tuple:
    """int8 front end: accept a raw X or a prebuilt :class:`QuantPlan` and
    return (plan, quantized padded centroids, padded centroid scales,
    masked centroid norms, params). Centroids are quantized per row here —
    they move every iteration, so unlike X their quantization is per-call —
    and their squared norms come from the *unquantized* values (the
    template's norm term is exact; only the cross term is quantized)."""
    k = c.shape[0]
    if isinstance(x, QuantPlan):
        plan = x
        if plan.params is None:
            raise ValueError(
                "QuantPlan was built without KernelParams (the unpadded "
                "XLA-analogue layout); the Pallas int8 template needs a "
                "block-padded plan — build it with plan_data_int8(x, "
                "params)")
        params = plan.params
    else:
        if params is None:
            from repro.api.cache import default_cache
            _, params = default_cache().lookup(x.shape[0], k, x.shape[1],
                                               kind="int8", dtype=jnp.int8)
        params = clamp_params(x.shape[0], k, x.shape[1], params,
                              dtype=jnp.int8)
        plan = plan_data_int8(x, params)
    cf = c.astype(jnp.float32)
    kp = _round_up(k, params.block_k)
    fp = plan.xq.shape[1]
    cpad = jnp.pad(cf, ((0, kp - k), (0, fp - cf.shape[1])))
    cn = jnp.sum(cpad ** 2, axis=1)
    cn = jnp.where(jnp.arange(kp) < k, cn, jnp.inf)[None, :]
    cq, sc = _quantize_rows(cf)
    cqp = jnp.pad(cq.astype(plan.xq.dtype),
                  ((0, kp - k), (0, fp - cf.shape[1])))
    scp = jnp.pad(sc, ((0, kp - k), (0, 0)), constant_values=1.0).T  # (1,kp)
    return plan, cqp, scp, cn, params


def fused_assign_int8(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    variant: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment via the int8 distance template.

    ``x`` may be a raw (M, F) array (quantized here, per row) or a prebuilt
    :class:`QuantPlan` (then ``params`` comes from the plan and the per-fit
    quantization is reused). ``c`` is the *unquantized* (K, F) centroid
    array — centroid quantization is per-call because centroids move every
    iteration. ``variant=None`` auto-selects the small-K fast path exactly
    like :func:`fused_assign`. Returns (assign (M,) int32, partial min
    distance (M,) f32); add ``sum(x**2, -1)`` for true squared distances.

    On quantization-safe data (integer entries in [-127, 127] with a
    +-127 entry per row) the argmin is bit-exact against
    :func:`fused_assign`; on float data the distance error is bounded by
    the ~1/127-per-operand quantization step (see
    :mod:`repro.kernels.distance_argmin_int8`).
    """
    if not isinstance(x, QuantPlan) and x.shape[0] == 0:
        # zero-row request: same serving edge case as fused_assign
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32)
    plan, cqp, scp, cn, params = _resolve_padded_int8(x, c, params)
    variant = resolve_variant(c.shape[0], params, variant)
    if interpret is None:
        interpret = not on_tpu()
    # The Pallas template wants int8 tiles. A plan built with the f32
    # carrier (the off-TPU default, consumed by the XLA analogue backend)
    # holds int8-valued floats, so the cast back is exact.
    xq = plan.xq.astype(jnp.int8)
    cq = cqp.astype(jnp.int8)
    mind, am = _dai.distance_argmin_int8(
        xq, cq, plan.sx, scp, cn, block_m=params.block_m,
        block_k=params.block_k, block_f=params.block_f, variant=variant,
        interpret=interpret)
    m = plan.m
    return am[:m, 0], mind[:m, 0]


def _tree_sum(a: jax.Array) -> jax.Array:
    """Balanced pairwise reduction over axis 0 (log2 depth, better fp
    behaviour than a linear fold for many partial blocks)."""
    while a.shape[0] > 1:
        half = a.shape[0] // 2
        rest = a[2 * half:]
        a = jnp.concatenate([a[:half] + a[half:2 * half], rest], axis=0)
    return a[0]


def fused_lloyd(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    variant: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass Lloyd step via the fused kernel: assignment plus the
    per-cluster sums/counts the centroid update needs, X read once.

    ``x`` may be a raw (M, F) array or a prebuilt :class:`DataPlan`; f32,
    bf16 and fp16 inputs all lower (f32 accumulators and outputs).
    ``variant=None`` auto-selects the small-K fast path whenever K fits one
    centroid tile. Returns (assign (M,) int32, true squared distance (M,)
    f32, sums (K, F) f32, counts (K,) f32).
    """
    plan, cp, cn, params = _resolve_padded(x, c, params, "lloyd")
    variant = resolve_variant(c.shape[0], params, variant)
    if interpret is None:
        interpret = not on_tpu()
    k, m = c.shape[0], plan.m
    meta = jnp.array([m], jnp.int32)
    mind, am, sums, counts = _ll.lloyd_step(
        plan.xp, cp, cn, meta, block_m=params.block_m,
        block_k=params.block_k, block_f=params.block_f, variant=variant,
        interpret=interpret)
    sums = _tree_sum(sums)[:k, :plan.f]
    counts = _tree_sum(counts)[:k]
    return am[:m, 0], mind[:m, 0] + plan.xn, sums, counts


# Relative + absolute fp-safety slack on the tile skip test. The bounds
# are f32 and derived from rounded kernel outputs, so the raw comparison
# is not rigorously conservative at the last ulp; the slack makes a wrong
# skip require a bound error several orders of magnitude above f32
# rounding noise, while separated clusters keep margins far above it.
PRUNE_SLACK = 1e-3


@dataclasses.dataclass(frozen=True)
class BoundsState:
    """Iteration-carried Hamerly bounds for the pruned one-pass kernel.

    Registered as a pytree (all fields are leaves) so it threads through
    ``jax.lax.scan`` carries and jit boundaries like any array. The state
    is only meaningful for the (params, k, f, backend) it was built for;
    anything that moves centroids outside the kernel's own update —
    ``partial_fit`` resumption, ``from_state`` rehydration, a served
    centroid hot-swap — must replace it with a fresh state
    (:func:`init_bounds`), whose ``fresh`` flag forces the next call to
    compute every tile and reseed real bounds.

    ub     : (m,)       f32 upper bound on each row's Euclidean distance
                        to its assigned centroid
    assign : (m,)       i32 assignment the upper bounds pair with
    tmin   : (nmt, nkt) f32 per-(row tile, centroid tile) Euclidean group
                        lower bound
    c_prev : (kp, fp)   f32 copy of the padded centroids the bounds were
                        computed against (the drift reference; stored in
                        f32 *after* the compute-dtype cast so drift is
                        measured in the space the kernel sees)
    fresh  : ()         bool — True = placeholder state; the next call
                        skips nothing and seeds real bounds
    """

    ub: jax.Array
    assign: jax.Array
    tmin: jax.Array
    c_prev: jax.Array
    fresh: jax.Array


jax.tree_util.register_pytree_node(
    BoundsState,
    lambda s: ((s.ub, s.assign, s.tmin, s.c_prev, s.fresh), ()),
    lambda aux, kids: BoundsState(*kids))


def init_bounds(m: int, k: int, f: int,
                params: Optional[KernelParams] = None, *,
                dtype: Any = jnp.float32) -> BoundsState:
    """Fresh (all-invalid) :class:`BoundsState` for a pruned fit: shaped
    for the clamped tile grid of (m, k, f) so it is a valid scan carry
    from iteration zero, with ``fresh=True`` so the first call computes
    every tile."""
    if params is None:
        from repro.api.cache import default_cache
        _, params = default_cache().lookup(m, k, f, kind="pruned",
                                           dtype=dtype)
    params = clamp_params(m, k, f, params, dtype=dtype)
    mp = _round_up(m, params.block_m)
    kp = _round_up(k, params.block_k)
    fp = _round_up(f, params.block_f)
    return BoundsState(
        ub=jnp.zeros((m,), jnp.float32),
        assign=jnp.zeros((m,), jnp.int32),
        tmin=jnp.zeros((mp // params.block_m, kp // params.block_k),
                       jnp.float32),
        c_prev=jnp.zeros((kp, fp), jnp.float32),
        fresh=jnp.ones((), bool),
    )


def fused_lloyd_pruned(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    bounds: Optional[BoundsState] = None,
    variant: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, BoundsState,
           jax.Array]:
    """One-pass Lloyd step with tile-granular triangle-inequality pruning.

    Same contract as :func:`fused_lloyd` plus an iteration-carried
    :class:`BoundsState`: each (row tile, centroid tile) cell is skipped
    when its decayed Euclidean group lower bound cannot beat the row
    tile's worst-case upper bound (``tlb > maxub`` with
    :data:`PRUNE_SLACK` safety margin). Skipping only omits folds that
    provably lose strictly, so assignments, distances, sums and counts
    are bit-identical to :func:`fused_lloyd` at the same tiles.

    ``bounds=None`` (or a ``fresh`` state) computes every tile and seeds
    the bounds — the unpruned first iteration. Single-tile shapes
    (``smallk``, or K inside one ``block_k``) can never skip.

    Returns (assign (M,) int32, true squared distance (M,) f32, sums
    (K, F) f32, counts (K,) f32, new bounds, pruned tile fraction
    (scalar f32)).
    """
    plan, cp, cn, params = _resolve_padded(x, c, params, "pruned")
    variant = resolve_variant(c.shape[0], params, variant)
    if interpret is None:
        interpret = not on_tpu()
    k, m = c.shape[0], plan.m
    mp = plan.xp.shape[0]
    kp = cp.shape[0]
    nmt = mp // params.block_m
    nkt = kp // params.block_k
    if bounds is None:
        bounds = init_bounds(m, k, plan.f, params, dtype=plan.xp.dtype)
    meta = jnp.array([m], jnp.int32)
    xnp = jnp.pad(plan.xn, (0, mp - m))[:, None]
    cpf = cp.astype(jnp.float32)
    # Decay the recorded group bounds by each tile's worst centroid drift
    # and compare against the row tile's worst adjusted upper bound. The
    # tile holding a row's assigned centroid always satisfies
    # tlb <= ub_adj <= maxub, so at least that tile survives per row and
    # the argmin stays grounded.
    drift = jnp.sqrt(jnp.sum((cpf - bounds.c_prev) ** 2, axis=1))   # (kp,)
    maxdrift = jnp.max(drift.reshape(nkt, params.block_k), axis=1)  # (nkt,)
    ub_adj = bounds.ub + drift[bounds.assign]                       # (m,)
    maxub = jnp.max(
        jnp.pad(ub_adj, (0, mp - m), constant_values=-jnp.inf)
        .reshape(nmt, params.block_m), axis=1)                      # (nmt,)
    tlb = bounds.tmin - maxdrift[None, :]                           # (nmt, nkt)
    if nkt == 1:
        # A single centroid tile contains every assigned centroid and can
        # never be skipped; forcing the mask statically keeps the smallk
        # kernel skip-free.
        skip = jnp.zeros((nmt, nkt), jnp.int32)
    else:
        can_skip = tlb > maxub[:, None] * (1.0 + PRUNE_SLACK) + PRUNE_SLACK
        skip = jnp.where(bounds.fresh, 0, can_skip.astype(jnp.int32))
    mind, am, sums, counts, tmin_k = _llp.lloyd_step_pruned(
        plan.xp, cp, cn, xnp, meta, skip, block_m=params.block_m,
        block_k=params.block_k, block_f=params.block_f, variant=variant,
        interpret=interpret)
    md = mind[:m, 0] + plan.xn
    sums_k = _tree_sum(sums)[:k, :plan.f]
    counts_k = _tree_sum(counts)[:k]
    new_bounds = BoundsState(
        ub=jnp.sqrt(jnp.maximum(md, 0.0)),
        assign=am[:m, 0],
        # skipped cells keep the decayed bound; computed cells refresh
        tmin=jnp.where(skip == 1, tlb, tmin_k),
        c_prev=cpf,
        fresh=jnp.zeros((), bool),
    )
    prune_frac = jnp.mean(skip.astype(jnp.float32))
    return am[:m, 0], md, sums_k, counts_k, new_bounds, prune_frac


def _resolve_padded_batched(x: Any, c: jax.Array,
                            params: Optional[KernelParams]) -> tuple:
    """Batched front end: accept a raw (B, N, F) stack or a prebuilt
    :class:`BatchPlan` and return (plan, padded centroids, masked centroid
    norms, params). Centroids are cast to the plan's dtype like the
    single-problem path; padded K is always one centroid tile (the batched
    template is the smallk epilogue by construction)."""
    k = c.shape[1]
    if isinstance(x, BatchPlan):
        plan = x
        params = plan.params
        if params is None:
            raise ValueError(
                "BatchPlan was built without KernelParams (plan_data_batched"
                "(x) with params=None pads nothing); build it with the "
                "kernel's tile selection — plan_data_batched(x, params) — "
                "before feeding the batched Pallas kernel")
    else:
        if params is None:
            from repro.api.cache import default_cache
            _, params = default_cache().lookup(
                x.shape[1], k, x.shape[2], kind="batched", dtype=x.dtype,
                batch=x.shape[0])
        params = clamp_params(x.shape[1], k, x.shape[2], params,
                              dtype=x.dtype)
        plan = plan_data_batched(x, params)
    c = c.astype(plan.xp.dtype)
    kp = _round_up(k, 128)
    cp, cn = _pad_centroids_batched(c, k, kp, plan.xp.shape[2])
    return plan, cp, cn, params


def fused_lloyd_batched(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass Lloyd step for B independent problems in a single launch.

    ``x`` may be a raw (B, N, F) stack or a prebuilt :class:`BatchPlan`;
    ``c`` is the (B, K, F) per-problem centroid stack. f32, bf16 and fp16
    inputs all lower (f32 accumulators and outputs). The problem axis maps
    to the outermost grid dimension of the kernel, so one launch replaces B
    dispatches; per-problem arithmetic is identical to a loop of
    single-problem :func:`fused_lloyd` calls at the same tiles (same
    epilogue, same tree-reduction order). Returns (assign (B, N) int32,
    true squared distance (B, N) f32, sums (B, K, F) f32,
    counts (B, K) f32).
    """
    plan, cp, cn, params = _resolve_padded_batched(x, c, params)
    if interpret is None:
        interpret = not on_tpu()
    k, n = c.shape[1], plan.n
    meta = jnp.array([n], jnp.int32)
    mind, am, sums, counts = _ll.lloyd_step_batched(
        plan.xp, cp, cn, meta, block_m=params.block_m,
        block_f=params.block_f, interpret=interpret)
    # same balanced pairwise order as the single-problem reduction, per
    # problem: collapse the row-tile partials (axis 1) for all B at once
    sums = _tree_sum(jnp.moveaxis(sums, 1, 0))[:, :k, :plan.f]
    counts = _tree_sum(jnp.moveaxis(counts, 1, 0))[:, :k]
    return am[:, :n, 0], mind[:, :n, 0] + plan.xn, sums, counts


def _verify_update_partials(plan: Any, am: jax.Array, sums_p: jax.Array,
                            counts_p: jax.Array, ucheck: jax.Array,
                            ccheck: jax.Array, params: KernelParams
                            ) -> tuple:
    """Verification interval of the fused update epilogue (paper Fig. 6
    applied to the one-hot product). Compares the observed e1/e2 column
    checksums of each row tile's partial sums/counts against the expected
    ones the kernel computed from its argmin/valid vectors, and recomputes
    a mismatched tile from the data plan and the (corrected) assignment.
    The recompute replays the kernel's own one-hot arithmetic on the same
    operands, so a recovered run is bit-identical to a clean one. Under
    the §II-A SEU model at most one tile can mismatch per step; every
    mismatch is counted, the worst tile is repaired.
    """
    from repro.core.checksum import threshold_factor
    num_m, kp, fp = sums_p.shape
    bm = params.block_m
    w_k = jnp.arange(1.0, kp + 1.0, dtype=jnp.float32)
    obs1 = jnp.sum(sums_p, axis=1)                           # (num_m, fp)
    obs2 = jnp.sum(w_k[None, :, None] * sums_p, axis=1)
    res1 = jnp.abs(obs1 - ucheck[:, 0])                      # (num_m, fp)
    res2 = jnp.abs(obs2 - ucheck[:, 1])
    cres1 = jnp.abs(jnp.sum(counts_p, axis=1) - ccheck[:, 0])   # (num_m,)
    cres2 = jnp.abs(jnp.sum(w_k[None, :] * counts_p, axis=1)
                    - ccheck[:, 1])
    # contraction length is the row tile; eps tracks the stash dtype. The
    # scale comes from the expected checksums only (clean invariant side):
    # folding the possibly-corrupted partials in would let a large delta
    # inflate its own threshold (self-masking) at 2-byte dtypes. Each
    # e1/e2 pair thresholds against its own magnitude — the e2 row runs
    # ~K x larger, and a shared scale would raise the e1 detection floor
    # by that factor.
    factor = threshold_factor(bm, plan.xp.dtype)
    scale1 = jnp.maximum(jnp.max(jnp.abs(ucheck[:, 0]), axis=1), 1.0)
    scale2 = jnp.maximum(jnp.max(jnp.abs(ucheck[:, 1]), axis=1), 1.0)
    bad = ((jnp.max(res1, axis=1) > factor * scale1)
           | (jnp.max(res2, axis=1) > factor * scale2)
           | (cres1 > factor * jnp.maximum(jnp.abs(ccheck[:, 0]), 1.0))
           | (cres2 > factor * jnp.maximum(jnp.abs(ccheck[:, 1]), 1.0)))
    n_bad = jnp.sum(bad.astype(jnp.int32))

    def _recompute(operands: tuple) -> tuple:
        sums_p, counts_p = operands
        i = jnp.argmax(bad)
        x_tile = jax.lax.dynamic_slice(plan.xp, (i * bm, 0), (bm, fp))
        am_tile = jax.lax.dynamic_slice(am, (i * bm, 0), (bm, 1))
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
        valid = (rows < plan.m).astype(jnp.float32)
        clusters = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
        onehot = (am_tile == clusters).astype(jnp.float32) * valid
        new_counts = jnp.sum(onehot, axis=0, keepdims=True)
        new_sums = jax.lax.dot_general(
            onehot.astype(x_tile.dtype), x_tile, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return (jax.lax.dynamic_update_slice(sums_p, new_sums[None],
                                             (i, 0, 0)),
                jax.lax.dynamic_update_slice(counts_p, new_counts, (i, 0)))

    sums_p, counts_p = jax.lax.cond(
        n_bad > 0, _recompute, lambda o: o, (sums_p, counts_p))
    return sums_p, counts_p, n_bad


def fused_lloyd_ft(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    inj: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass FT Lloyd step: fused ABFT around the distance GEMM plus the
    checksum-protected update epilogue, X read from HBM once.

    ``x`` may be a raw (M, F) array or a prebuilt :class:`DataPlan`; f32,
    bf16 and fp16 inputs all lower (f32 accumulators, checksums and
    outputs). The FT template is always the generic grid (like
    ``fused_assign_ft``). ``inj`` is a dual-slot
    :func:`~repro.kernels.lloyd_step_ft.make_injection` descriptor.
    Returns (assign (M,) int32, true squared distance (M,) f32,
    sums (K, F) f32, counts (K,) f32, detected (scalar int32) — corrected
    distance-GEMM errors plus recomputed update tiles).
    """
    plan, cp, cn, params = _resolve_padded(x, c, params, "lloyd_ft")
    if interpret is None:
        interpret = not on_tpu()
    if inj is None:
        inj = _llft.no_injection()
    k, m = c.shape[0], plan.m
    meta = jnp.array([m], jnp.int32)
    mind, am, det, sums_p, counts_p, ucheck, ccheck = _llft.lloyd_step_ft(
        plan.xp, cp, cn, meta, inj, block_m=params.block_m,
        block_k=params.block_k, block_f=params.block_f, interpret=interpret)
    sums_p, counts_p, det_up = _verify_update_partials(
        plan, am, sums_p, counts_p, ucheck, ccheck, params)
    sums = _tree_sum(sums_p)[:k, :plan.f]
    counts = _tree_sum(counts_p)[:k]
    return (am[:m, 0], mind[:m, 0] + plan.xn, sums, counts,
            jnp.sum(det) + det_up)


def fused_assign_ft(
    x: jax.Array,
    c: jax.Array,
    params: Optional[KernelParams] = None,
    *,
    inj: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FT assignment: fused ABFT detect+locate+correct inside the kernel.

    ``x`` may be a raw (M, F) array or a prebuilt :class:`DataPlan`; f32,
    bf16 and fp16 inputs all lower (checksums stay f32). The FT template is
    always the generic grid — its checksum scratch is already VMEM-resident,
    so there is no small-K variant to select. Returns (assign, partial min
    distance, corrected_error_count).
    """
    plan, cp, cn, params = _resolve_padded(x, c, params, "assign")
    if interpret is None:
        interpret = not on_tpu()
    if inj is None:
        inj = _daft.no_injection()
    mind, am, det = _daft.distance_argmin_ft(
        plan.xp, cp, cn, inj, block_m=params.block_m, block_k=params.block_k,
        block_f=params.block_f, interpret=interpret)
    m = plan.m
    return am[:m, 0], mind[:m, 0], jnp.sum(det)


def abft_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    inj: Optional[jax.Array] = None,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """ABFT GEMM D = X @ Y with in-kernel correction. Returns (D, det_count)."""
    if interpret is None:
        interpret = not on_tpu()
    m, k = x.shape
    n = y.shape[1]
    p = clamp_params(m, n, k, KernelParams(block_m, block_n, block_k))
    bm, bn, bk = p.block_m, p.block_k, p.block_f
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    if inj is None:
        inj = _mma.no_injection()
    d, det = _mma.matmul_abft(
        xp, yp, inj, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return d[:m, :n], jnp.sum(det)


def plan_injection_tile(m: int, k: int, f: int, params: KernelParams,
                        row: int, col: int, f_step: int,
                        delta: float) -> jax.Array:
    """Translate a global (row, col) error position into tile coordinates."""
    params = clamp_params(m, k, f, params)
    return _daft.make_injection(
        row // params.block_m, col // params.block_k,
        f_step % max(f // params.block_f, 1),
        row % params.block_m, col % params.block_k, delta)


# ---------------------------------------------------------------------------
# Introspected kernel plans — the contract surface for repro.analysis.
# ---------------------------------------------------------------------------

# Kernel kinds with a Pallas plan. This is the canonical kind vocabulary:
# repro.core.autotune.KINDS re-exports it, so extending the family (and
# the autotune cache schema with it) is a single-point change here.
PLAN_KINDS: tuple[str, ...] = ("assign", "lloyd", "lloyd_ft", "batched",
                               "pruned", "int8", "init", "serve")

# Per-kind compute dtypes: the f32 template family lowers at every
# supported width; the int8 template is its own dtype notch (x/c tiles are
# int8 by construction, the epilogue is f32), and the fused k-means++
# round kernel runs its D² state in f32 only (seeding precision is the
# fit's floor — a half-precision CDF would bias every later iteration).
# Contract checks and the autotuner iterate this mapping instead of
# assuming one dtype set fits every kind.
PLAN_KIND_DTYPES: dict[str, tuple[str, ...]] = {
    "assign": ("float32", "bfloat16", "float16"),
    "lloyd": ("float32", "bfloat16", "float16"),
    "lloyd_ft": ("float32", "bfloat16", "float16"),
    "batched": ("float32", "bfloat16", "float16"),
    "pruned": ("float32", "bfloat16", "float16"),
    "int8": ("int8",),
    "init": ("float32",),
    # serve = the assignment kernel launched as an AOT-compiled predict
    # cell at a serving bucket shape (repro.serve). Same Pallas plan as
    # "assign"; a separate kind so bucket-shaped tile winners and the
    # per-launch dispatch cost live in their own autotune-cache namespace.
    "serve": ("float32", "bfloat16", "float16"),
}


@dataclasses.dataclass(frozen=True)
class BufferPlan:
    """One operand of a traced ``pallas_call``: per-grid-step block shape,
    dtype and memory space, recovered from the kernel jaxpr itself rather
    than re-derived from the BlockSpecs by hand — so the plan cannot drift
    from what the kernel actually allocates."""

    role: str                     # "input" | "output" | "scratch"
    memory: str                   # "vmem" | "smem"
    block_shape: tuple[int, ...]
    dtype: str

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.block_shape:
            n *= int(d)
        return n * int(jnp.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Grid and operand blocks of the single ``pallas_call`` behind one
    kernel entry point, obtained abstractly (``jax.make_jaxpr`` over
    ``ShapeDtypeStruct``s — no compile, no TPU)."""

    kind: str
    variant: str
    grid: tuple[int, ...]
    inputs: tuple[BufferPlan, ...]
    outputs: tuple[BufferPlan, ...]
    scratch: tuple[BufferPlan, ...]

    def vmem_bytes(self) -> int:
        """Implied footprint under the byte-model convention: VMEM input
        blocks are double-buffered, output and scratch blocks are resident
        once, SMEM operands don't count against the VMEM budget."""
        def tally(bufs: tuple[BufferPlan, ...], mult: int) -> int:
            return sum(mult * b.nbytes for b in bufs if b.memory == "vmem")
        return (tally(self.inputs, 2) + tally(self.outputs, 1)
                + tally(self.scratch, 1))


def _walk_pallas_eqns(jaxpr: jex_core.Jaxpr) -> Iterator[Any]:
    """Yield every pallas_call equation, recursing through sub-jaxprs
    (the kernel wrappers trace under a pjit equation)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for v in eqn.params.values():
            if isinstance(v, jex_core.ClosedJaxpr):
                yield from _walk_pallas_eqns(v.jaxpr)
            elif isinstance(v, jex_core.Jaxpr):
                yield from _walk_pallas_eqns(v)


def _plan_buffers(eqn: Any) -> tuple[tuple[BufferPlan, ...],
                                     tuple[BufferPlan, ...],
                                     tuple[BufferPlan, ...]]:
    gm = eqn.params["grid_mapping"]

    def buf(role: str, aval: Any, shape: Any) -> BufferPlan:
        memory = "smem" if "smem" in str(aval).lower() else "vmem"
        return BufferPlan(role=role, memory=memory,
                          block_shape=tuple(int(d) for d in shape),
                          dtype=jnp.dtype(aval.dtype).name)

    maps = list(gm.block_mappings)
    ins = tuple(buf("input", b.block_aval, b.block_shape)
                for b in maps[:gm.num_inputs])
    outs = tuple(buf("output", b.block_aval, b.block_shape)
                 for b in maps[gm.num_inputs:gm.num_inputs + gm.num_outputs])
    invars = eqn.params["jaxpr"].invars
    n_scr = gm.num_scratch_operands
    # DMA-semaphore scratch (the double-buffered stash handshake) has no
    # numpy dtype and occupies no VMEM bytes — it is not a buffer and is
    # excluded from the plan rather than shoehorned into one.
    def _is_sem(aval: Any) -> bool:
        try:
            jnp.dtype(aval.dtype)
        except TypeError:
            return True
        return False
    scr = tuple(buf("scratch", v.aval, v.aval.shape)
                for v in (invars[len(invars) - n_scr:] if n_scr else [])
                if not _is_sem(v.aval))
    return ins, outs, scr


def kernel_plan(kind: str, m: int, k: int, f: int,
                params: Optional[KernelParams] = None, *,
                dtype: Any = jnp.float32,
                variant: Optional[str] = None,
                batch: int = 1) -> KernelPlan:
    """Abstractly trace the kernel entry point for (kind, shape, dtype,
    variant) and return its pallas_call grid/block plan.

    Shapes are padded and params clamped exactly as the real call path
    does, so the returned plan is the plan the kernel would launch with.
    ``repro.analysis.contracts`` checks the declared VMEM byte models
    (``KernelParams.vmem_bytes`` and friends) against
    :meth:`KernelPlan.vmem_bytes` — the footprint the BlockSpecs imply.
    """
    if kind not in PLAN_KINDS:
        raise ValueError(f"kind must be one of {PLAN_KINDS}, got {kind!r}")
    if params is None:
        params = DEFAULT_PARAMS
    dt = jnp.dtype(dtype)
    p = clamp_params(m, k, f, params, dtype=dt)
    fp = _round_up(f, p.block_f)
    meta = jax.ShapeDtypeStruct((1,), jnp.int32)
    fn: Any
    args: tuple[Any, ...]
    if kind == "batched":
        np_ = _round_up(m, p.block_m)
        kp = _round_up(k, 128)
        xs = jax.ShapeDtypeStruct((batch, np_, fp), dt)
        cs = jax.ShapeDtypeStruct((batch, kp, fp), dt)
        cn = jax.ShapeDtypeStruct((batch, 1, kp), jnp.float32)
        var = "smallk"   # the batched template is the smallk epilogue
        fn = functools.partial(_ll.lloyd_step_batched, block_m=p.block_m,
                               block_f=p.block_f, interpret=False)
        args = (xs, cs, cn, meta)
    elif kind == "init":
        # fused k-means++ round: (B, Np/bn) grid, full-F blocks; K and
        # block_k/block_f are not axes of this kernel
        bn = _kpi.clamp_init_block(m, p.block_m)
        np_ = _round_up(m, bn)
        fpl = _round_up(f, 128)
        xs = jax.ShapeDtypeStruct((batch, np_, fpl), jnp.float32)
        xn = jax.ShapeDtypeStruct((batch, np_), jnp.float32)
        cs = jax.ShapeDtypeStruct((batch, 1, fpl), jnp.float32)
        d2 = jax.ShapeDtypeStruct((batch, np_), jnp.float32)
        var = "generic"
        fn = functools.partial(_kpi.kmeanspp_round, block_n=bn,
                               interpret=False)
        args = (xs, xn, cs, d2)
    else:
        mp = _round_up(m, p.block_m)
        kp = _round_up(k, p.block_k)
        xs = jax.ShapeDtypeStruct((mp, fp), dt)
        cs = jax.ShapeDtypeStruct((kp, fp), dt)
        cn = jax.ShapeDtypeStruct((1, kp), jnp.float32)
        if kind in ("assign", "serve"):
            # a serve predict cell launches the assignment kernel at the
            # bucket shape — same plan, serving-specific tile selection
            var = resolve_variant(k, p, variant)
            fn = functools.partial(_da.distance_argmin, block_m=p.block_m,
                                   block_k=p.block_k, block_f=p.block_f,
                                   variant=var, interpret=False)
            args = (xs, cs, cn)
        elif kind == "int8":
            var = resolve_variant(k, p, variant)
            xs = jax.ShapeDtypeStruct((mp, fp), jnp.int8)
            cs = jax.ShapeDtypeStruct((kp, fp), jnp.int8)
            sx = jax.ShapeDtypeStruct((mp, 1), jnp.float32)
            sc = jax.ShapeDtypeStruct((1, kp), jnp.float32)
            fn = functools.partial(_dai.distance_argmin_int8,
                                   block_m=p.block_m, block_k=p.block_k,
                                   block_f=p.block_f, variant=var,
                                   interpret=False)
            args = (xs, cs, sx, sc, cn)
        elif kind == "pruned":
            var = resolve_variant(k, p, variant)
            xn = jax.ShapeDtypeStruct((mp, 1), jnp.float32)
            skip = jax.ShapeDtypeStruct(
                (mp // p.block_m, kp // p.block_k), jnp.int32)
            fn = functools.partial(_llp.lloyd_step_pruned, block_m=p.block_m,
                                   block_k=p.block_k, block_f=p.block_f,
                                   variant=var, interpret=False)
            args = (xs, cs, cn, xn, meta, skip)
        elif kind == "lloyd":
            var = resolve_variant(k, p, variant)
            fn = functools.partial(_ll.lloyd_step, block_m=p.block_m,
                                   block_k=p.block_k, block_f=p.block_f,
                                   variant=var, interpret=False)
            args = (xs, cs, cn, meta)
        else:                     # lloyd_ft: FT template is always generic
            var = "generic"
            inj = jax.ShapeDtypeStruct((_llft.INJ_LEN,), jnp.float32)
            fn = functools.partial(_llft.lloyd_step_ft, block_m=p.block_m,
                                   block_k=p.block_k, block_f=p.block_f,
                                   interpret=False)
            args = (xs, cs, cn, meta, inj)
    closed = jax.make_jaxpr(fn)(*args)
    eqns = list(_walk_pallas_eqns(closed.jaxpr))
    if len(eqns) != 1:
        raise RuntimeError(
            f"expected exactly one pallas_call behind kind={kind!r}, "
            f"found {len(eqns)}")
    ins, outs, scr = _plan_buffers(eqns[0])
    grid = tuple(int(g) for g in eqns[0].params["grid_mapping"].grid)
    return KernelPlan(kind=kind, variant=var, grid=grid,
                      inputs=ins, outputs=outs, scratch=scr)
