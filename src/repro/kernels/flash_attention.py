"""Flash-attention Pallas kernel (beyond-paper §Perf direction).

The roofline (§EXPERIMENTS) shows every train/prefill cell memory-bound,
dominated by materialized f32 score chunks (B·KV·G·Sq·Skv per layer).
Online-softmax attention never materializes the scores to HBM: per
(query-block, kv-block) tile the running max/denominator/accumulator live
in VMEM — the standard fix, here in the same BlockSpec style as the
k-means kernels so it drops into `repro.models.attention` on TPU.

Supports causal + local-window masking via absolute key positions (same
mask contract as models/attention.attend). GQA: q arrives grouped
(B, KV, G·bq?, ...) — this kernel takes q (B, H, Sq, hd), k/v
(B, KV, Skv, hd) with H = KV·G and maps h -> kv = h // G.

Grid: (B·H, Sq/bq, Skv/bk) — kv axis innermost (sequential), carrying
(m, l, acc) in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, causal: bool, window: int):
    kv_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    qpos = qpos_ref[...]                           # (bq, 1) int32
    kpos = kpos_ref[...]                           # (1, bk) int32
    mask = kpos >= 0
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (bq, bk)
    scale = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q, k, v, q_positions, kv_positions, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q (B, H, Sq, hd); k, v (B, KV, Skv, hd); positions absolute int32.

    Returns (B, H, Sq, hd). Shapes must be pre-padded to the blocks
    (pad keys with kv_positions = -1 -> masked out).
    """
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    assert sq % block_q == 0 and skv % block_k == 0
    qf = q.reshape(b * h, sq, hd)
    grid = (b * h, sq // block_q, skv // block_k)

    def q_map(i, j, t):
        return (i, j, 0)

    def kv_map(i, j, t):
        return ((i % h) // g + (i // h) * kvh, t, 0)

    kf = k.reshape(b * kvh, skv, hd)
    vf = v.reshape(b * kvh, skv, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j, t: (j, 0)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, t)),
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_positions.astype(jnp.int32)[:, None],
      kv_positions.astype(jnp.int32)[None, :],
      qf, kf, vf)
    return out.reshape(b, h, sq, hd)
