"""Fused k-means++ D² seeding rounds (batched problems, one kernel/round).

The vmapped seeding path (``jax.vmap(init_kmeanspp)``) pays, per round and
per problem, a full elementwise ``(N, F)`` distance recompute plus
``jax.random.choice`` over N weights — and the categorical draw itself
re-materializes a cumulative distribution every round. For the B-problem
regime the batched estimator targets (many small problems), that is B
dispatches of XLA glue per round with nothing fused.

This module fuses one whole D² round into a single launch over the
``(B, N/bn)`` grid:

  * **distance update** — the cross-term form ``d² = max(‖x‖² - 2·x·c
    + ‖c‖², 0)`` against the single centroid chosen last round, folded
    into the running ``min``;
  * **per-tile partial sums** of the updated d² — the first level of the
    inverse-CDF selection tree — written alongside.

Selection then finishes on the host side of the launch in O(B·(T + bn))
instead of O(B·N): a cumulative sum over the T tile sums picks the tile,
an inner cumulative sum over that tile's bn entries picks the row
(``index = tile · bn + offset``), exactly one uniform draw per round.

**Deviation from the issue text**: the issue sketches Gumbel-top-1
sampling for the categorical draw; measured on the batched shapes it was
~5x slower than the round it replaces (a full log/noise pass over every
weight, every round). The tiled inverse-CDF above is the standard
single-uniform equivalent — identical distribution, one uniform per round
— and is what ships. Parity is pinned at the *chosen-index* level against
:func:`_round_twin`, a tile-mirrored XLA implementation of the same
round (Pallas-interpret and XLA float reductions are not bitwise
identical, so value-level parity would overconstrain the kernel).

Key protocol: ``k0, ku = split(key)``; ``randint(k0)`` picks the uniform
first centroid and ``uniform(ku, (K-1,))`` yields the K-1 round draws up
front (one uniform per round, drawn as a block so the loop body carries
no PRNG state). The stream therefore differs from ``init_kmeanspp`` —
same D² distribution, not the same samples — and reproducibility is
against *itself* per seed, plus chosen-index parity between the kernel
and the twin at a fixed ``block_n``.

Padding contract: rows are zero-padded to the tile grid and their d² is
pinned to 0.0 from the start — zero mass never advances the CDF, so a
padded row is never selected and never pollutes a tile sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BLOCK_N = 512
# off-TPU the tile size only shapes the two-level CDF, not a launch grid
TWIN_BLOCK_N = 128


def _round_up(v: int, b: int) -> int:
    return -(-v // b) * b


def clamp_init_block(n: int, block_n: int) -> int:
    """Row-tile size for the init round kernel: at least 128 (the d² and
    tile-sum blocks put bn on a lane-tiled axis) and no larger than the
    128-aligned problem (bigger only buys padding)."""
    return max(128, min(block_n, _round_up(n, 128)))


def _round_kernel(x_ref, xn_ref, c_ref, d2_ref, d2o_ref, ts_ref):
    """One (bn,) slice of one problem's D² round.

    x_ref  : (1, bn, fp) f32  sample tile (zero padded)
    xn_ref : (1, bn, 1)  f32  row squared norms (0 in padded rows)
    c_ref  : (1, 1, fp)  f32  the centroid chosen last round
    d2_ref : (1, bn, 1)  f32  incoming d² (0 in padded rows)
    d2o_ref: (1, bn, 1)  f32  updated d² (output)
    ts_ref : (1, 1)      f32  tile sum of the updated d² (output)
    """
    xt = x_ref[0]                                    # (bn, fp)
    ct = c_ref[0]                                    # (1, fp)
    cross = jax.lax.dot_general(
        xt, ct, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bn, 1)
    cn = jnp.sum(ct * ct)
    nd = jnp.maximum(xn_ref[0] - 2.0 * cross + cn, 0.0)
    d2 = jnp.minimum(d2_ref[0], nd)
    d2o_ref[0] = d2
    ts_ref[...] = jnp.sum(d2, axis=0, keepdims=True).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeanspp_round(x: jax.Array, xn: jax.Array, c: jax.Array,
                   d2: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                   interpret: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """One fused D² round over the (B, Np/bn) grid.

    x (B, Np, Fp) f32 zero-padded samples, xn (B, Np) their row squared
    norms, c (B, 1, Fp) the last-chosen centroid per problem, d2 (B, Np)
    the running minimum squared distance (0.0 in padded rows). Returns
    ``(d2', tile_sums)`` with ``tile_sums`` of shape (B, Np // block_n).
    """
    b, np_, fp = x.shape
    assert np_ % block_n == 0 and fp % 128 == 0, (
        f"unpadded shapes {(np_, fp)} vs block_n={block_n}")
    t = np_ // block_n
    d2n, ts = pl.pallas_call(
        _round_kernel,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, block_n, fp), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, block_n, 1), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, 1, fp), lambda bb, i: (bb, 0, 0)),
            pl.BlockSpec((1, block_n, 1), lambda bb, i: (bb, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n, 1), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, 1), lambda bb, i: (bb, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, np_, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, t), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, xn[..., None], c, d2[..., None])
    return d2n[..., 0], ts


def _round_twin(x: jax.Array, xn: jax.Array, c: jax.Array, d2: jax.Array,
                *, block_n: int) -> tuple[jax.Array, jax.Array]:
    """Tile-mirrored XLA twin of :func:`kmeanspp_round`: same cross-term
    distance form, same tile decomposition of the partial sums — the
    off-TPU production path and the kernel's chosen-index parity oracle."""
    cross = jnp.matmul(x, jnp.swapaxes(c, 1, 2))[:, :, 0]        # (B, Np)
    cn = jnp.sum(c * c, axis=2)                                  # (B, 1)
    nd = jnp.maximum(xn - 2.0 * cross + cn, 0.0)
    d2n = jnp.minimum(d2, nd)
    b, np_ = d2n.shape
    ts = jnp.sum(d2n.reshape(b, np_ // block_n, block_n), axis=2)
    return d2n, ts


def _select_index(d2: jax.Array, ts: jax.Array, u: jax.Array,
                  block_n: int, n: int) -> jax.Array:
    """Two-level inverse-CDF: tile from the T partial sums, row offset
    from the chosen tile's bn entries. One uniform per problem; zero-mass
    (padded or already-chosen) rows never advance the CDF."""
    if ts.shape[1] == 1:
        # single tile: the inner cumsum IS the whole CDF
        inner = jnp.cumsum(d2, axis=1)                           # (B, bn)
        tgt = u * inner[:, -1]
        off = jnp.sum((inner <= tgt[:, None]).astype(jnp.int32), axis=1)
        return jnp.minimum(off, n - 1)
    cum = jnp.cumsum(ts, axis=1)                                 # (B, T)
    target = u * cum[:, -1]                                      # (B,)
    tile = jnp.sum((cum <= target[:, None]).astype(jnp.int32), axis=1)
    tile = jnp.minimum(tile, ts.shape[1] - 1)
    prev = jnp.where(
        tile > 0,
        jnp.take_along_axis(cum, jnp.maximum(tile - 1, 0)[:, None],
                            axis=1)[:, 0],
        0.0)
    b = d2.shape[0]
    d2t = jnp.take_along_axis(d2.reshape(b, -1, block_n),
                              tile[:, None, None], axis=1)[:, 0]
    inner = jnp.cumsum(d2t, axis=1)                              # (B, bn)
    off = jnp.sum((inner <= (target - prev)[:, None]).astype(jnp.int32),
                  axis=1)
    off = jnp.minimum(off, block_n - 1)
    return jnp.minimum(tile * block_n + off, n - 1)


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "use_kernel", "interpret"))
def _init_impl(keys: jax.Array, x: jax.Array, *, k: int, block_n: int,
               use_kernel: bool, interpret: bool) -> jax.Array:
    b, n, f = x.shape
    xf = x.astype(jnp.float32)
    np_ = _round_up(n, block_n)
    # the kernel wants lane-aligned features resident; the twin runs
    # unpadded (zero feature columns add nothing but bytes)
    fp = _round_up(f, 128) if use_kernel else f
    xp = jnp.pad(xf, ((0, 0), (0, np_ - n), (0, fp - f)))
    xn = jnp.sum(xp * xp, axis=2)
    d2_0 = jnp.broadcast_to(
        jnp.where(jnp.arange(np_) < n, jnp.inf, 0.0), (b, np_))

    def _draws(key: jax.Array) -> tuple:
        k0, ku = jax.random.split(key)
        return (jax.random.randint(k0, (), 0, n),
                jax.random.uniform(ku, (k - 1,)))

    i0, us = jax.vmap(_draws)(keys)                  # (B,), (B, K-1)
    first = jnp.take_along_axis(xp, i0[:, None, None], axis=1)   # (B,1,fp)

    round_fn = (functools.partial(kmeanspp_round, block_n=block_n,
                                  interpret=interpret) if use_kernel
                else functools.partial(_round_twin, block_n=block_n))

    # the loop carries (B, K) chosen-row indices, not the centroid stack:
    # one int32 write per round beats a (B, K, F) copy, and a single
    # gather at the end materializes the centroids
    idx0 = jnp.zeros((b, k), jnp.int32).at[:, 0].set(i0)

    def body(i, carry):
        idx, d2, last = carry
        d2, ts = round_fn(xp, xn, last, d2)
        sel = _select_index(d2, ts, us[:, i - 1], block_n, n)
        nxt = jnp.take_along_axis(xp, sel[:, None, None], axis=1)
        return idx.at[:, i].set(sel), d2, nxt

    idx, _, _ = jax.lax.fori_loop(1, k, body, (idx0, d2_0, first))
    return jnp.take_along_axis(xf, idx[..., None], axis=1).astype(x.dtype)


def init_kmeanspp_fused(keys: jax.Array, x: jax.Array, k: int, *,
                        params=None, block_n: int = None,
                        use_kernel: bool = None,
                        interpret: bool = None) -> jax.Array:
    """Fused k-means++ seeding for B stacked problems.

    keys (B, 2) per-problem PRNG keys, x (B, N, F) stacked samples.
    Returns (B, K, F) centroids in ``x.dtype``. ``use_kernel=None``
    auto-selects the Pallas round kernel on TPU and the tile-mirrored XLA
    twin elsewhere — both drive the identical round/selection protocol,
    and per seed they choose the same indices (the parity contract
    ``tests/test_seeding.py`` pins). ``block_n``/``params`` override the
    tile size (``params.block_m`` wins the autotune ``"init"``-kind
    lookup); ``interpret`` only affects the kernel path.
    """
    from repro.kernels.ops import on_tpu
    b, n, f = x.shape
    if use_kernel is None:
        use_kernel = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    if block_n is None:
        if params is not None:
            block_n = params.block_m
        elif use_kernel:
            from repro.api.cache import default_cache
            _, p = default_cache().lookup(n, k, f, kind="init")
            block_n = p.block_m
        else:
            # twin path: no launch grid to amortize off-TPU, so the tile
            # size only shapes the two-level CDF — small tiles keep both
            # cumsums short (XLA CPU cumsum cost grows superlinearly in
            # row length, so one long cumsum loses to tile-sum + gather)
            block_n = TWIN_BLOCK_N
    block_n = clamp_init_block(n, block_n)
    return _init_impl(keys, x, k=k, block_n=block_n,
                      use_kernel=use_kernel, interpret=interpret)
