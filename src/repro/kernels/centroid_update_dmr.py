"""DMR-fused centroid-update kernel (paper §I/§IV: "DMR protects the
memory-bound update phase for <1%").

The paper's argument only holds if the duplicated arithmetic shares ONE
load of the samples: at the XLA level two calls to the update read HBM
twice (2x cost for a memory-bound op). This kernel makes the claim
structural on TPU: each (bm, F) sample tile is staged into VMEM once and
accumulated into TWO independent (K, F) sum buffers + count buffers; a
mismatch between replicas flags an SEU in the accumulation arithmetic.

Grid: (M/bm,) — sequential on a TensorCore, outputs revisited.
Outputs: sums (K, F), counts (1, K), shadow sums/counts, mismatch flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(x_ref, a_ref, sums_ref, counts_ref, sums2_ref, counts2_ref,
            bad_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        sums2_ref[...] = jnp.zeros_like(sums2_ref)
        counts2_ref[...] = jnp.zeros_like(counts2_ref)
        bad_ref[...] = jnp.zeros_like(bad_ref)

    x = x_ref[...]                                   # (bm, F) one VMEM load
    a = a_ref[...]                                   # (bm, 1) assignments
    k = sums_ref.shape[0]
    onehot = (a == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], k), 1)).astype(jnp.float32)   # (bm, K)

    # primary replica
    part = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    cnt = jnp.sum(onehot, axis=0, keepdims=True)     # (1, K)
    sums_ref[...] += part
    counts_ref[...] += cnt

    # shadow replica: same VMEM-resident tile, independent arithmetic
    # (reversed accumulation order so an MXU/VPU SEU can't hit both
    # identically; optimization_barrier-free because the buffers differ).
    part2 = jax.lax.dot_general(onehot[::-1], x[::-1],
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    cnt2 = jnp.sum(onehot[::-1], axis=0, keepdims=True)
    sums2_ref[...] += part2
    counts2_ref[...] += cnt2

    nf = pl.num_programs(0)

    @pl.when(i == nf - 1)
    def _compare():
        diff = jnp.max(jnp.abs(sums_ref[...] - sums2_ref[...]))
        dcnt = jnp.max(jnp.abs(counts_ref[...] - counts2_ref[...]))
        tol = 1e-4 * jnp.maximum(jnp.max(jnp.abs(sums_ref[...])), 1.0)
        mismatch = jnp.logical_or(diff > tol, dcnt > 0)
        bad_ref[...] = mismatch.astype(jnp.int32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("k", "block_m", "interpret"))
def centroid_update_dmr(x: jax.Array, assign: jax.Array, k: int,
                        *, block_m: int = 1024,
                        interpret: bool = False):
    """Per-cluster sums/counts with in-kernel DMR.

    x (M, F) f32, assign (M,) int32. Returns (sums (K,F), counts (K,),
    mismatch flag). M must be padded to block_m with assign = -1 (padded
    rows match no cluster).
    """
    m, f = x.shape
    assert m % block_m == 0
    grid = (m // block_m,)
    sums, counts, sums2, counts2, bad = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, f), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, f), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((k, f), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, assign[:, None].astype(jnp.int32))
    return sums, counts[0], bad[0, 0]
