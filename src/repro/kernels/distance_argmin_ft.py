"""Fault-tolerant fused distance + argmin kernel (paper §IV, Fig. 6 — TPU).

Extends ``distance_argmin`` with the paper's dual-checksum ABFT, fully fused
into the tile loop:

  * while streaming feature tiles, the *expected* checksums of the cross
    product D = X C^T are accumulated from the inputs already resident in
    VMEM (never re-read from HBM — the TPU analogue of the paper's "no
    register reuse after cp.async" constraint):
        col1 += (e1^T X_t) C_t^T        col2 += (e2^T X_t) C_t^T
        row1 += X_t (C_t^T e1)          row2 += X_t (C_t^T e2)
    e1 = ones, e2 = [1..b] (location encoding), at tile-local indices;
  * at the verification interval (the last feature step of each (m, k)
    tile — the paper's ``k % 256 == 0`` boundary maps to the tile
    boundary on TPU), the observed checksums of the accumulator are
    compared; a residual above threshold *locates* the corrupted element
    via the e2/e1 ratio and the kernel corrects it in place, then runs the
    fused min/argmin epilogue on the *corrected* tile;
  * an optional injection descriptor adds a delta into the accumulator
    mid-stream (a simulated SEU in the MXU output), exercising the whole
    detect->locate->correct path inside one kernel launch.

Checksum arithmetic is O((bm + bk) * bf) per tile against the tile's
O(bm * bk * bf) MACs — e.g. ~1.2 % extra FLOPs at (256, 128) tiles; the
measured overhead is benchmarked in benchmarks/bench_ft_overhead.py.

X and C tiles may be f32, bf16 or fp16 (the dtype axis of the §III-B
template family); the main product accumulates in f32 and the checksums are
computed from f32 casts of the resident tiles. The detection threshold is
dtype-aware (``checksum.threshold_factor``): on backends that round the
main product's partial terms to the *input* precision, a clean bf16/fp16
tile's residual sits at bf16/fp16 rounding level, so the threshold scales
with ``max(eps_input, eps_f32)`` instead of assuming f32 everywhere. This
FT template keeps the generic (revisited-output) grid for all K: its
checksum scratch already holds everything VMEM-resident, so the small-K
fast path buys nothing here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.kernels.distance_argmin import (MIN_INIT, fold_min,
                                           tile_min_argmin)


def threshold_factor(n: int, input_dtype) -> float:
    """Dtype-aware detection-threshold factor (lazy import: repro.core's
    package init imports the api layer, which imports this package)."""
    from repro.core.checksum import threshold_factor as _tf
    return _tf(n, input_dtype)

# Injection descriptor layout (SMEM scalars):
# [enabled, m_tile, c_tile, f_tile, row_in_tile, col_in_tile] + delta (f32).
INJ_LEN = 8
# One protected interval: the distance GEMM (detect+locate+correct in
# kernel). The registry's ``protected_intervals`` must agree with this.
INJ_SLOTS = 1


def _kernel(inj_ref, x_ref, c_ref, cn_ref,
            mind_ref, argmin_ref, det_ref,
            acc_ref, col1_ref, col2_ref, row1_ref, row2_ref):
    m_idx = pl.program_id(0)
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nf = pl.num_programs(2)
    bm, bk = acc_ref.shape
    bf = x_ref.shape[1]

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        # running minimum starts at +float32 max so any distance wins
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)
        det_ref[...] = jnp.zeros_like(det_ref)

    @pl.when(f_idx == 0)
    def _init_scratch():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        col1_ref[...] = jnp.zeros_like(col1_ref)
        col2_ref[...] = jnp.zeros_like(col2_ref)
        row1_ref[...] = jnp.zeros_like(row1_ref)
        row2_ref[...] = jnp.zeros_like(row2_ref)

    x = x_ref[...]
    c = c_ref[...]

    # --- main MXU product (native dtype in, f32 accumulate) -----------------
    acc_ref[...] += jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # --- expected checksums, from VMEM-resident tiles (paper lines 15-24) ---
    # Checksums run in f32 regardless of the input dtype: products of
    # 2-byte values are exactly representable in f32, so the residual of a
    # clean bf16/fp16 tile stays at f32 rounding level and the f32-eps
    # threshold below applies unchanged.
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    w_m = jax.lax.broadcasted_iota(jnp.float32, (bm, 1), 0) + 1.0   # e2 rows
    w_k = jax.lax.broadcasted_iota(jnp.float32, (1, bk), 1) + 1.0   # e2 cols
    e1x = jnp.sum(xf, axis=0, keepdims=True)                 # (1, bf)
    e2x = jnp.sum(w_m * xf, axis=0, keepdims=True)           # (1, bf)
    ce1 = jnp.sum(cf, axis=0, keepdims=True)                 # (1, bf)
    ce2 = jnp.sum(w_k.reshape(bk, 1) * cf, axis=0, keepdims=True)
    dot_t = lambda a, b: jax.lax.dot_general(                # a (1|bm, bf) x b (bk|1, bf)^T
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col1_ref[...] += dot_t(e1x, cf)                          # (1, bk)
    col2_ref[...] += dot_t(e2x, cf)                          # (1, bk)
    row1_ref[...] += dot_t(xf, ce1)                          # (bm, 1)
    row2_ref[...] += dot_t(xf, ce2)                          # (bm, 1)

    # --- simulated SEU in the accumulator (compute-unit error) --------------
    hit = jnp.logical_and(
        inj_ref[0] > 0,
        jnp.logical_and(
            jnp.logical_and(m_idx == inj_ref[1], c_idx == inj_ref[2]),
            f_idx == inj_ref[3]))

    @pl.when(hit)
    def _inject():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        mask = jnp.logical_and(rows == inj_ref[4], cols == inj_ref[5])
        delta = jax.lax.bitcast_convert_type(inj_ref[6], jnp.float32)
        acc_ref[...] += jnp.where(mask, delta, 0.0)

    # --- verification interval: detect -> locate -> correct -> reduce -------
    @pl.when(f_idx == nf - 1)
    def _verify_and_reduce():
        acc = acc_ref[...]
        obs_col1 = jnp.sum(acc, axis=0, keepdims=True)            # (1, bk)
        obs_col2 = jnp.sum(w_m * acc, axis=0, keepdims=True)
        obs_row1 = jnp.sum(acc, axis=1, keepdims=True)            # (bm, 1)
        obs_row2 = jnp.sum(w_k * acc, axis=1, keepdims=True)

        res_col1 = obs_col1 - col1_ref[...]
        res_col2 = obs_col2 - col2_ref[...]
        res_row1 = obs_row1 - row1_ref[...]
        res_row2 = obs_row2 - row2_ref[...]

        # grid is static -> the factor is a trace-time constant; the eps
        # inside tracks the input dtype's rounding of the main accumulator
        # (bf16/fp16 tiles), not bare f32 eps. The magnitude scale comes
        # from the *expected* checksums — the invariant side, computed from
        # clean inputs — never from the possibly-corrupted accumulator: a
        # corrupted-side scale lets a large delta inflate its own threshold
        # past itself whenever the factor exceeds 1 (bf16 at wide tiles),
        # self-masking exactly the errors worth catching.
        scale = jnp.maximum(jnp.maximum(jnp.max(jnp.abs(col1_ref[...])),
                                        jnp.max(jnp.abs(row1_ref[...]))), 1.0)
        thr = jnp.float32(threshold_factor(nf * bf, x_ref.dtype)) * scale

        detected = jnp.logical_or(jnp.max(jnp.abs(res_col1)) > thr,
                                  jnp.max(jnp.abs(res_row1)) > thr)

        # Locate: argmax |column residual| gives j and delta; e2/e1 ratio of
        # the row residuals gives i (and vice versa as fallback).
        j = jnp.argmax(jnp.abs(res_col1[0, :])).astype(jnp.int32)
        delta_col = res_col1[0, j]
        i_direct = jnp.argmax(jnp.abs(res_row1[:, 0])).astype(jnp.int32)
        safe = jnp.where(delta_col == 0.0, 1.0, delta_col)
        i_ratio = (jnp.round(res_col2[0, j] / safe) - 1.0).astype(jnp.int32)
        use_ratio = jnp.abs(delta_col) > thr
        i = jnp.clip(jnp.where(use_ratio, i_ratio, i_direct), 0, bm - 1)
        delta_row = res_row1[i, 0]
        delta = jnp.where(jnp.abs(delta_col) > jnp.abs(delta_row),
                          delta_col, delta_row)
        safe_r = jnp.where(delta_row == 0.0, 1.0, delta_row)
        j_ratio = (jnp.round(res_row2[i, 0] / safe_r) - 1.0).astype(jnp.int32)
        j = jnp.where(use_ratio, j, jnp.clip(j_ratio, 0, bk - 1))

        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        corrected = acc - jnp.where(
            jnp.logical_and(rows == i, cols == j), delta, 0.0)
        acc = jnp.where(detected, corrected, acc)
        acc_ref[...] = acc
        det_ref[...] += detected.astype(jnp.int32)

        # --- fused epilogue on the corrected tile ---------------------------
        local_min, local_arg = tile_min_argmin(acc, cn_ref[...], c_idx * bk)
        fold_min(mind_ref, argmin_ref, local_min, local_arg)


def no_injection() -> jax.Array:
    return jnp.zeros((INJ_LEN,), jnp.int32)


def make_injection(m_tile: int, c_tile: int, f_tile: int,
                   row: int, col: int, delta: float) -> jax.Array:
    """Build an injection descriptor (delta carried bit-cast in an int32)."""
    dbits = jnp.asarray(delta, jnp.float32).view(jnp.int32)
    return jnp.array([1, m_tile, c_tile, f_tile, row, col, dbits, 0],
                     jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "interpret"))
def distance_argmin_ft(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    inj: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FT fused kernel. Returns (min_d (M,1), argmin (M,1), det (m_tiles,1)).

    det[i] counts corrected errors in row-tile i; sum for the campaign total.
    """
    m, f = x.shape
    k = c.shape[0]
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0
    grid = (m // block_m, k // block_k, f // block_f)

    kernel = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
            jax.ShapeDtypeStruct((m // block_m, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_k), jnp.float32),
            pltpu.VMEM((1, block_k), jnp.float32),
            pltpu.VMEM((1, block_k), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(inj, x, c, cn)
