"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics the kernels are tested against (pytest sweeps
shapes/dtypes and asserts allclose). They are also the fallback execution
path on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import checksum


def distance_matrix(x: jax.Array, c: jax.Array) -> jax.Array:
    """Full squared-distance matrix ||x_i - c_j||^2, shape (M, K), f32.

    Mirrors the kernel templates' dtype semantics: the GEMM multiplies in
    the input dtype (f32/bf16/fp16) but accumulates in f32, and norms are
    computed in f32 — so this oracle is comparable to the Pallas kernels
    at every compute dtype.
    """
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1, keepdims=True)        # (M, 1)
    cn = jnp.sum(cf * cf, axis=1)[None, :]              # (1, K)
    cross = jnp.matmul(x, c.T, precision=jax.lax.Precision.HIGHEST,
                       preferred_element_type=jnp.float32)
    return xn + cn - 2.0 * cross


def distance_argmin(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused kernel: (min partial distance, argmin).

    The fused kernel omits the per-row constant ||x_i||^2 (irrelevant to the
    argmin); the returned min distance is therefore
    ``||c_j||^2 - 2 x_i . c_j`` for the winning j. Use
    ``min_dist + sum(x**2, -1)`` for true squared distances.
    """
    cf = c.astype(jnp.float32)
    cn = jnp.sum(cf * cf, axis=1)[None, :]
    cross = jnp.matmul(x, c.T, precision=jax.lax.Precision.HIGHEST,
                       preferred_element_type=jnp.float32)
    d = cn - 2.0 * cross
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32)


def distance_argmin_ft(
    x: jax.Array,
    c: jax.Array,
    inject_delta: jax.Array | None = None,
    inject_pos: tuple[int, int] | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the FT fused kernel.

    Simulates one SEU in the distance tile (additive delta at inject_pos of
    the cross-product matrix), then applies dual-checksum verify + correct,
    then reduces. Returns (min_dist, argmin, detected_count).
    """
    cn = jnp.sum(c * c, axis=1)[None, :]
    cross = jnp.matmul(x, c.T, precision=jax.lax.Precision.HIGHEST)
    expected = checksum.expected_checksums(x, c.T)
    detected_count = jnp.zeros((), jnp.int32)
    if inject_delta is not None and inject_pos is not None:
        cross = cross.at[inject_pos].add(inject_delta)
    scale = jnp.maximum(jnp.max(jnp.abs(cross)), 1.0)
    thr = checksum.default_threshold(x.shape[1], cross.dtype) * scale
    verdict = checksum.verify(cross, expected, thr)
    cross = checksum.correct(cross, verdict)
    detected_count = detected_count + verdict.detected.astype(jnp.int32)
    d = cn - 2.0 * cross
    return jnp.min(d, axis=1), jnp.argmin(d, axis=1).astype(jnp.int32), detected_count


def lloyd_step(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array,
                                                    jax.Array, jax.Array]:
    """Oracle for the one-pass Lloyd kernel.

    Returns (min partial distance, argmin, sums (K, F), counts (K,)) —
    the assignment semantics of :func:`distance_argmin` plus the
    per-cluster sums/counts of :func:`centroid_update`, all from one pass.
    """
    md, am = distance_argmin(x, c)
    sums, counts = centroid_update(x, am, c.shape[0])
    return md, am, sums, counts


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for the ABFT matmul kernel: plain product."""
    return jnp.matmul(x, y, precision=jax.lax.Precision.HIGHEST)


def centroid_update(x: jax.Array, assign: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for the centroid-update: per-cluster sums and counts.

    Returns (sums (K, N) f32, counts (K,) f32). The mean (= new centroids)
    is sums / max(counts, 1); callers handle empty clusters. Accumulation
    is f32 for every input dtype (bf16 counts would lose exactness past
    256 members) — the same contract as the one-pass kernel's epilogue.
    """
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)   # (M, K)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
    return sums, counts
