"""Fault-tolerant one-pass Lloyd kernel (paper §IV Fig. 6 composed with §III
Fig. 4 — the ABFT epilogue on the fused-update iteration).

``lloyd_step`` fused the centroid update's accumulation into the assignment
kernel so X is read from HBM once per iteration; ``distance_argmin_ft``
fused the paper's dual-checksum ABFT into the distance GEMM. Before this
kernel the two were mutually exclusive: enabling fault tolerance forfeited
the one-pass speedup. This kernel is their composition — both protection
layers ride the same streamed tiles:

  * **distance GEMM** (compute-bound): the e1/e2 column/row checksums of
    D = X C^T accumulate from the VMEM-resident tiles exactly as in
    ``distance_argmin_ft``; at the verification interval (last feature
    step of each (m, k) tile) a residual above the dtype-aware threshold
    locates the corrupted accumulator element via the e2/e1 ratio and the
    kernel corrects it in place — the min/argmin epilogue and the update
    epilogue both run on the *corrected* accumulator;
  * **update epilogue** (the one-hot MXU product): alongside each row
    tile's partial per-cluster sums/counts the kernel emits their
    *expected* e1/e2 column checksums, computed from the argmin/valid
    vectors and the stashed X tiles — an arithmetic path disjoint from
    the one-hot product they verify:

        e1^T (onehot^T X) = (onehot e1)^T X = valid^T X
        e2^T (onehot^T X) = (onehot e2)^T X = (valid * (argmin+1))^T X

    The jitted tree-reduction (``ops.fused_lloyd_ft``) compares the
    observed checksums of the emitted partial blocks against these and
    *recomputes* a mismatched tile from the data plan and the corrected
    assignment — the recompute replays the kernel's own arithmetic, so a
    recovered run is bit-identical to a clean one. This supersedes the
    host-side DMR of the two-pass update for fused backends.

The injection descriptor carries two independent SEU slots — one for the
distance GEMM accumulator, one for the one-hot update product — matching
the two independently verified intervals a single Lloyd step exposes
(§II-A: at most one error per detection interval).

Like ``distance_argmin_ft`` this template keeps the generic
(revisited-output) grid for all K: the checksum scratch is already
VMEM-resident, so the small-K fast path buys nothing here. X and C tiles
may be f32, bf16 or fp16; accumulators, checksums and outputs are f32 and
the detection thresholds scale with the input dtype's rounding
(``checksum.threshold_factor``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.distance_argmin import MIN_INIT, fold_min, tile_min_argmin
from repro.kernels.distance_argmin_ft import threshold_factor
from repro.kernels.lloyd_step import (STASH_SLOTS, _emit_update,
                                      _stash_dma_start, _stash_dma_wait_last)

# SMEM metadata layout: [true_m] — rows >= true_m are padding and must not
# contribute to sums/counts.
META_LEN = 1

# Injection descriptor (SMEM scalars): two independent SEU slots.
#   distance slot: [0] enabled, [1] m_tile, [2] c_tile, [3] f_tile,
#                  [4] row_in_tile, [5] col_in_tile, [6] delta (f32 bits)
#   update slot:   [7] enabled, [8] m_tile, [9] cluster_row,
#                  [10] feature_col, [11] delta (f32 bits)
INJ_LEN = 12
# Two protected intervals: the distance GEMM and the update epilogue —
# one descriptor slot each. The registry's ``protected_intervals`` must
# agree with this.
INJ_SLOTS = 2


def no_injection() -> jax.Array:
    return jnp.zeros((INJ_LEN,), jnp.int32)


def _f32_bits(delta: float) -> int:
    return int(np.float32(delta).view(np.int32))


def make_injection(*, distance: Optional[tuple] = None,
                   update: Optional[tuple] = None) -> jax.Array:
    """Build a descriptor with either or both SEU slots armed.

    distance = (m_tile, c_tile, f_tile, row_in_tile, col_in_tile, delta)
    update   = (m_tile, cluster_row, feature_col, delta) — coordinates in
               the *padded* (K, F) partial-sum block of that row tile.
    """
    desc = np.zeros((INJ_LEN,), np.int32)
    if distance is not None:
        mt, ct, ft, row, col, delta = distance
        desc[0:7] = [1, mt, ct, ft, row, col, _f32_bits(delta)]
    if update is not None:
        mt, row, col, delta = update
        desc[7:12] = [1, mt, row, col, _f32_bits(delta)]
    return jnp.asarray(desc)


def _kernel(meta_ref, inj_ref, x_ref, c_ref, cn_ref,
            mind_ref, argmin_ref, det_ref, sums_ref, counts_ref,
            ucheck_ref, ccheck_ref,
            acc_ref, col1_ref, col2_ref, row1_ref, row2_ref, xbuf_ref,
            sem_ref):
    """One (bm, bk) distance tile with fused ABFT + the protected update
    epilogue.

    meta_ref  : (1,)        SMEM — [true_m]
    inj_ref   : (INJ_LEN,)  SMEM — dual-slot injection descriptor
    x_ref     : (bm, bf)    sample tile
    c_ref     : (bk, bf)    centroid tile
    cn_ref    : (1, bk)     centroid squared norms (+inf for padded slots)
    mind_ref  : (bm, 1)     running minimum of d_ij  (output, revisited)
    argmin_ref: (bm, 1)     running argmin           (output, revisited)
    det_ref   : (1, 1)      corrected distance-GEMM errors in this row tile
    sums_ref  : (1, kp, fp) per-row-tile partial cluster sums (output)
    counts_ref: (1, kp)     per-row-tile partial cluster counts (output)
    ucheck_ref: (1, 2, fp)  expected e1/e2 column checksums of the sums
    ccheck_ref: (1, 2)      expected e1/e2 checksums of the counts
    acc/colN/rowN          : ABFT scratch as in ``distance_argmin_ft``
    xbuf_ref  : (bm, fp)    VMEM stash of the row tile's feature chunks
    sem_ref   : (2,)        DMA semaphores for the double-buffered stash
    """
    m_idx = pl.program_id(0)
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nk = pl.num_programs(1)
    nf = pl.num_programs(2)
    bm, bk = acc_ref.shape
    bf = x_ref.shape[1]

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)
        det_ref[...] = jnp.zeros_like(det_ref)

    @pl.when(f_idx == 0)
    def _init_scratch():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        col1_ref[...] = jnp.zeros_like(col1_ref)
        col2_ref[...] = jnp.zeros_like(col2_ref)
        row1_ref[...] = jnp.zeros_like(row1_ref)
        row2_ref[...] = jnp.zeros_like(row2_ref)

    # Stash the streamed X tile on its first visit: the update epilogue
    # reuses it from VMEM instead of a second HBM read. Async, so the copy
    # overlaps this step's MXU + checksum products (the double-buffered
    # stash shared with the unprotected kernel).
    @pl.when(c_idx == 0)
    def _stash_x():
        _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf)

    x = x_ref[...]
    c = c_ref[...]

    # --- main MXU product (native dtype in, f32 accumulate) -----------------
    acc_ref[...] += jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # --- expected checksums, from VMEM-resident tiles (paper lines 15-24) ---
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    w_m = jax.lax.broadcasted_iota(jnp.float32, (bm, 1), 0) + 1.0   # e2 rows
    w_k = jax.lax.broadcasted_iota(jnp.float32, (1, bk), 1) + 1.0   # e2 cols
    e1x = jnp.sum(xf, axis=0, keepdims=True)                 # (1, bf)
    e2x = jnp.sum(w_m * xf, axis=0, keepdims=True)           # (1, bf)
    ce1 = jnp.sum(cf, axis=0, keepdims=True)                 # (1, bf)
    ce2 = jnp.sum(w_k.reshape(bk, 1) * cf, axis=0, keepdims=True)
    dot_t = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col1_ref[...] += dot_t(e1x, cf)                          # (1, bk)
    col2_ref[...] += dot_t(e2x, cf)                          # (1, bk)
    row1_ref[...] += dot_t(xf, ce1)                          # (bm, 1)
    row2_ref[...] += dot_t(xf, ce2)                          # (bm, 1)

    # --- simulated SEU in the distance accumulator --------------------------
    hit = jnp.logical_and(
        inj_ref[0] > 0,
        jnp.logical_and(
            jnp.logical_and(m_idx == inj_ref[1], c_idx == inj_ref[2]),
            f_idx == inj_ref[3]))

    @pl.when(hit)
    def _inject():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        mask = jnp.logical_and(rows == inj_ref[4], cols == inj_ref[5])
        delta = jax.lax.bitcast_convert_type(inj_ref[6], jnp.float32)
        acc_ref[...] += jnp.where(mask, delta, 0.0)

    # --- verification interval: detect -> locate -> correct -> reduce -------
    @pl.when(f_idx == nf - 1)
    def _verify_and_reduce():
        acc = acc_ref[...]
        obs_col1 = jnp.sum(acc, axis=0, keepdims=True)            # (1, bk)
        obs_col2 = jnp.sum(w_m * acc, axis=0, keepdims=True)
        obs_row1 = jnp.sum(acc, axis=1, keepdims=True)            # (bm, 1)
        obs_row2 = jnp.sum(w_k * acc, axis=1, keepdims=True)

        res_col1 = obs_col1 - col1_ref[...]
        res_col2 = obs_col2 - col2_ref[...]
        res_row1 = obs_row1 - row1_ref[...]
        res_row2 = obs_row2 - row2_ref[...]

        # static grid -> trace-time constant factor; dtype-aware eps. The
        # magnitude scale comes from the *expected* checksums (the clean
        # invariant side), never the possibly-corrupted accumulator —
        # a corrupted-side scale would let a large delta inflate its own
        # threshold past itself (self-masking) once the factor exceeds 1.
        scale = jnp.maximum(jnp.maximum(jnp.max(jnp.abs(col1_ref[...])),
                                        jnp.max(jnp.abs(row1_ref[...]))), 1.0)
        thr = jnp.float32(threshold_factor(nf * bf, x_ref.dtype)) * scale

        detected = jnp.logical_or(jnp.max(jnp.abs(res_col1)) > thr,
                                  jnp.max(jnp.abs(res_row1)) > thr)

        # Locate: argmax |column residual| gives j and delta; e2/e1 ratio of
        # the row residuals gives i (and vice versa as fallback).
        j = jnp.argmax(jnp.abs(res_col1[0, :])).astype(jnp.int32)
        delta_col = res_col1[0, j]
        i_direct = jnp.argmax(jnp.abs(res_row1[:, 0])).astype(jnp.int32)
        safe = jnp.where(delta_col == 0.0, 1.0, delta_col)
        i_ratio = (jnp.round(res_col2[0, j] / safe) - 1.0).astype(jnp.int32)
        use_ratio = jnp.abs(delta_col) > thr
        i = jnp.clip(jnp.where(use_ratio, i_ratio, i_direct), 0, bm - 1)
        delta_row = res_row1[i, 0]
        delta = jnp.where(jnp.abs(delta_col) > jnp.abs(delta_row),
                          delta_col, delta_row)
        safe_r = jnp.where(delta_row == 0.0, 1.0, delta_row)
        j_ratio = (jnp.round(res_row2[i, 0] / safe_r) - 1.0).astype(jnp.int32)
        j = jnp.where(use_ratio, j, jnp.clip(j_ratio, 0, bk - 1))

        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        corrected = acc - jnp.where(
            jnp.logical_and(rows == i, cols == j), delta, 0.0)
        acc = jnp.where(detected, corrected, acc)
        acc_ref[...] = acc
        det_ref[...] += detected.astype(jnp.int32)

        # --- fused min/argmin epilogue on the corrected tile ----------------
        local_min, local_arg = tile_min_argmin(acc, cn_ref[...], c_idx * bk)
        fold_min(mind_ref, argmin_ref, local_min, local_arg)

    # --- protected update epilogue: argmin for this row tile is final -------
    @pl.when(jnp.logical_and(c_idx == nk - 1, f_idx == nf - 1))
    def _update_epilogue():
        kp = counts_ref.shape[1]
        fp = xbuf_ref.shape[1]
        _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf)
        # the one-hot product itself is the unprotected kernel's epilogue,
        # shared verbatim — the bit-identity contract between this kernel,
        # the plain lloyd kernel and the recompute in
        # ops._verify_update_partials rests on one definition
        _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                     m_idx, bm)
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + m_idx * bm
        valid = (rows < meta_ref[0]).astype(jnp.float32)           # (bm, 1)

        # expected checksums of the one-hot product, from the argmin/valid
        # vectors and the stashed tiles — never from the product itself
        amp1 = valid * (argmin_ref[...] + 1).astype(jnp.float32)   # (bm, 1)
        enc = jnp.concatenate([valid, amp1], axis=1)               # (bm, 2)
        ucheck_ref[...] = jax.lax.dot_general(
            enc, xbuf_ref[...].astype(jnp.float32),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]              # (1,2,fp)
        ccheck_ref[...] = jnp.sum(enc, axis=0, keepdims=True)      # (1, 2)

        # simulated SEU in the one-hot update product — applied after the
        # invariant side is recorded (inputs are ECC's job, per §II-A)
        uhit = jnp.logical_and(inj_ref[7] > 0, m_idx == inj_ref[8])

        @pl.when(uhit)
        def _inject_update():
            krows = jax.lax.broadcasted_iota(jnp.int32, (kp, fp), 0)
            fcols = jax.lax.broadcasted_iota(jnp.int32, (kp, fp), 1)
            mask = jnp.logical_and(krows == inj_ref[9], fcols == inj_ref[10])
            udelta = jax.lax.bitcast_convert_type(inj_ref[11], jnp.float32)
            sums_ref[...] += jnp.where(mask, udelta, 0.0)[None]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "interpret"))
def lloyd_step_ft(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    meta: jax.Array,
    inj: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Raw one-pass FT kernel entry. Shapes must be pre-padded to the grid.

    x (M, F) samples, c (K, F) centroids (f32/bf16/fp16), cn (1, K) f32
    centroid sq-norms with +inf in padded slots, meta (1,) int32 =
    [true_m], inj (INJ_LEN,) int32 dual-slot injection descriptor.
    Returns (min_d (M, 1), argmin (M, 1), det (M/bm, 1),
    sums (M/bm, K, F), counts (M/bm, K), ucheck (M/bm, 2, F),
    ccheck (M/bm, 2)); verify + reduce the partial blocks with
    ``ops.fused_lloyd_ft``.
    """
    m, f = x.shape
    k = c.shape[0]
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0, (
        f"unpadded shapes {(m, k, f)} vs blocks {(block_m, block_k, block_f)}")
    num_m = m // block_m

    kernel = pl.pallas_call(
        _kernel,
        grid=(m // block_m, k // block_k, f // block_f),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, k, f), lambda i, j, t: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, 2, f), lambda i, j, t: (i, 0, 0)),
            pl.BlockSpec((1, 2), lambda i, j, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
            jax.ShapeDtypeStruct((num_m, 1), jnp.int32),
            jax.ShapeDtypeStruct((num_m, k, f), jnp.float32),
            jax.ShapeDtypeStruct((num_m, k), jnp.float32),
            jax.ShapeDtypeStruct((num_m, 2, f), jnp.float32),
            jax.ShapeDtypeStruct((num_m, 2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, block_k), jnp.float32),
            pltpu.VMEM((1, block_k), jnp.float32),
            pltpu.VMEM((1, block_k), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, f), x.dtype),   # stash in the input dtype
            pltpu.SemaphoreType.DMA((STASH_SLOTS,)),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(meta, inj, x, c, cn)
