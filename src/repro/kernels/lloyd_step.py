"""One-pass Lloyd iteration kernel (paper §III, Fig. 4 — fused update).

``distance_argmin`` performs the distance GEMM and the min/argmin epilogue,
but the centroid *update* still re-reads X from HBM in a second pass
(``ref.centroid_update``). This kernel folds that second pass into the
assignment kernel's epilogue: while the feature tiles of X stream through
VMEM for the GEMM, they are stashed in a VMEM row-tile buffer; once the
argmin for a row tile is final (last centroid tile, last feature step), a
one-hot MXU product against the stashed tiles accumulates per-cluster
partial sums and counts into per-row-tile output blocks:

    sums   (num_m_tiles, K, F)   partial per-cluster feature sums
    counts (num_m_tiles, K)      partial per-cluster member counts

A small jitted tree-reduction (``ops.fused_lloyd``) collapses the partial
blocks to the (K, F) sums / (K,) counts the update needs — so X is read
from HBM once per centroid tile and never again, where the two-pass
pipeline paid a second full read of X plus an assignment round trip.

Grid and tiling match ``distance_argmin``: (M/bm, K/bk, F/bf), feature axis
fastest, MXU-aligned blocks, running min/argmin accumulated in the
revisited output block. Padded sample rows are masked out of the sums and
counts via the true row count carried in SMEM; padded centroid slots carry
+inf norms and never win the argmin.

This is the prerequisite shape for porting the §IV ABFT epilogue onto the
one-pass kernel: the checksum accumulators of ``distance_argmin_ft`` attach
to the same streamed tiles, and the update epilogue runs on the *corrected*
accumulator.

Template family (paper §III-B): the ``"smallk"`` variant drops the centroid
grid dimension when padded K fits one ``block_k`` tile — every row tile is
visited once, so min/argmin writes directly (no revisit compare) and the
one-hot update epilogue fires in the same grid step. X and C tiles may be
f32, bf16 or fp16; the stash buffer holds the input dtype (halving its VMEM
at 2-byte dtypes) while every accumulator and output stays f32.

Batched many-problem variant (:func:`lloyd_step_batched`): production
traffic is rarely one big clustering problem — it is thousands of
independent small ones (per-user embeddings, per-shard codebooks) whose
individual kernel launches waste the MXU. The batched template threads a
leading problem dimension ``B`` through the grid as its *outermost*
dimension ``(B, M/bm, F/bf)``: each problem carries its own centroid tile
and per-problem accumulator, and — because batched problems have small K by
construction (padded K is a single centroid tile) — every grid step reuses
the ``smallk`` epilogue, min/argmin written directly and the one-hot update
emitted in the same step. One launch amortizes B dispatches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.distance_argmin import MIN_INIT, fold_min, tile_min_argmin

# SMEM metadata layout: [true_m] — rows >= true_m are padding and must not
# contribute to sums/counts.
META_LEN = 1

# Stash DMA slots: the X-tile stash is issued as an async VMEM copy so the
# current feature step's MXU product overlaps the previous chunk's store
# (the emit-pipeline idiom). Two semaphore slots, used round-robin.
STASH_SLOTS = 2


def _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf):
    """Issue this feature chunk's stash as an async copy.

    The previous chunk's copy is drained first — at most one stash is in
    flight, so the copy issued here overlaps this grid step's MXU product
    and is waited at the *next* stash (or by ``_stash_dma_wait_last``
    before the update epilogue reads the buffer). Draining f-1 before
    issuing f also keeps the revolving input block of f-1 safe to recycle
    before the pipeline lands chunk f+1 in it.
    """
    @pl.when(f_idx >= 1)
    def _drain_prev():
        pltpu.make_async_copy(
            x_ref, xbuf_ref.at[:, pl.ds((f_idx - 1) * bf, bf)],
            sem_ref.at[(f_idx - 1) % STASH_SLOTS]).wait()

    pltpu.make_async_copy(
        x_ref, xbuf_ref.at[:, pl.ds(f_idx * bf, bf)],
        sem_ref.at[f_idx % STASH_SLOTS]).start()


def _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf):
    """Drain the final in-flight stash before an epilogue reads xbuf."""
    pltpu.make_async_copy(
        x_ref, xbuf_ref.at[:, pl.ds((nf - 1) * bf, bf)],
        sem_ref.at[(nf - 1) % STASH_SLOTS]).wait()


def _kernel(meta_ref, x_ref, c_ref, cn_ref,
            mind_ref, argmin_ref, sums_ref, counts_ref,
            acc_ref, xbuf_ref, sem_ref):
    """One (bm, bk) distance tile + the fused update epilogue.

    meta_ref  : (1,)        SMEM — [true_m]
    x_ref     : (bm, bf)    sample tile
    c_ref     : (bk, bf)    centroid tile
    cn_ref    : (1, bk)     centroid squared norms (+inf for padded slots)
    mind_ref  : (bm, 1)     running minimum of d_ij  (output, revisited)
    argmin_ref: (bm, 1)     running argmin           (output, revisited)
    sums_ref  : (1, kp, fp) per-row-tile partial cluster sums (output)
    counts_ref: (1, kp)     per-row-tile partial cluster counts (output)
    acc_ref   : (bm, bk)    VMEM scratch accumulator for X C^T
    xbuf_ref  : (bm, fp)    VMEM stash of the row tile's feature chunks
    sem_ref   : (2,)        DMA semaphores for the double-buffered stash
    """
    m_idx = pl.program_id(0)
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nk = pl.num_programs(1)
    nf = pl.num_programs(2)
    bm = acc_ref.shape[0]
    bf = x_ref.shape[1]

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Stash the streamed X tile on its first visit: the update epilogue
    # reuses it from VMEM instead of a second HBM read. The stash is an
    # async copy overlapping this step's MXU product; it is drained at the
    # next stash / before the update epilogue reads the buffer.
    @pl.when(c_idx == 0)
    def _stash_x():
        _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf)

    # MXU tile product, f32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _min_epilogue():
        local_min, local_arg = tile_min_argmin(
            acc_ref[...], cn_ref[...], c_idx * acc_ref.shape[1])
        fold_min(mind_ref, argmin_ref, local_min, local_arg)

    # Fused update epilogue: the argmin for this row tile is final — scatter
    # the stashed X tiles into per-cluster partial sums via a one-hot MXU
    # product, masking padded sample rows.
    @pl.when(jnp.logical_and(c_idx == nk - 1, f_idx == nf - 1))
    def _update_epilogue():
        _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf)
        _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                     m_idx, bm)


def _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                 m_idx, bm):
    """Shared one-hot update epilogue: final argmin -> per-cluster partial
    sums/counts for this row tile. The one-hot matrix is exact (0/1) in the
    stash dtype, so a 2-byte stash loses nothing; accumulation is f32."""
    kp = counts_ref.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + m_idx * bm
    valid = (rows < meta_ref[0]).astype(jnp.float32)           # (bm, 1)
    clusters = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    onehot = (argmin_ref[...] == clusters).astype(jnp.float32) * valid
    counts_ref[...] = jnp.sum(onehot, axis=0, keepdims=True)   # (1, kp)
    sums_ref[...] = jax.lax.dot_general(
        onehot.astype(xbuf_ref.dtype), xbuf_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]              # (1, kp, fp)


def _kernel_smallk(meta_ref, x_ref, c_ref, cn_ref,
                   mind_ref, argmin_ref, sums_ref, counts_ref,
                   acc_ref, xbuf_ref, sem_ref):
    """Small-K fast path: padded K is one centroid tile, grid (M/bm, F/bf).

    Every row tile is visited exactly once, so there is no revisited
    min/argmin accumulation: the epilogue computes min/argmin from the
    VMEM-resident accumulator, writes it directly, and emits the one-hot
    update in the same grid step."""
    m_idx = pl.program_id(0)
    f_idx = pl.program_id(1)
    nf = pl.num_programs(1)
    bm = acc_ref.shape[0]
    bf = x_ref.shape[1]

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Single centroid-tile sweep: every feature step is a first visit, so
    # every step issues its async stash (overlapping its own MXU product).
    _stash_dma_start(x_ref, xbuf_ref, sem_ref, f_idx, bf)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(acc_ref[...], cn_ref[...], 0)
        mind_ref[...] = local_min       # single visit: direct write
        argmin_ref[...] = local_arg
        _stash_dma_wait_last(x_ref, xbuf_ref, sem_ref, nf, bf)
        _emit_update(meta_ref, argmin_ref, sums_ref, counts_ref, xbuf_ref,
                     m_idx, bm)


def _kernel_batched(meta_ref, x_ref, c_ref, cn_ref,
                    mind_ref, argmin_ref, sums_ref, counts_ref,
                    acc_ref, xbuf_ref, sem_ref):
    """One problem's (bm, kp) tile of the batched grid (B, M/bm, F/bf).

    The problem index is the outermost grid dimension: every block spec
    selects problem ``b``'s slab, so the kernel body is the ``smallk``
    single-sweep epilogue on that problem's own centroid tile and
    accumulator — blocks just carry a leading length-1 problem axis.

    meta_ref  : (1,)              SMEM — [true_n] (shared: stacked problems
                                  are padded together)
    x_ref     : (1, bm, bf)       problem b's sample tile
    c_ref     : (1, kp, bf)       problem b's (single) centroid tile
    cn_ref    : (1, 1, kp)        problem b's centroid squared norms
    mind_ref  : (1, bm, 1)        min distance (output, single visit)
    argmin_ref: (1, bm, 1)        argmin       (output, single visit)
    sums_ref  : (1, 1, kp, fp)    per-row-tile partial cluster sums
    counts_ref: (1, 1, kp)        per-row-tile partial cluster counts
    acc_ref   : (bm, kp)          per-problem VMEM scratch accumulator
    xbuf_ref  : (bm, fp)          VMEM stash of the row tile's chunks
    sem_ref   : (2,)              DMA semaphores for the async stash
    """
    m_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nf = pl.num_programs(2)
    bm = acc_ref.shape[0]
    bf = x_ref.shape[2]

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Single centroid-tile sweep per problem: every feature step is a
    # first visit, so stash unconditionally (smallk rule) — async, so the
    # copy overlaps this step's MXU product.
    _stash_dma_start(x_ref.at[0], xbuf_ref, sem_ref, f_idx, bf)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], c_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(acc_ref[...], cn_ref[0], 0)
        mind_ref[0] = local_min      # single visit: direct write
        argmin_ref[0] = local_arg
        _stash_dma_wait_last(x_ref.at[0], xbuf_ref, sem_ref, nf, bf)
        kp = counts_ref.shape[-1]
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + m_idx * bm
        valid = (rows < meta_ref[0]).astype(jnp.float32)
        clusters = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
        onehot = (local_arg == clusters).astype(jnp.float32) * valid
        counts_ref[0, 0] = jnp.sum(onehot, axis=0)
        sums_ref[0, 0] = jax.lax.dot_general(
            onehot.astype(xbuf_ref.dtype), xbuf_ref[...],
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_f", "interpret"))
def lloyd_step_batched(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    meta: jax.Array,
    *,
    block_m: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Raw batched one-pass kernel entry: B independent problems, one launch.

    x (B, N, F) stacked samples, c (B, K, F) per-problem centroids (f32/
    bf16/fp16), cn (B, 1, K) f32 per-problem centroid sq-norms with +inf in
    padded slots, meta (1,) int32 = [true_n]. Shapes must be pre-padded to
    the block grid; padded K must be a single centroid tile (the smallk
    condition — batched problems have small K by construction), so K itself
    is the centroid tile and there is no ``block_k`` knob. Returns
    (min_d (B, N, 1), argmin (B, N, 1), sums (B, N/bm, K, F),
    counts (B, N/bm, K)); sum the partial blocks over axis 1 for each
    problem's (K, F) / (K,) totals.
    """
    bsz, m, f = x.shape
    k = c.shape[1]
    assert m % block_m == 0 and f % block_f == 0 and k % 128 == 0, (
        f"unpadded shapes {(bsz, m, k, f)} vs blocks "
        f"({block_m}, {k}, {block_f})")
    num_m = m // block_m

    out_shape = [
        jax.ShapeDtypeStruct((bsz, m, 1), jnp.float32),
        jax.ShapeDtypeStruct((bsz, m, 1), jnp.int32),
        jax.ShapeDtypeStruct((bsz, num_m, k, f), jnp.float32),
        jax.ShapeDtypeStruct((bsz, num_m, k), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((block_m, k), jnp.float32),
        pltpu.VMEM((block_m, f), x.dtype),   # stash in the input dtype
        pltpu.SemaphoreType.DMA((STASH_SLOTS,)),
    ]
    kernel = pl.pallas_call(
        _kernel_batched,
        grid=(bsz, num_m, f // block_f),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_m, block_f), lambda b, i, t: (b, i, t)),
            pl.BlockSpec((1, k, block_f), lambda b, i, t: (b, 0, t)),
            pl.BlockSpec((1, 1, k), lambda b, i, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda b, i, t: (b, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda b, i, t: (b, i, 0)),
            pl.BlockSpec((1, 1, k, f), lambda b, i, t: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, k), lambda b, i, t: (b, i, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return kernel(meta, x, c, cn)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "variant", "interpret"))
def lloyd_step(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    meta: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    variant: str = "generic",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Raw one-pass kernel entry. Shapes must be pre-padded to the block grid.

    x (M, F) samples, c (K, F) centroids (f32/bf16/fp16), cn (1, K) f32
    centroid sq-norms with +inf in padded slots, meta (1,) int32 =
    [true_m]. ``variant`` selects the template: ``"generic"`` or
    ``"smallk"`` (requires padded K == block_k). Returns
    (min_d (M, 1), argmin (M, 1), sums (M/bm, K, F), counts (M/bm, K));
    sum the partial blocks over axis 0 for the (K, F) / (K,) totals.
    """
    m, f = x.shape
    k = c.shape[0]
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0, (
        f"unpadded shapes {(m, k, f)} vs blocks {(block_m, block_k, block_f)}")
    num_m = m // block_m

    out_shape = [
        jax.ShapeDtypeStruct((m, 1), jnp.float32),
        jax.ShapeDtypeStruct((m, 1), jnp.int32),
        jax.ShapeDtypeStruct((num_m, k, f), jnp.float32),
        jax.ShapeDtypeStruct((num_m, k), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((block_m, block_k), jnp.float32),
        pltpu.VMEM((block_m, f), x.dtype),   # stash in the input dtype
        pltpu.SemaphoreType.DMA((STASH_SLOTS,)),
    ]

    if variant == "smallk":
        assert k == block_k, (
            f"smallk variant needs padded K ({k}) == block_k ({block_k})")
        kernel = pl.pallas_call(
            _kernel_smallk,
            grid=(m // block_m, f // block_f),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((block_m, block_f), lambda i, t: (i, t)),
                pl.BlockSpec((block_k, block_f), lambda i, t: (0, t)),
                pl.BlockSpec((1, block_k), lambda i, t: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((1, k, f), lambda i, t: (i, 0, 0)),
                pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )
        return kernel(meta, x, c, cn)

    assert variant == "generic", f"unknown kernel variant {variant!r}"
    kernel = pl.pallas_call(
        _kernel,
        grid=(m // block_m, k // block_k, f // block_f),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, k, f), lambda i, j, t: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i, j, t: (i, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(meta, x, c, cn)
