"""int8 distance + nearest-centroid template (paper §III-B, one dtype notch
past the paper's fp16 floor).

The distance GEMM is the one place the template family can still shrink
its bytes and double its MXU rate: X and C are quantized **per row** with
the symmetric scheme of :mod:`repro.dist.compression` (scale =
max|row|/127, clipped away from zero; values rounded into [-127, 127]),
the tile product runs int8 x int8 -> int32 on the MXU, and the epilogue
corrects the scales in f32:

    d_ij  =  ||c_j||^2  -  2 * sx_i * sc_j * acc_ij

where ``acc`` is the exact int32 dot of the quantized rows and ``sx``/
``sc`` are the per-row scales. Two exactness properties follow:

  * the ``||c_j||^2`` term is computed from the *unquantized* centroids
    (exact, like the f32 template's) and ``||x_i||^2`` is row-constant and
    dropped from the argmin exactly as in ``distance_argmin`` — the only
    approximation lives in the cross term;
  * on *quantization-safe* data (integer entries in [-127, 127] with a
    +-127 entry per row, so every scale is exactly 1.0) the int32
    accumulator holds the same integers the f32 template accumulates, the
    scale corrections multiply by 1.0, and the argmin is **bit-exact**
    against the f32 template. That is the parity contract
    ``tests/test_int8.py`` pins; on float data the relative distance error
    is bounded by the quantization step (~1/127 per operand).

Epilogue semantics (first-min tie-break, ``MIN_INIT``) are shared with
every other template via ``tile_min_argmin`` — the scale correction is
applied to the accumulator *before* the shared reduction, so the int8
template cannot drift from the family's tie-break rules.

Grid and variants mirror :mod:`distance_argmin`: ``"generic"``
(M/bm, K/bk, F/bf) with the revisited-output min/argmin, and ``"smallk"``
(M/bm, F/bf) when padded K fits one centroid tile. The accumulator
scratch is int32; scales and outputs are f32/i32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.distance_argmin import MIN_INIT, fold_min, tile_min_argmin


def _scaled_acc(acc_ref, sx_ref, sc_ref):
    """Scale-correct one int32 accumulator tile into the f32 cross term:
    sx_i * sc_j * acc_ij. Exact when both scales are 1.0 (quantization-safe
    data), since the int32 values fit f32 for any feasible tile depth."""
    return sx_ref[...] * (acc_ref[...].astype(jnp.float32) * sc_ref[...])


def _kernel_int8(x_ref, c_ref, sx_ref, sc_ref, cn_ref,
                 mind_ref, argmin_ref, acc_ref):
    """One (bm, bk) int8 distance tile, accumulated over feature steps.

    x_ref   : (bm, bf) i8   quantized sample tile
    c_ref   : (bk, bf) i8   quantized centroid tile
    sx_ref  : (bm, 1)  f32  per-row sample scales
    sc_ref  : (1, bk)  f32  per-row centroid scales
    cn_ref  : (1, bk)  f32  exact centroid squared norms (+inf padded)
    mind_ref: (bm, 1)  f32  running minimum of d_ij  (output, revisited)
    argmin_ref: (bm, 1) i32 running argmin           (output, revisited)
    acc_ref : (bm, bk) i32  VMEM scratch accumulator for Xq Cq^T
    """
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 MXU tile product, exact int32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(
            _scaled_acc(acc_ref, sx_ref, sc_ref), cn_ref[...],
            c_idx * acc_ref.shape[1])
        fold_min(mind_ref, argmin_ref, local_min, local_arg)


def _kernel_int8_smallk(x_ref, c_ref, sx_ref, sc_ref, cn_ref,
                        mind_ref, argmin_ref, acc_ref):
    """Small-K fast path: one centroid tile, grid (M/bm, F/bf); min/argmin
    written directly from the scale-corrected resident accumulator."""
    f_idx = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(
            _scaled_acc(acc_ref, sx_ref, sc_ref), cn_ref[...], 0)
        mind_ref[...] = local_min       # single visit: direct write
        argmin_ref[...] = local_arg


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "variant", "interpret"))
def distance_argmin_int8(
    x: jax.Array,
    c: jax.Array,
    sx: jax.Array,
    sc: jax.Array,
    cn: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    variant: str = "generic",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw int8 kernel entry. Shapes must be pre-padded to the block grid.

    x (M, F) int8 quantized samples, c (K, F) int8 quantized centroids,
    sx (M, 1) f32 per-row sample scales, sc (1, K) f32 per-row centroid
    scales, cn (1, K) f32 *exact* centroid sq-norms (from the unquantized
    centroids) with +inf in padded slots. ``variant`` selects the template:
    ``"generic"`` or ``"smallk"`` (requires padded K == block_k). Returns
    (min_d (M, 1) f32, argmin (M, 1) i32) under the same partial-distance
    contract as ``distance_argmin`` (add ``||x||^2`` for true distances).
    """
    m, f = x.shape
    k = c.shape[0]
    assert x.dtype == jnp.int8 and c.dtype == jnp.int8, (
        f"int8 template fed {x.dtype}/{c.dtype} tiles — quantize at the "
        f"plan boundary (ops.plan_data_int8)")
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0, (
        f"unpadded shapes {(m, k, f)} vs blocks {(block_m, block_k, block_f)}")

    out_shape = [
        jax.ShapeDtypeStruct((m, 1), jnp.float32),
        jax.ShapeDtypeStruct((m, 1), jnp.int32),
    ]
    scratch = [pltpu.VMEM((block_m, block_k), jnp.int32)]

    if variant == "smallk":
        assert k == block_k, (
            f"smallk variant needs padded K ({k}) == block_k ({block_k})")
        kernel = pl.pallas_call(
            _kernel_int8_smallk,
            grid=(m // block_m, f // block_f),
            in_specs=[
                pl.BlockSpec((block_m, block_f), lambda i, t: (i, t)),
                pl.BlockSpec((block_k, block_f), lambda i, t: (0, t)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((1, block_k), lambda i, t: (0, 0)),
                pl.BlockSpec((1, block_k), lambda i, t: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )
        return kernel(x, c, sx, sc, cn)

    assert variant == "generic", f"unknown kernel variant {variant!r}"
    kernel = pl.pallas_call(
        _kernel_int8,
        grid=(m // block_m, k // block_k, f // block_f),
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(x, c, sx, sc, cn)
