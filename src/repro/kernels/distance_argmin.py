"""Fused distance + nearest-centroid kernel (paper §III, Fig. 4 — TPU-native).

Computes, for samples X (M, F) and centroids C (K, F):

    argmin_j  ||x_i - c_j||^2   and the winning partial distance
    d_ij = ||c_j||^2 - 2 x_i . c_j      (||x_i||^2 is row-constant)

in a single pass: the GEMM (-2 X C^T), the paper's fused epilogue (thread /
threadblock min-reduction) and the cross-threadblock broadcast are all
folded into one Pallas kernel.

TPU adaptation (see docs/kernels.md):
  * the contraction (feature) axis is the innermost grid dimension with a
    VMEM scratch accumulator — the analogue of the paper's cp.async k-loop;
    Mosaic generates the HBM->VMEM double-buffered pipeline from BlockSpecs;
  * grid steps on a TensorCore are sequential, so the running min/argmin is
    accumulated directly in the revisited output block — the paper's
    lock-vector broadcast degenerates to a data dependence;
  * tiles are MXU-aligned: block_m, block_k multiples of (8, 128) lanes.

Grid: (M/bm, K/bk, F/bf), iterated row-major (feature axis fastest).

Template family (paper §III-B): two variants share this module —

  * ``"generic"`` — the grid above; min/argmin accumulated in the revisited
    output block across centroid tiles;
  * ``"smallk"``  — when padded K fits a single ``block_k`` tile the
    centroid grid dimension is dropped entirely (grid (M/bm, F/bf)): the
    min/argmin is computed once from the VMEM-resident accumulator and
    written directly, with no revisited-output compare/accumulate machinery.

Input dtype is a template axis too: X and C tiles may be f32, bf16 or fp16;
the MXU accumulator, norms and outputs are always f32
(``preferred_element_type``), matching the paper's f32-accumulate GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

# Initial value of the running minimum: +float32 max, so the first observed
# distance always wins the compare. (Historically misnamed NEG_LIMIT; kept
# as a deprecated alias below.)
MIN_INIT = float(jnp.finfo(jnp.float32).max)
NEG_LIMIT = MIN_INIT  # deprecated alias — use MIN_INIT


def tile_min_argmin(acc, cn, base_col):
    """Min/argmin of one (bm, bk) distance tile from its f32 accumulator:
    d = cn - 2*acc, first-min (lowest-index) tie-break, ``base_col`` added
    to globalize the column index. The single definition of the epilogue
    semantics — every template variant (generic/smallk, plain/FT, with or
    without the fused update) must reduce through here so the bit-identity
    between variants holds by construction."""
    d = cn - 2.0 * acc
    local_min = jnp.min(d, axis=1, keepdims=True)
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    local_arg = jnp.min(
        jnp.where(d == local_min, cols, jnp.iinfo(jnp.int32).max),
        axis=1, keepdims=True) + base_col
    return local_min, local_arg


def fold_min(mind_ref, argmin_ref, local_min, local_arg):
    """Accumulate a tile's (min, argmin) into the revisited output block.
    Strict compare: the earlier centroid tile wins ties."""
    cur = mind_ref[...]
    take = local_min < cur
    mind_ref[...] = jnp.where(take, local_min, cur)
    argmin_ref[...] = jnp.where(take, local_arg, argmin_ref[...])


def _kernel(x_ref, c_ref, cn_ref, mind_ref, argmin_ref, acc_ref):
    """One (bm, bk) distance tile, accumulated over feature steps.

    x_ref   : (bm, bf)   sample tile
    c_ref   : (bk, bf)   centroid tile
    cn_ref  : (1, bk)    centroid squared norms (+inf for padded slots)
    mind_ref: (bm, 1)    running minimum of d_ij  (output, revisited)
    argmin_ref: (bm, 1)  running argmin           (output, revisited)
    acc_ref : (bm, bk)   VMEM scratch accumulator for X C^T
    """
    c_idx = pl.program_id(1)
    f_idx = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(jnp.logical_and(c_idx == 0, f_idx == 0))
    def _init_outputs():
        mind_ref[...] = jnp.full_like(mind_ref, MIN_INIT)
        argmin_ref[...] = jnp.zeros_like(argmin_ref)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU tile product, f32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(
            acc_ref[...], cn_ref[...], c_idx * acc_ref.shape[1])
        fold_min(mind_ref, argmin_ref, local_min, local_arg)


def _kernel_smallk(x_ref, c_ref, cn_ref, mind_ref, argmin_ref, acc_ref):
    """Small-K fast path: the whole centroid set is one (bk, bf) tile, so
    the centroid grid dimension is gone — grid (M/bm, F/bf). The min/argmin
    is computed once from the VMEM-resident accumulator and written
    directly; no init-to-MIN_INIT, no revisited-output compare."""
    f_idx = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(f_idx == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_idx == nf - 1)
    def _epilogue():
        local_min, local_arg = tile_min_argmin(acc_ref[...], cn_ref[...], 0)
        mind_ref[...] = local_min       # single visit: direct write
        argmin_ref[...] = local_arg


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_f", "variant", "interpret"))
def distance_argmin(
    x: jax.Array,
    c: jax.Array,
    cn: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 128,
    block_f: int = 512,
    variant: str = "generic",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel entry. Shapes must be pre-padded to the block grid.

    x (M, F) samples, c (K, F) centroids, cn (1, K) centroid sq-norms with
    +inf in padded centroid slots; any of f32/bf16/fp16 for x and c (cn is
    always f32). ``variant`` selects the template: ``"generic"`` or
    ``"smallk"`` (requires padded K == block_k). Returns
    (min_d (M, 1) f32, argmin (M, 1) i32).
    """
    m, f = x.shape
    k = c.shape[0]
    assert m % block_m == 0 and k % block_k == 0 and f % block_f == 0, (
        f"unpadded shapes {(m, k, f)} vs blocks {(block_m, block_k, block_f)}")

    out_specs_3d = lambda: [pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0)),
                            pl.BlockSpec((block_m, 1), lambda i, j, t: (i, 0))]
    out_shape = [
        jax.ShapeDtypeStruct((m, 1), jnp.float32),
        jax.ShapeDtypeStruct((m, 1), jnp.int32),
    ]
    scratch = [pltpu.VMEM((block_m, block_k), jnp.float32)]

    if variant == "smallk":
        assert k == block_k, (
            f"smallk variant needs padded K ({k}) == block_k ({block_k})")
        kernel = pl.pallas_call(
            _kernel_smallk,
            grid=(m // block_m, f // block_f),
            in_specs=[
                pl.BlockSpec((block_m, block_f), lambda i, t: (i, t)),
                pl.BlockSpec((block_k, block_f), lambda i, t: (0, t)),
                pl.BlockSpec((1, block_k), lambda i, t: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
                pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )
        return kernel(x, c, cn)

    assert variant == "generic", f"unknown kernel variant {variant!r}"
    kernel = pl.pallas_call(
        _kernel,
        grid=(m // block_m, k // block_k, f // block_f),
        in_specs=[
            pl.BlockSpec((block_m, block_f), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_f), lambda i, j, t: (j, t)),
            pl.BlockSpec((1, block_k), lambda i, j, t: (0, j)),
        ],
        out_specs=out_specs_3d(),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return kernel(x, c, cn)
