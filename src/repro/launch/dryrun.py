import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell: build the production mesh, the abstract (ShapeDtypeStruct)
parameters/optimizer/caches with their shardings, lower the real
train/serve step, compile, and record:

  * memory_analysis()       — per-device bytes (proves it fits),
  * cost_analysis()         — per-device HLO flops/bytes,
  * collective byte counts  — parsed from the optimized HLO,

into a JSON cache (results/dryrun/<arch>__<shape>__<mesh>.json) that
EXPERIMENTS.md §Dry-run / §Roofline and the roofline tooling read.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import analyze_hlo, collective_bytes
from repro.train.steps import build_serve_steps, build_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    return os.path.abspath(os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}.json"))


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             force: bool = False, save_hlo: bool = False,
             abft: bool = False) -> dict:
    path = cell_path(arch_id, shape_name, multi_pod)
    if abft:
        path = path.replace(".json", "__abft.json")
    if os.path.exists(path) and not force:
        with open(path) as fh:
            return json.load(fh)

    cfg = get_config(arch_id, abft=True) if abft else get_config(arch_id)
    shape = SHAPES[shape_name]
    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        _save(path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # jax >= 0.6 spells the ambient-mesh context jax.set_mesh; older
        # releases use the Mesh itself as the context manager
        set_mesh = getattr(jax, "set_mesh", None)
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            if shape.kind == "train":
                bundle = build_train_step(cfg, mesh, shape)
            else:
                bundle = build_serve_steps(cfg, mesh, shape)
            lowered = bundle.step_fn.lower(*bundle.arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # jax < 0.5 returns one dict per device
            cost = cost[0] if cost else None
        hlo = compiled.as_text()
        t_an = time.time()
        analyzed = analyze_hlo(hlo)   # loop-aware (scan bodies x trip count)
        record.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            analyze_s=round(time.time() - t_an, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            cost={
                # loop-aware per-device numbers (roofline inputs)
                "flops": analyzed["flops"],
                "bytes_accessed": analyzed["bytes"],
                # raw cost_analysis (counts loop bodies once; cross-check)
                "xla_flops_once": cost.get("flops") if cost else None,
                "xla_bytes_once": cost.get("bytes accessed") if cost else None,
            },
            collectives=analyzed["collectives"],
            collective_bytes=collective_bytes(analyzed["collectives"]),
            hlo_bytes=len(hlo),
        )
        if save_hlo:
            import gzip
            with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as fh:
                fh.write(hlo)
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _save(path, record)
    return record


def _save(path: str, record: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path + ".tmp", "w") as fh:
        json.dump(record, fh, indent=1)
    os.replace(path + ".tmp", path)


def summarize(record: dict) -> str:
    if record["status"] == "skipped":
        return (f"{record['arch']:28s} {record['shape']:12s} "
                f"{record['mesh']:8s} SKIP ({record['reason'][:40]}...)")
    if record["status"] == "error":
        return (f"{record['arch']:28s} {record['shape']:12s} "
                f"{record['mesh']:8s} ERROR {record['error'][:80]}")
    mem = record["memory"]
    gb = lambda b: f"{(b or 0) / 2**30:.2f}GiB"
    return (f"{record['arch']:28s} {record['shape']:12s} {record['mesh']:8s} "
            f"OK args={gb(mem['argument_bytes'])} temp={gb(mem['temp_bytes'])} "
            f"flops/dev={record['cost']['flops']:.3g} "
            f"coll={record['collective_bytes'] / 2**20:.1f}MiB "
            f"compile={record['compile_s']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--abft", action="store_true",
                    help="ABFT-protect dense projections (paper technique)")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, multi_pod=mp, force=args.force,
                       save_hlo=args.save_hlo, abft=args.abft)
        print(summarize(rec), flush=True)
        failures += rec["status"] == "error"
    print(f"\n{len(cells)} cells, {failures} errors")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
