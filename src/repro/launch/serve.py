"""Serving launcher: micro-batched prefill + decode on the local mesh.

The request queue rides the generic micro-batching layer from
``repro.serve`` — the same :class:`~repro.serve.MicroBatcher` the
k-means service uses. Each request submits its ``(1, prompt_len)``
prompt; the batcher coalesces a wave into one row-concatenated batch,
the dispatch function pads it to the fixed compiled batch shape, runs
prefill + greedy decode once, and the batcher scatters each request its
generated row.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 8 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import LM
from repro.serve import MicroBatcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(lm.prefill, static_argnames=("max_len",))
    decode = jax.jit(lm.decode_step)

    def generate(prompts):
        """One coalesced wave: pad rows to the compiled batch shape,
        prefill + greedy decode, return the generated ``(rows, gen)``
        tokens (sliced back so the batcher can scatter per request)."""
        rows = prompts.shape[0]
        if rows < args.batch:              # pad the tail wave
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], args.batch - rows, 0)])
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.frontend == "audio_stub":
            batch["audio_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
        logits, caches = prefill(params, batch, max_len=max_len)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated = [tok]
        for t in range(args.prompt_len, max_len - 1):
            logits, caches = decode(params, caches, tok,
                                    jnp.asarray(t, jnp.int32))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            generated.append(tok)
        return (jnp.concatenate(generated, axis=1)[:rows],)

    batcher = MicroBatcher(generate)
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size, size=(1, args.prompt_len))
             for _ in range(args.requests)]
    served, total_tokens, t0 = 0, 0, time.time()
    while queue:
        wave, queue = queue[:args.batch], queue[args.batch:]
        tickets = [batcher.submit(p) for p in wave]
        batcher.flush()
        for tk in tickets:
            total_tokens += tk.result()[0].shape[1]
        served += len(wave)
        print(f"served {served}/{args.requests} requests")
    dt = time.time() - t0
    print(f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s greedy, CPU)")


if __name__ == "__main__":
    main()
