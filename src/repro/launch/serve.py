"""Serving launcher: batched prefill + decode on the local mesh.

Continuous-batch-flavoured driver: a queue of requests is served in fixed
batches through the production prefill/decode steps (same callables the
dry-run lowers for the decode cells), with greedy sampling and per-request
length accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        --requests 8 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import LM


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(lm.prefill, static_argnames=("max_len",))
    decode = jax.jit(lm.decode_step)

    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
             for _ in range(args.requests)]
    served, t0 = 0, time.time()
    total_tokens = 0
    while queue:
        chunk, queue = queue[:args.batch], queue[args.batch:]
        while len(chunk) < args.batch:     # pad the last batch
            chunk.append(chunk[-1])
        batch = {"tokens": jnp.asarray(np.stack(chunk), jnp.int32)}
        if cfg.frontend == "audio_stub":
            batch["audio_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
        logits, caches = prefill(params, batch, max_len=max_len)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for t in range(args.prompt_len, max_len - 1):
            logits, caches = decode(params, caches, tok,
                                    jnp.asarray(t, jnp.int32))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        served += min(args.batch, args.requests - served)
        total_tokens += args.batch * args.gen
        print(f"served {served}/{args.requests} requests")
    dt = time.time() - t0
    print(f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s greedy, CPU)")


if __name__ == "__main__":
    main()
