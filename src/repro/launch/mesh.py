"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (tests / CPU smoke): (data, model)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
