"""Training launcher: --arch <id> on the local or production mesh.

The full production loop: sharded init, microbatched AdamW step (the same
jit'd callable the dry-run lowers), synthetic token pipeline, async atomic
checkpointing, --restore for fail-stop recovery, ABFT switch, straggler
observation hooks.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 [--abft] [--restore]

(On this CPU host use --smoke; the full configs are for the TPU meshes.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, train_schedule, ARCH_IDS
from repro.configs.base import ShapeConfig, SHAPES
from repro.data.synthetic import TokenPipeline
from repro.dist.sharding import shard_params
from repro.ft.checkpoint import Checkpointer
from repro.ft.elastic import StragglerPolicy
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.train.optimizer import TrainConfig, init_opt_state
from repro.train.steps import build_train_step


def _tree_unflatten_from_flat(template, flat, prefix):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = prefix + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", ""))))
            for p in path)
        out.append(jnp.asarray(flat[key]).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--shape", choices=tuple(SHAPES), default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch (CPU host)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--abft", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.abft:
        cfg = dataclasses.replace(cfg, abft=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod) \
        if args.production_mesh else make_local_mesh()
    if args.smoke:
        shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
    else:
        shape = SHAPES[args.shape]

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                       total_steps=args.steps,
                       schedule=train_schedule(args.arch),
                       grad_accum=cfg.grad_accum_override or 2,
                       opt_state_dtype=cfg.opt_state_dtype,
                       accum_dtype=cfg.opt_state_dtype)
    bundle = build_train_step(cfg, mesh, shape, tcfg)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"schedule={tcfg.schedule} abft={cfg.abft} "
          f"params={cfg.param_count() / 1e6:.1f}M")

    params, axes = bundle.lm.init(jax.random.PRNGKey(0))
    params = shard_params(mesh, params, axes)
    opt = init_opt_state(params, tcfg)
    start = 0
    ck = Checkpointer(args.ckpt_dir, keep=3, async_write=True)
    if args.restore:
        st = ck.restore()
        if st is not None:
            start = st["_step"]
            flat = {k: v for k, v in st.items() if k != "_step"}
            params = shard_params(
                mesh, _tree_unflatten_from_flat(params, flat, "params"), axes)
            opt = _tree_unflatten_from_flat(opt, flat, "opt")
            print(f"restored checkpoint at step {start}")

    pipe = TokenPipeline(cfg.vocab_size, shape.seq_len, shape.global_batch)
    straggler = StragglerPolicy()
    times = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = pipe.next_batch(step)
        params, opt, m = bundle.step_fn(params, opt, batch)
        dt = time.time() - t0
        times.append(dt)
        median = float(np.median(times[-20:]))
        straggler.observe(0, dt, median)   # single-host: shard 0
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}  {dt:.2f}s")
        if (step + 1) % args.ckpt_every == 0:
            ck.save(step + 1, {"params": params, "opt": opt})
    ck.save(args.steps, {"params": params, "opt": opt})
    ck.wait()
    print(f"done; snapshots: {ck.available_steps()}")


if __name__ == "__main__":
    main()
