"""Checkpoint/restart for fail-stop faults (paper fault model §II-A).

Design points for 1000+ nodes:
  * **Asynchronous**: device->host copy happens on the caller thread (cheap;
    state is small for k-means, sharded for LM), serialization + fsync on a
    background thread so the training loop never blocks on storage.
  * **Atomic**: write to a temp file, fsync, rename — a crash mid-write
    never corrupts the latest valid checkpoint.
  * **Self-describing**: a manifest (step, tree structure, shapes/dtypes)
    travels with the arrays; restore validates structure before use.
  * **Sharded**: each host saves only the addressable shards of its arrays
    (`save(..., local_only=True)`); restore re-assembles per host. In this
    single-process container that degenerates to a full save, but the code
    path is the multi-host one.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_write
        self._worker: Optional[threading.Thread] = None
        self._errors: list[BaseException] = []
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- public API ---------------------------------------------------------

    def save(self, step: int, state: Any, *, local_only: bool = False):
        """Snapshot `state` (a pytree of arrays) at `step`."""
        flat, _ = _tree_flatten_with_paths(state)
        host_arrays = {}
        for key, leaf in flat.items():
            # checkpointing IS the host boundary: serializing device state
            # to disk is this function's whole job
            arr_h = jax.device_get(  # analysis: allow=host-sync
                self._addressable(leaf) if local_only else leaf)
            host_arrays[key] = np.asarray(arr_h)
        payload = (step, host_arrays)
        if self._async:
            self._q.put(payload)
        else:
            self._write(payload)

    def restore(self, step: Optional[int] = None) -> Optional[dict]:
        """Latest (or specific) checkpoint as {key: np.ndarray} + '_step'.

        A corrupt or truncated file — a crash landed between the atomic
        rename and durable bytes, or the storage lost some — is *skipped*:
        restore walks backward to the newest checkpoint that still loads,
        which the tmp+fsync+rename write protocol guarantees exists
        unless every snapshot is gone. A pinned ``step`` is never
        substituted; asking for a specific broken snapshot raises."""
        self.wait()
        steps = self.available_steps()
        if not steps:
            return None
        candidates = [step] if step is not None else list(reversed(steps))
        for s in candidates:
            try:
                with np.load(self._path(s)) as data:
                    out = {k: data[k] for k in data.files}
            except Exception:
                if step is not None:
                    raise
                continue
            out["_step"] = s
            return out
        return None

    def available_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and name.endswith(".npz"):
                out.append(int(name[5:-4]))
        return sorted(out)

    def wait(self):
        """Block until all queued snapshots are durable."""
        if self._async:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    # -- internals ----------------------------------------------------------

    def _addressable(self, leaf):
        if hasattr(leaf, "addressable_shards"):
            shards = [s.data for s in leaf.addressable_shards]
            if len(shards) == 1:
                return shards[0]
        return leaf

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def _write(self, payload):
        step, arrays = payload
        tmp = self._path(step) + ".tmp"
        with open(tmp, "wb") as fh:
            np.savez(fh, **arrays)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self._path(step))
        manifest = {
            "step": step,
            "keys": {k: [list(v.shape), str(v.dtype)] for k, v in arrays.items()},
            "time": time.time(),
        }
        mtmp = os.path.join(self.directory, "manifest.json.tmp")
        with open(mtmp, "w") as fh:
            json.dump(manifest, fh)
        os.replace(mtmp, os.path.join(self.directory, "manifest.json"))
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for old in steps[: max(0, len(steps) - self.keep)]:
            try:
                os.remove(self._path(old))
            except OSError:
                pass

    def _drain(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            except BaseException as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()
