from repro.ft.checkpoint import Checkpointer
from repro.ft.abft_dense import ft_einsum, FTContext
from repro.ft import elastic
from repro.ft.elastic import (FailureSchedule, WorkerLossError,
                              plan_rescale_rows)

__all__ = ["Checkpointer", "ft_einsum", "FTContext", "elastic",
           "FailureSchedule", "WorkerLossError", "plan_rescale_rows"]
