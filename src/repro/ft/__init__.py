from repro.ft.checkpoint import Checkpointer
from repro.ft.abft_dense import ft_einsum, FTContext
from repro.ft import elastic

__all__ = ["Checkpointer", "ft_einsum", "FTContext", "elastic"]
