"""Elastic scaling + straggler mitigation policy (DESIGN.md §5).

The recovery ladder for a 1000+ node deployment, cheapest first:

  1. SDC in a GEMM           -> corrected in-kernel (ABFT), zero restarts.
  2. SDC in a reduction      -> DMR mismatch -> recompute that op.
  3. Straggling host         -> k-means: per-iteration work is stateless
     beyond the centroids, so the coordinator drops the straggler's shard
     for the iteration (the psum re-normalizes by the live counts — the
     estimator stays unbiased); LM training: skip-straggler = gradient
     psum over the responsive subset with count renormalization.
  4. Failed host (fail-stop) -> shrink the mesh, re-shard, restore the last
     checkpoint, continue.

This module implements the *decision* layer: given the live device set it
produces the new mesh + resharding plan. The mechanics (rebuild loader,
re-lower step) live with ``DistributedKMeans.fit_elastic`` and the
launchers; in a single-process container the device set is simulated
(:class:`FailureSchedule` raises :class:`WorkerLossError` at scheduled
iterations), and tests drive the policy with fake topologies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


class WorkerLossError(RuntimeError):
    """A fail-stop device loss (recovery ladder step 4).

    Raised by the runtime — or, in drills, by a :class:`FailureSchedule`
    — when devices drop out of the mesh mid-fit. ``lost`` holds indices
    into the fit's current flat device list; the elastic driver removes
    them, replans the mesh and resumes from the last checkpoint.
    """

    def __init__(self, lost: Sequence[int], message: str = ""):
        self.lost = tuple(lost)
        super().__init__(message or f"lost devices {self.lost}")


@dataclasses.dataclass
class FailureSchedule:
    """Deterministic device-loss injector for fault drills.

    Maps iteration -> device indices to kill; passed as the fit loop's
    ``on_iteration`` hook, it raises :class:`WorkerLossError` when the
    loop reaches a scheduled iteration. Each entry fires once — after a
    restart the resumed trajectory passes the same iteration numbers
    again, and a drill kills each worker set exactly one time.
    """

    schedule: dict

    def __call__(self, iteration: int) -> None:
        lost = self.schedule.pop(iteration, None)
        if lost:
            raise WorkerLossError(tuple(lost))


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_devices: tuple
    data_shards: int            # new number of data shards
    note: str = ""


def largest_mesh(n_devices: int, *, model_parallel: int,
                 pods: int = 1) -> tuple[int, ...]:
    """Largest (pod, data, model) grid that fits n_devices, keeping the
    model axis intact (TP groups must stay whole) and shrinking data."""
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError(
            f"cannot keep model={model_parallel} with {n_devices} devices")
    return (pods, data, model_parallel) if pods > 1 else (data, model_parallel)


def plan_rescale(live_devices: Sequence, *, model_parallel: int,
                 pods: int = 1,
                 axis_names: tuple = ("data", "model")) -> ReshardPlan:
    """Compute the post-failure mesh. Drops the minimum number of devices
    needed to make the grid rectangular (whole TP groups only)."""
    n = len(live_devices)
    shape = largest_mesh(n - n % model_parallel, model_parallel=model_parallel,
                         pods=pods)
    used = int(np.prod(shape))
    dropped = tuple(range(used, n))
    names = (("pod",) + axis_names) if pods > 1 else axis_names
    data_shards = shape[-2] * (shape[0] if pods > 1 else 1)
    return ReshardPlan(
        mesh_shape=shape, axis_names=names, dropped_devices=dropped,
        data_shards=data_shards,
        note=f"{n} live -> mesh {shape} ({used} used, {len(dropped)} spare)")


def plan_rescale_rows(live_devices: Sequence, *, problems: int = 1,
                      hosts: int = 1) -> ReshardPlan:
    """:func:`plan_rescale` for the ``("host", "row", "problem")`` mesh.

    Problem groups play the role TP groups play in :func:`plan_rescale`
    (independent problems must keep their full row set), so the row
    parallelism shrinks and ``problems`` stays intact. The host grouping
    re-derives from what divides: when the surviving row count no longer
    splits evenly over ``hosts``, the plan collapses to one host group —
    the hierarchical reduce then degenerates to its exact flat form
    rather than leaving devices idle.
    """
    flat = plan_rescale(live_devices, model_parallel=problems)
    rows = flat.mesh_shape[0]
    h = hosts if hosts > 1 and rows % hosts == 0 else 1
    return dataclasses.replace(
        flat,
        mesh_shape=(h, rows // h, problems),
        axis_names=("host", "row", "problem"),
        data_shards=rows,
        note=f"{len(live_devices)} live -> mesh ({h}, {rows // h}, "
             f"{problems}) ({rows * problems} used, "
             f"{len(flat.dropped_devices)} spare)")


def build_mesh(plan: ReshardPlan, devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    used = int(np.prod(plan.mesh_shape))
    grid = np.asarray(devices[:used]).reshape(plan.mesh_shape)
    return Mesh(grid, plan.axis_names)


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation for the synchronous collectives.

    A shard that misses `deadline_factor` x median step time for
    `strikes` consecutive steps is treated as failed (-> plan_rescale).
    Until then its contribution is simply skipped: for k-means the psum
    denominators use live counts — :meth:`aggregate` is that fold, and
    its unbiasedness is pinned by tests — and for SGD the gradient mean
    renormalizes by the responding shard count.
    """

    deadline_factor: float = 3.0
    strikes: int = 2
    _history: dict = dataclasses.field(default_factory=dict)

    def observe(self, shard: int, step_time: float, median_time: float) -> bool:
        """Returns True when the shard should be evicted."""
        late = step_time > self.deadline_factor * max(median_time, 1e-9)
        count = self._history.get(shard, 0)
        count = count + 1 if late else 0
        self._history[shard] = count
        return count >= self.strikes

    @staticmethod
    def aggregate(sums, counts, live):
        """Fold per-shard partial ``(sums, counts)`` with late/dead shards
        masked out: ``(sum of live sums, sum of live counts)``.

        This is the drop-shard renormalization the ladder's step 3
        claims is unbiased, as code: dropping a shard removes its rows
        from numerator and denominator alike, so
        ``mean = sum(live sums) / sum(live counts)`` is *exactly* the
        mean of the rows the live shards hold — a subsample estimate,
        not a rescaled one. The tempting alternative (average the
        per-shard means, renormalizing by the live shard count) is
        biased whenever shards hold unequal per-cluster counts; the
        test suite contrasts both forms.

        ``sums`` is (S, K, F), ``counts`` (S, K), ``live`` (S,) bool.
        Works on device values inside the step or on host partials.
        """
        import jax.numpy as jnp
        mask = jnp.asarray(live)
        sums = jnp.asarray(sums)
        counts = jnp.asarray(counts)
        ms = mask.reshape((-1,) + (1,) * (sums.ndim - 1))
        mc = mask.reshape((-1,) + (1,) * (counts.ndim - 1))
        return (jnp.where(ms, sums, 0.0).sum(axis=0),
                jnp.where(mc, counts, 0.0).sum(axis=0))
