"""Elastic scaling + straggler mitigation policy (DESIGN.md §5).

The recovery ladder for a 1000+ node deployment, cheapest first:

  1. SDC in a GEMM           -> corrected in-kernel (ABFT), zero restarts.
  2. SDC in a reduction      -> DMR mismatch -> recompute that op.
  3. Straggling host         -> k-means: per-iteration work is stateless
     beyond the centroids, so the coordinator drops the straggler's shard
     for the iteration (the psum re-normalizes by the live counts — the
     estimator stays unbiased); LM training: skip-straggler = gradient
     psum over the responsive subset with count renormalization.
  4. Failed host (fail-stop) -> shrink the mesh, re-shard, restore the last
     checkpoint, continue.

This module implements the *decision* layer: given the live device set it
produces the new mesh + resharding plan. The mechanics (rebuild loader,
re-lower step) live with the launchers; in a single-process container the
device set is simulated, and tests drive the policy with fake topologies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_devices: tuple
    data_shards: int            # new number of data shards
    note: str = ""


def largest_mesh(n_devices: int, *, model_parallel: int,
                 pods: int = 1) -> tuple[int, ...]:
    """Largest (pod, data, model) grid that fits n_devices, keeping the
    model axis intact (TP groups must stay whole) and shrinking data."""
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError(
            f"cannot keep model={model_parallel} with {n_devices} devices")
    return (pods, data, model_parallel) if pods > 1 else (data, model_parallel)


def plan_rescale(live_devices: Sequence, *, model_parallel: int,
                 pods: int = 1,
                 axis_names: tuple = ("data", "model")) -> ReshardPlan:
    """Compute the post-failure mesh. Drops the minimum number of devices
    needed to make the grid rectangular (whole TP groups only)."""
    n = len(live_devices)
    shape = largest_mesh(n - n % model_parallel, model_parallel=model_parallel,
                         pods=pods)
    used = int(np.prod(shape))
    dropped = tuple(range(used, n))
    names = (("pod",) + axis_names) if pods > 1 else axis_names
    data_shards = shape[-2] * (shape[0] if pods > 1 else 1)
    return ReshardPlan(
        mesh_shape=shape, axis_names=names, dropped_devices=dropped,
        data_shards=data_shards,
        note=f"{n} live -> mesh {shape} ({used} used, {len(dropped)} spare)")


def build_mesh(plan: ReshardPlan, devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    used = int(np.prod(plan.mesh_shape))
    grid = np.asarray(devices[:used]).reshape(plan.mesh_shape)
    return Mesh(grid, plan.axis_names)


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation for the synchronous collectives.

    A shard that misses `deadline_factor` x median step time for
    `strikes` consecutive steps is treated as failed (-> plan_rescale).
    Until then its contribution is simply skipped: for k-means the psum
    denominators use live counts (unbiased); for SGD the gradient mean
    renormalizes by the responding shard count.
    """

    deadline_factor: float = 3.0
    strikes: int = 2
    _history: dict = dataclasses.field(default_factory=dict)

    def observe(self, shard: int, step_time: float, median_time: float) -> bool:
        """Returns True when the shard should be evicted."""
        late = step_time > self.deadline_factor * max(median_time, 1e-9)
        count = self._history.get(shard, 0)
        count = count + 1 if late else 0
        self._history[shard] = count
        return count >= self.strikes
