"""ABFT-protected projections for the LM stack (paper technique, level 2).

``ft_einsum`` is the single entry point the model layers use for every
dense contraction. With FT disabled it is ``jnp.einsum``; with FT enabled
the contraction gains the paper's dual-checksum invariant in an
*einsum-native* form (beyond-paper refinement, §Perf internlm2 log):

    the MAIN product runs untouched (GSPMD keeps its optimal sharding —
    reshaping to a 2-D GEMM perturbed the partitioner into re-sharding
    every projection), and the checksums are separate vector contractions:

      exp1 = (sum_tokens x) @ W          obs1 = sum_tokens D      (out...,)
      exp2 = (sum_tokens w_t * x) @ W    obs2 = sum_tokens w_t*D

    detection: |obs1 - exp1| > threshold at output coordinate j;
    location:  flat token index t = round((obs2-exp2)_j / (obs1-exp1)_j)-1;
    correction: D[unravel(t), j] -= delta.   (SEU model: <=1 per interval)

Cost: two token-sum passes + two (k,)x(k,out) vector GEMMs per projection
— O(1/B/S) relative flops, a few KiB of all-reduce per projection.

A thread-local ``FTContext`` collects the enable switch so the step
builders configure protection without threading flags through every layer.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp


class FTContext(threading.local):
    """Per-thread FT switches; configured once by the step builder."""

    def __init__(self):
        self.enabled = False

    def configure(self, enabled: bool):
        self.enabled = enabled


_CTX = FTContext()


def configure(enabled: bool):
    _CTX.configure(enabled)


def ft_enabled() -> bool:
    return _CTX.enabled


def _parse(spec: str, x, w):
    """Returns (batch_labels, contracted, out_labels) or None."""
    try:
        lhs, out = spec.split("->")
        a, b = lhs.split(",")
    except ValueError:
        return None
    contracted = [c for c in a if c in b and c not in out]
    if not contracted or any(c in out for c in contracted):
        return None
    if a[-len(contracted):] != "".join(contracted) or \
            b[: len(contracted)] != "".join(contracted):
        return None
    batch_labels = a[: -len(contracted)]
    out_labels = b[len(contracted):]
    if out != batch_labels + out_labels:
        return None
    return batch_labels, contracted, out_labels


def ft_einsum(spec: str, x: jax.Array, w: jax.Array, *,
              enabled: Optional[bool] = None) -> jax.Array:
    """einsum with optional einsum-native ABFT protection.

    Supported specs are the LM stack's projection forms — (batch..., k...)
    x (k..., out...). Other specs fall back to plain einsum (elementwise /
    recurrent ops are DMR territory, not ABFT — DESIGN.md §4).
    """
    on = _CTX.enabled if enabled is None else enabled
    if not on:
        return jnp.einsum(spec, x, w)
    parsed = _parse(spec, x, w)
    if parsed is None:
        return jnp.einsum(spec, x, w)
    batch_labels, contracted, out_labels = parsed

    nb = len(batch_labels)
    nk = len(contracted)
    bdims = tuple(range(nb))
    k = 1
    for d in x.shape[nb:]:
        k *= d
    ntok = 1
    for d in x.shape[:nb]:
        ntok *= d
    out_elems = 1
    for d in w.shape[nk:]:
        out_elems *= d

    @jax.custom_vjp
    def _protected(x, w):
        return _detect_correct(x, w)

    def _detect_correct(x, w):
        d = jnp.einsum(spec, x, w)
        xf = x.astype(jnp.float32)
        df = d.astype(jnp.float32)
        w2 = w.reshape(k, out_elems).astype(jnp.float32)
        # e1/e2 over the flattened token dims
        w_t = (jnp.arange(ntok, dtype=jnp.float32) + 1.0).reshape(
            x.shape[:nb] + (1,) * nk)
        exp1 = jnp.sum(xf, axis=bdims).reshape(k) @ w2          # (out,)
        exp2 = jnp.sum(xf * w_t, axis=bdims).reshape(k) @ w2
        obs1 = jnp.sum(df, axis=bdims).reshape(out_elems)
        w_t_out = w_t.reshape(x.shape[:nb] + (1,) * len(out_labels))
        obs2 = jnp.sum(df * w_t_out, axis=bdims).reshape(out_elems)

        res1 = obs1 - exp1
        res2 = obs2 - exp2
        eps = jnp.float32(1.1920929e-07)
        scale = jnp.maximum(jnp.max(jnp.abs(exp1)) / ntok, 1.0)
        thr = 16.0 * jnp.sqrt(jnp.float32(k)) * eps * scale * ntok
        detected = jnp.any(jnp.abs(res1) > thr)

        j = jnp.argmax(jnp.abs(res1)).astype(jnp.int32)
        delta = res1[j]
        safe = jnp.where(delta == 0.0, 1.0, delta)
        t = jnp.clip((jnp.round(res2[j] / safe) - 1.0).astype(jnp.int32),
                     0, ntok - 1)
        # correct the single element (flat token t, flat out j)
        tok_idx = jnp.unravel_index(t, x.shape[:nb])
        out_idx = jnp.unravel_index(j, w.shape[nk:])
        fix = jnp.where(detected, delta, 0.0).astype(d.dtype)
        return d.at[tok_idx + out_idx].add(-fix)

    def _fwd(x, w):
        return _protected(x, w), (x, w)

    def _bwd(res, g):
        x, w = res
        # backward contractions protected with the same invariant by
        # recursion through ft_einsum on transposed specs
        gx = jnp.einsum(f"{batch_labels}{''.join(out_labels)},"
                        f"{''.join(contracted)}{''.join(out_labels)}"
                        f"->{batch_labels}{''.join(contracted)}",
                        g, w)
        gw = jnp.einsum(f"{batch_labels}{''.join(contracted)},"
                        f"{batch_labels}{''.join(out_labels)}"
                        f"->{''.join(contracted)}{''.join(out_labels)}",
                        x, g)
        return gx.astype(x.dtype), gw.astype(w.dtype)

    _protected.defvjp(_fwd, _bwd)
    return _protected(x, w)
