"""Mixture-of-Experts layer (GShard-style einsum dispatch, EP over 'model').

Top-k routing with per-row capacity: tokens beyond an expert's capacity are
dropped (standard GShard/Switch semantics; the residual stream carries
them). Experts are sharded over the 'model' mesh axis (EP); the dispatch
einsums lower to all-to-all-like collectives under SPMD.

The einsum dispatch costs O(B*S * E*C * D) — with capacity_factor c it is
~c * B*S^2-ish per layer for top-1 (same order as attention). The sort-based
dispatch (cheaper, data-movement-only) is a §Perf hillclimb item; this
formulation is the portable baseline.

ABFT note (DESIGN.md §4): expert GEMMs route through ft_einsum — the
checksummed matmul covers the grouped (E, C, D) x (E, D, F) contraction by
folding E into the row dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.ft.abft_dense import ft_einsum


def init_moe(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.num_experts
    gated = L.mlp_gated(cfg.mlp_act)
    specs = {
        "router": ((d, e), ("embed", "experts")),
        "wi": ((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if gated:
        specs["wg"] = ((e, d, f), ("experts", "embed", "expert_mlp"))
    params, axes = L.build(key, specs, dtype)
    if cfg.moe.shared_expert:
        sp, sa = L.init_mlp(jax.random.fold_in(key, 7), d, f, cfg.mlp_act, dtype)
        params["shared"], axes["shared"] = sp, sa
    return params, axes


def _capacity(s: int, k: int, e: int, factor: float) -> int:
    c = int(s * k / e * factor) + 1
    return max(min(c, s), 4)


def apply_moe(cfg, params, x):
    """x (B, S, D) -> (B, S, D). Router in f32 for stability."""
    b, s, d = x.shape
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    c = _capacity(s, k, e, cfg.moe.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)           # renormalize

    # one-hot expert choice per (token, slot): (B, S, K, E)
    choice = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue, row-major over
    # (S, K): cumulative count per expert. (B, S, K, E)
    flat = choice.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)
    within_cap = pos_in_expert < c
    choice = choice * within_cap

    # dispatch/combine tensors (B, S, E, C) — built in bf16: they are 0/1
    # (resp. gate-valued) masks, and the f32 versions dominated the 400B
    # config's temp memory + HBM traffic (§Perf llama4 iteration 2).
    slot = jax.nn.one_hot(jnp.sum(pos_in_expert * choice, axis=-1), c,
                          dtype=x.dtype)                       # (B,S,K,C)
    choice_lp = choice.astype(x.dtype)
    dispatch = jnp.einsum("bske,bskc->bsec", choice_lp, slot)
    combine = jnp.einsum("bske,bskc,bsk->bsec", choice_lp, slot,
                         gate_vals.astype(x.dtype))

    xin = jnp.einsum("bsec,bsd->becd", dispatch, x)
    h = ft_einsum("becd,edf->becf", xin, params["wi"])
    if "wg" in params:
        g = ft_einsum("becd,edf->becf", xin, params["wg"])
        h = jax.nn.silu(g) * h if cfg.mlp_act == "silu" else jax.nn.gelu(g) * h
    else:
        h = jax.nn.relu(h) ** 2 if cfg.mlp_act == "relu2" else jax.nn.gelu(h)
    out_e = ft_einsum("becf,efd->becd", h, params["wo"])
    y = jnp.einsum("bsec,becd->bsd", combine, out_e)

    if cfg.moe.shared_expert:
        y = y + L.apply_mlp(params["shared"], x, cfg.mlp_act)

    # GShard load-balancing auxiliary loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(choice.sum(axis=2), axis=(0, 1))     # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))                    # (E,)
    aux = e * jnp.sum(frac_tokens * mean_prob) / k
    return y, aux
