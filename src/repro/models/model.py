"""Model factory: ArchConfig -> init / forward / prefill / decode.

Layer stacking: the repeating ``layer_pattern`` defines a *period*; full
periods are stacked and executed under ``jax.lax.scan`` (one HLO body for
the whole depth — essential for 48-layer x 512-device compiles), remainder
layers run unrolled. Each scanned period is rematerialized
(``jax.checkpoint``) when cfg.remat.

Caches mirror the parameter stacking: per pattern-slot, stacked over
periods, so decode scans over (params, caches) together.

Supported families: dense / MoE decoders, mamba2 (SSD), RecurrentGemma
hybrid, VLM early-fusion (M-RoPE), whisper-style encoder-decoder.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, RGLRU, SSM, ArchConfig)
from repro.dist.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _moe_on_layer(cfg, layer_idx) -> bool:
    return cfg.moe is not None and \
        layer_idx % cfg.moe.interleave == cfg.moe.interleave - 1


def _init_layer(key, cfg: ArchConfig, kind: str, dtype, *, cross: bool,
                layer_idx: int = 0):
    """NOTE: when MoE interleaves (every Nth layer), the layer pattern's
    period must be a multiple of `interleave` so scanned slots are
    structurally homogeneous (llama4 uses pattern=('attn','attn'))."""
    keys = jax.random.split(key, 6)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["norm1"], axes["norm1"] = L.init_rmsnorm(cfg.d_model, dtype)
    if kind in (ATTN, ATTN_LOCAL):
        params["mix"], axes["mix"] = attn_mod.init_attention(keys[0], cfg, dtype)
    elif kind == RGLRU:
        params["mix"], axes["mix"] = rglru_mod.init_rglru(keys[0], cfg, dtype)
    elif kind == SSM:
        params["mix"], axes["mix"] = ssm_mod.init_ssm(keys[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        params["norm_x"], axes["norm_x"] = L.init_rmsnorm(cfg.d_model, dtype)
        params["cross"], axes["cross"] = attn_mod.init_attention(
            keys[1], cfg, dtype)
    if kind != SSM:   # mamba blocks have no separate FFN
        params["norm2"], axes["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
        if _moe_on_layer(cfg, layer_idx):
            params["ffn"], axes["ffn"] = moe_mod.init_moe(keys[2], cfg, dtype)
        else:
            params["ffn"], axes["ffn"] = L.init_mlp(
                keys[2], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return params, axes


def _apply_layer(cfg: ArchConfig, kind: str, params, x, *, positions,
                 layer_idx, cache=None, pos=None, encoder_out=None,
                 make_cache=False, max_len=0, causal=True):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else (
        {} if make_cache else None)

    if kind in (ATTN, ATTN_LOCAL):
        window = cfg.local_window if kind == ATTN_LOCAL else 0
        kv = cache.get("kv") if cache else None
        out, nkv = attn_mod.apply_attention(
            cfg, params["mix"], h, positions=positions, causal=causal,
            window=window, cache=kv, pos=pos, make_cache=make_cache,
            max_len=max_len)
        if new_cache is not None and nkv is not None:
            new_cache["kv"] = nkv
    elif kind == RGLRU:
        st = cache.get("rglru") if cache else None
        if make_cache and st is None:
            st = rglru_mod.RGLRUCache(
                jnp.zeros((x.shape[0], cfg.rglru_width or cfg.d_model),
                          jnp.float32),
                jnp.zeros((x.shape[0], cfg.conv_width - 1,
                           cfg.rglru_width or cfg.d_model), x.dtype))
        out, nst = rglru_mod.apply_rglru(cfg, params["mix"], h, cache=st)
        if new_cache is not None and nst is not None:
            new_cache["rglru"] = nst
    elif kind == SSM:
        st = cache.get("ssm") if cache else None
        if make_cache and st is None:
            inner, nh, p, n = ssm_mod.dims(cfg)
            st = ssm_mod.SSMCache(
                jnp.zeros((x.shape[0], nh, p, n), jnp.float32),
                jnp.zeros((x.shape[0], cfg.conv_width - 1, inner + 2 * n),
                          x.dtype))
        out, nst = ssm_mod.apply_ssm(cfg, params["mix"], h, cache=st)
        if new_cache is not None and nst is not None:
            new_cache["ssm"] = nst
    else:
        raise ValueError(kind)
    x = x + out
    x = constrain(x, ("batch", None, None))

    if "cross" in params and encoder_out is not None:
        h = L.rmsnorm(params["norm_x"], x, cfg.norm_eps)
        out, _ = attn_mod.apply_attention(
            cfg, params["cross"], h, positions=positions,
            kv_input=encoder_out)
        x = x + out

    if "ffn" in params:
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if "router" in params["ffn"]:          # structural MoE dispatch
            out, aux = moe_mod.apply_moe(cfg, params["ffn"], h)
        else:
            out = L.apply_mlp(params["ffn"], h, cfg.mlp_act)
        x = x + out
        x = constrain(x, ("batch", None, None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pattern = tuple(cfg.layer_pattern)
        self.period = len(self.pattern)
        self.n_periods = cfg.num_layers // self.period if cfg.scan_layers else 0
        self.remainder = cfg.num_layers - self.n_periods * self.period

    # -- init ---------------------------------------------------------------

    def init(self, key) -> tuple[dict, dict]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(key, cfg.num_layers + 3)
        params: dict[str, Any] = {}
        axes: dict[str, Any] = {}
        params["embed"], axes["embed"] = L.init_embed(
            keys[0], cfg.padded_vocab, cfg.d_model, dtype, cfg.tie_embeddings)
        params["final_norm"], axes["final_norm"] = L.init_rmsnorm(
            cfg.d_model, dtype)

        cross = cfg.encoder_decoder
        per_layer, per_axes = [], []
        for i in range(cfg.num_layers):
            kind = cfg.pattern_for_layer(i)
            p, a = _init_layer(keys[1 + i], cfg, kind, dtype, cross=cross,
                               layer_idx=i)
            per_layer.append(p)
            per_axes.append(a)

        # stack full periods: periods[slot] has leading dim n_periods
        if self.n_periods > 0:
            slots, slot_axes = [], []
            for j in range(self.period):
                group = [per_layer[t * self.period + j]
                         for t in range(self.n_periods)]
                slots.append(jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *group))
                slot_axes.append(jax.tree_util.tree_map(
                    lambda ax: ("layers",) + tuple(ax), per_axes[j],
                    is_leaf=lambda v: isinstance(v, tuple)))
            params["periods"] = slots
            axes["periods"] = slot_axes
        if self.remainder:
            base = self.n_periods * self.period
            params["tail"] = per_layer[base:]
            axes["tail"] = per_axes[base:]

        if cfg.encoder_decoder:
            enc_l, enc_a = [], []
            ekeys = jax.random.split(keys[-1], cfg.encoder_layers)
            for i in range(cfg.encoder_layers):
                p, a = _init_layer(ekeys[i], cfg, ATTN, dtype, cross=False)
                enc_l.append(p)
                enc_a.append(a)
            params["encoder"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *enc_l)
            axes["encoder"] = jax.tree_util.tree_map(
                lambda ax: ("layers",) + tuple(ax), enc_a[0],
                is_leaf=lambda v: isinstance(v, tuple))
        return params, axes

    def abstract_params(self) -> tuple[dict, dict]:
        """(ShapeDtypeStruct params, logical axes) with zero allocation."""
        holder = {}

        def _capture(key):
            p, a = self.init(key)
            holder["axes"] = a
            return p

        params_sds = jax.eval_shape(_capture, jax.random.PRNGKey(0))
        return params_sds, holder["axes"]

    # -- embedding / positions ------------------------------------------------

    def _positions(self, batch: dict, b: int, s: int, offset=0):
        cfg = self.cfg
        if cfg.mrope_sections:
            base = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
            pos = jnp.stack([base, base, base], axis=-1)        # (1,S,3)
            pos = jnp.broadcast_to(pos, (b, s, 3))
            if "patch_embeds" in batch and offset == 0:
                # grid positions for the fused patch prefix (t=0, h, w)
                npatch = batch["patch_embeds"].shape[1]
                side = max(int(npatch ** 0.5), 1)
                idx = jnp.arange(npatch, dtype=jnp.int32)
                grid = jnp.stack([jnp.zeros_like(idx), idx // side,
                                  idx % side], axis=-1)          # (P,3)
                pos = pos.at[:, :npatch].set(
                    jnp.broadcast_to(grid[None], (b, npatch, 3)))
            return pos
        return jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :] + offset, (b, s))

    def _embed_inputs(self, params, batch: dict):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            npatch = batch["patch_embeds"].shape[1]
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x[:, npatch:]], axis=1)
        return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    # -- encoder (whisper) ----------------------------------------------------

    def encode(self, params, audio_embeds):
        cfg = self.cfg
        b, s, _ = audio_embeds.shape
        x = audio_embeds + L.sinusoidal_positions(
            s, cfg.d_model).astype(audio_embeds.dtype)[None]
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(x, layer_params):
            out, _, _ = _apply_layer(
                cfg, ATTN, layer_params, x, positions=positions,
                layer_idx=0, causal=False)
            return out, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return x

    # -- training / scoring forward ------------------------------------------

    def forward(self, params, batch: dict):
        """Full-sequence forward. Returns (logits f32, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_inputs(params, batch)
        positions = self._positions(batch, b, s)
        encoder_out = None
        if cfg.encoder_decoder:
            encoder_out = self.encode(params, batch["audio_embeds"])

        aux_total = jnp.zeros((), jnp.float32)

        if self.n_periods > 0:
            def period_body(carry, slot_params):
                x, aux = carry
                for j, kind in enumerate(self.pattern):
                    x, _, a = _apply_layer(
                        cfg, kind, slot_params[j], x, positions=positions,
                        layer_idx=j, encoder_out=encoder_out)
                    aux = aux + a
                return (x, aux), None
            body = jax.checkpoint(period_body) if cfg.remat else period_body
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), tuple(params["periods"]))
        if self.remainder:
            for t, lp in enumerate(params["tail"]):
                idx = self.n_periods * self.period + t
                x, _, a = _apply_layer(
                    cfg, cfg.pattern_for_layer(idx), lp, x,
                    positions=positions, layer_idx=idx,
                    encoder_out=encoder_out)
                aux_total = aux_total + a

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.logits(params["embed"], x, tie=cfg.tie_embeddings)
        return logits, aux_total

    def loss(self, params, batch: dict):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        logp = jax.nn.log_softmax(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: partitions cleanly
        # over the vocab-sharded logits (a label gather makes SPMD replicate
        # the full f32 logits/unembed — §Perf nemotron iteration 3).
        onehot = jax.nn.one_hot(labels, cfg.padded_vocab, dtype=logp.dtype)
        nll = -jnp.einsum("bsv,bsv->bs", onehot, logp)
        ce = jnp.mean(nll)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    # -- serving ---------------------------------------------------------------

    def _layer_moe_idx(self, j, slot_in_period=True):
        return j

    def init_caches(self, batch: int, max_len: int, *, abstract=False):
        """Stacked caches mirroring the period structure."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        def one(kind):
            if kind == ATTN:
                return {"kv": attn_mod.init_cache(
                    cfg, batch, max_len, dtype=dtype, abstract=abstract)}
            if kind == ATTN_LOCAL:
                return {"kv": attn_mod.init_cache(
                    cfg, batch, max_len, window=cfg.local_window,
                    dtype=dtype, abstract=abstract)}
            if kind == RGLRU:
                w = cfg.rglru_width or cfg.d_model
                mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
                    else (lambda s, d: jnp.zeros(s, d))
                return {"rglru": rglru_mod.RGLRUCache(
                    mk((batch, w), jnp.float32),
                    mk((batch, cfg.conv_width - 1, w), dtype))}
            if kind == SSM:
                inner, nh, p, n = ssm_mod.dims(cfg)
                mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract \
                    else (lambda s, d: jnp.zeros(s, d))
                return {"ssm": ssm_mod.SSMCache(
                    mk((batch, nh, p, n), jnp.float32),
                    mk((batch, cfg.conv_width - 1, inner + 2 * n), dtype))}
            raise ValueError(kind)

        caches: dict[str, Any] = {}
        if self.n_periods > 0:
            slots = []
            for j, kind in enumerate(self.pattern):
                c = one(kind)
                slots.append(jax.tree_util.tree_map(
                    lambda leaf: (jax.ShapeDtypeStruct(
                        (self.n_periods,) + leaf.shape, leaf.dtype)
                        if abstract else
                        jnp.broadcast_to(leaf, (self.n_periods,) + leaf.shape)
                        .copy()), c))
            caches["periods"] = slots
        if self.remainder:
            base = self.n_periods * self.period
            caches["tail"] = [one(self.cfg.pattern_for_layer(base + t))
                              for t in range(self.remainder)]
        if cfg.encoder_decoder:
            shape = (batch, cfg.encoder_seq, cfg.d_model)
            caches["encoder_out"] = (jax.ShapeDtypeStruct(shape, dtype)
                                     if abstract else jnp.zeros(shape, dtype))
        return caches

    def prefill(self, params, batch: dict, max_len: int):
        """Forward over the prompt, building decode caches.

        Returns (logits (B, S, V), caches). For enc-dec models the encoder
        output is stored in caches["encoder_out"].
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_inputs(params, batch)
        positions = self._positions(batch, b, s)
        encoder_out = None
        if cfg.encoder_decoder:
            encoder_out = self.encode(params, batch["audio_embeds"])

        caches: dict[str, Any] = {}
        if self.n_periods > 0:
            def period_body(x, slot_params):
                new_slots = []
                for j, kind in enumerate(self.pattern):
                    x, nc, _ = _apply_layer(
                        cfg, kind, slot_params[j], x, positions=positions,
                        layer_idx=j, encoder_out=encoder_out,
                        make_cache=True, max_len=max_len)
                    new_slots.append(nc)
                return x, tuple(new_slots)
            x, period_caches = jax.lax.scan(
                period_body, x, tuple(params["periods"]))
            caches["periods"] = list(period_caches)
        if self.remainder:
            caches["tail"] = []
            base = self.n_periods * self.period
            for t, lp in enumerate(params["tail"]):
                idx = base + t
                x, nc, _ = _apply_layer(
                    cfg, cfg.pattern_for_layer(idx), lp, x,
                    positions=positions, layer_idx=idx,
                    encoder_out=encoder_out, make_cache=True,
                    max_len=max_len)
                caches["tail"].append(nc)
        if encoder_out is not None:
            caches["encoder_out"] = encoder_out

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.logits(params["embed"], x, tie=cfg.tie_embeddings)
        return logits, caches

    def decode_step(self, params, caches, tokens, pos, *,
                    encoder_out=None):
        """One token for every sequence. tokens (B, 1), pos scalar int32.

        Returns (logits (B, 1, V), new caches).
        """
        cfg = self.cfg
        b = tokens.shape[0]
        if encoder_out is None:
            encoder_out = caches.get("encoder_out")
        x = L.embed(params["embed"], tokens) * jnp.asarray(
            cfg.d_model ** 0.5, jnp.dtype(cfg.dtype))
        positions = self._positions({}, b, 1, offset=pos)

        new_caches: dict[str, Any] = {}
        if self.n_periods > 0:
            def period_body(x, scan_in):
                slot_params, slot_caches = scan_in
                new_slots = []
                for j, kind in enumerate(self.pattern):
                    x, nc, _ = _apply_layer(
                        cfg, kind, slot_params[j], x, positions=positions,
                        layer_idx=j, cache=slot_caches[j], pos=pos,
                        encoder_out=encoder_out)
                    new_slots.append(nc)
                return x, tuple(new_slots)
            x, new_period_caches = jax.lax.scan(
                period_body, x,
                (tuple(params["periods"]), tuple(caches["periods"])))
            new_caches["periods"] = list(new_period_caches)
        if self.remainder:
            new_caches["tail"] = []
            base = self.n_periods * self.period
            for t, lp in enumerate(params["tail"]):
                idx = base + t
                x, nc, _ = _apply_layer(
                    cfg, cfg.pattern_for_layer(idx), lp, x,
                    positions=positions, layer_idx=idx,
                    cache=caches["tail"][t], pos=pos,
                    encoder_out=encoder_out)
                new_caches["tail"].append(nc)

        if "encoder_out" in caches:
            new_caches["encoder_out"] = caches["encoder_out"]
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.logits(params["embed"], x, tie=cfg.tie_embeddings)
        return logits, new_caches
