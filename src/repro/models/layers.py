"""Shared LM building blocks: params-with-logical-axes, norms, MLPs, RoPE.

Every ``init_*`` returns ``(params, axes)`` — two mirrored pytrees, the
second holding logical axis names per dimension (see dist/sharding.py).
Every ``apply_*`` is a pure function. Dense contractions route through
``repro.ft.abft_dense.ft_einsum`` so the paper's ABFT protection is a
config switch, not a code change.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import fsdp_hint
from repro.ft.abft_dense import ft_einsum


# ---------------------------------------------------------------------------
# Param construction
# ---------------------------------------------------------------------------

def param(key, shape, axes, dtype, *, scale: Optional[float] = None):
    """Normal(0, scale) weight + its logical axes (fsdp-promoted if large)."""
    if scale is None:
        fan_in = shape[0] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    w = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return w, fsdp_hint(shape, axes)


def build(key, specs: dict, dtype):
    """specs: {name: (shape, axes)} or {name: (shape, axes, scale)}."""
    params, axes = {}, {}
    keys = jax.random.split(key, len(specs))
    for k, (name, spec) in zip(keys, specs.items()):
        shape, ax = spec[0], spec[1]
        scale = spec[2] if len(spec) > 2 else None
        params[name], axes[name] = param(k, shape, ax, dtype, scale=scale)
    return params, axes


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    return ({"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)})


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLPs (gated silu/gelu; ungated squared-ReLU for nemotron-4)
# ---------------------------------------------------------------------------

def mlp_gated(act: str) -> bool:
    return act in ("silu", "gelu")


def init_mlp(key, d: int, f: int, act: str, dtype):
    if mlp_gated(act):
        specs = {
            "wi": ((d, f), ("embed", "mlp")),
            "wg": ((d, f), ("embed", "mlp")),
            "wo": ((f, d), ("mlp", "embed")),
        }
    else:
        specs = {
            "wi": ((d, f), ("embed", "mlp")),
            "wo": ((f, d), ("mlp", "embed")),
        }
    return build(key, specs, dtype)


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":                      # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def apply_mlp(params, x, act: str):
    h = ft_einsum("bsd,df->bsf", x, params["wi"])
    if mlp_gated(act):
        g = ft_einsum("bsd,df->bsf", x, params["wg"])
        h = _act(act, g) * h
    else:
        h = _act(act, h)
    return ft_einsum("bsf,fd->bsd", h, params["wo"])


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE sections for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: tuple = ()) -> jax.Array:
    """x (B, S, H, hd); positions (B, S) or (B, S, 3) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 frequency slots are split into
    ``sections`` = (t, h, w) groups; each group rotates by its own
    position stream (temporal / height / width). Text tokens carry the
    same id in all three streams, reducing to standard RoPE.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 2:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        assert sections and sum(sections) == hd // 2, (sections, hd)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            f = freqs[start:start + sec]
            parts.append(positions[..., i, None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)        # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset=0) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (length-agnostic stub for
    the learned table; see DESIGN.md hardware-adaptation notes)."""
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10_000.0) * dim / d)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype, tie: bool):
    # stddev 1/sqrt(d): with the sqrt(d) input multiplier this gives
    # unit-variance activations AND O(1) tied logits.
    specs = {"embedding": ((vocab, d), ("vocab", "embed"), d ** -0.5)}
    if not tie:
        specs["unembed"] = ((d, vocab), ("embed", "vocab"))
    return build(key, specs, dtype)


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def logits(params, x, *, tie: bool):
    from repro.dist.sharding import constrain
    if tie:
        out = jnp.einsum("bsd,vd->bsv", x, params["embedding"],
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                         preferred_element_type=jnp.float32)
    # pin vocab-sharded logits: without this GSPMD all-gathers the full
    # f32 unembedding twice per microbatch (§Perf nemotron iteration 3)
    return constrain(out, ("batch", None, "vocab"))
