"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060) in JAX.

Chunked SSD: the sequence is split into chunks of ``SSD_CHUNK``; within a
chunk the dual (attention-like) quadratic form runs on the MXU, between
chunks a (B, H, P, N) state is carried by ``lax.scan`` — O(S) memory,
O(S * (L + N)) time per head-dim. This *is* the paper-relevant GEMM
formulation: the intra-chunk products are dense matmuls, which is where
DESIGN.md §4 applies ABFT for the ssm family; the inter-chunk recurrence
is elementwise (DMR territory).

Decode carries the same state with a one-token update: O(1) per token —
the reason mamba2 runs the long_500k cell.

Layout: inner = expand * d_model, P = head dim (64), H = inner / P heads,
single B/C group (g = 1), state size N = cfg.ssm_state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.ft.abft_dense import ft_einsum

SSD_CHUNK = 256
P_HEAD = 64


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, P, N)
    conv: jax.Array        # (B, W-1, conv_dim) trailing conv window


def dims(cfg):
    inner = cfg.ssm_expand * cfg.d_model
    nheads = cfg.ssm_heads or inner // P_HEAD
    return inner, nheads, inner // nheads, cfg.ssm_state


def init_ssm(key, cfg, dtype):
    d = cfg.d_model
    inner, h, p, n = dims(cfg)
    conv_dim = inner + 2 * n
    specs = {
        # z (gate), x, B, C, dt packed in one input projection
        "in_proj": ((d, 2 * inner + 2 * n + h), ("embed", "mlp")),
        "conv_w": ((cfg.conv_width, conv_dim), ("conv", None)),
        "out_proj": ((inner, d), ("mlp", "embed")),
    }
    params, axes = L.build(key, specs, dtype)
    params["A_log"] = jnp.zeros((h,), jnp.float32)
    axes["A_log"] = (None,)
    params["D"] = jnp.ones((h,), jnp.float32)
    axes["D"] = (None,)
    params["dt_bias"] = jnp.zeros((h,), jnp.float32)
    axes["dt_bias"] = (None,)
    np_, na = L.init_rmsnorm(inner, dtype)
    params["norm"], axes["norm"] = np_, na
    return params, axes


def _causal_conv(u, w, carry=None):
    """Depthwise causal conv. u (B,S,C), w (W,C). carry (B,W-1,C) or None."""
    width = w.shape[0]
    if carry is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = carry.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(width))
    new_carry = full[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out), new_carry


def _split(cfg, zxbcdt):
    inner, h, p, n = dims(cfg)
    z, x, bmat, cmat, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + n, 2 * inner + 2 * n], axis=-1)
    return z, x, bmat, cmat, dt


def _ssd_chunk(carry, chunk, *, A, nheads, p, n):
    """One chunk of the SSD scan. carry: state (B,H,P,N)."""
    x, B, C, dt = chunk          # x (B,L,H,P); B,C (B,L,N); dt (B,L,H)
    state = carry
    dA = dt * A[None, None, :]                     # (B,L,H) negative
    cs = jnp.cumsum(dA, axis=1)                    # (B,L,H)
    # intra-chunk: M[t,s] = C_t.B_s * exp(cs_t - cs_s) * dt_s   (s <= t)
    scores = jnp.einsum("bln,bsn->bls", C, B,
                        preferred_element_type=jnp.float32)
    decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B,L,S,H)
    l = x.shape[1]
    tri = jnp.tril(jnp.ones((l, l), jnp.bool_))
    m = jnp.where(tri[None, :, :, None], scores[..., None] * decay, 0.0)
    y_diag = jnp.einsum("blsh,bsh,bshp->blhp", m, dt, x.astype(jnp.float32))
    # contribution of the incoming state
    state_decay = jnp.exp(cs)                      # (B,L,H)
    y_off = jnp.einsum("bln,bhpn,blh->blhp", C, state, state_decay)
    # chunk-exit state
    out_decay = jnp.exp(cs[:, -1:, :] - cs)        # (B,L,H)
    new_state = state * jnp.exp(cs[:, -1])[:, :, None, None] + jnp.einsum(
        "blh,blh,bln,blhp->bhpn", out_decay, dt, B, x.astype(jnp.float32))
    return new_state, (y_diag + y_off)


def apply_ssm(cfg, params, u, *, cache: SSMCache = None, chunk=SSD_CHUNK):
    """u (B, S, D) -> (B, S, D). With cache: decode step (S small)."""
    b, s, d = u.shape
    inner, h, p, n = dims(cfg)
    zxbcdt = ft_einsum("bsd,df->bsf", u, params["in_proj"])
    z, xbc_x, bmat, cmat, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xbc_x, bmat, cmat], axis=-1)
    conv_out, conv_carry = _causal_conv(
        conv_in, params["conv_w"],
        carry=None if cache is None else cache.conv)
    x, bmat, cmat = jnp.split(conv_out, [inner, inner + n], axis=-1)

    A = -jnp.exp(params["A_log"])                  # (H,) negative decay
    dt_ = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"][None, None])  # (B,S,H)
    xh = x.reshape(b, s, h, p)

    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if cache is None
              else cache.state)

    if s == 1:                                     # decode fast path
        dA = jnp.exp(dt_[:, 0] * A[None])          # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_[:, 0], bmat[:, 0],
                         xh[:, 0].astype(jnp.float32))
        state = state0 * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], state)[:, None]
        y = y.reshape(b, 1, h, p)
    else:
        pad = (-s) % chunk
        xp = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bp = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cp = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dtp = jnp.pad(dt_, ((0, 0), (0, pad), (0, 0)))
        nc = xp.shape[1] // chunk
        resh = lambda t: t.reshape((b, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
        state, ys = jax.lax.scan(
            lambda c, ch: _ssd_chunk(c, ch, A=A, nheads=h, p=p, n=n),
            state0, (resh(xp), resh(bp), resh(cp), resh(dtp)))
        y = ys.swapaxes(0, 1).reshape(b, nc * chunk, h, p)[:, :s]

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, inner).astype(u.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = ft_einsum("bsf,fd->bsd", y, params["out_proj"])
    new_cache = SSMCache(state, conv_carry) if cache is not None else None
    return out, new_cache
