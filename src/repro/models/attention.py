"""GQA attention with local windows, RoPE/M-RoPE, KV caches, chunked scores.

Memory discipline:
  * train/prefill: scores computed in query chunks (``Q_CHUNK``) so the
    (S x S) matrix never materializes (bounded 32k-prefill activations);
  * decode, global layers: full-length cache, masked by key position;
  * decode, local layers: **ring-buffer cache of `window` entries** — the
    gemma3/recurrentgemma long-context play; a 500k-token stream costs
    O(window) memory on local layers. Keys carry absolute positions, so
    masking is uniform: valid = (kpos <= q) & (kpos > q - window).

All projections route through ft_einsum (paper ABFT, config-switched).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import layers as L
from repro.ft.abft_dense import ft_einsum

Q_CHUNK = 1024
NEG_POS = -(1 << 30)


def _tp_size() -> int:
    mesh = shd.active_mesh()
    return mesh.shape.get("model", 1) if mesh is not None else 1


class KVCache(NamedTuple):
    k: jax.Array          # (B, Len, KV, hd)
    v: jax.Array          # (B, Len, KV, hd)
    positions: jax.Array  # (Len,) int32 absolute positions (NEG_POS = empty)


def init_cache(cfg, batch: int, max_len: int, *, window: int = 0,
               dtype=jnp.bfloat16, abstract: bool = False):
    """window > 0 -> ring buffer of `window` entries."""
    length = min(max_len, window) if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    pos = mk((length,), jnp.int32) if abstract else \
        jnp.full((length,), NEG_POS, jnp.int32)
    return KVCache(mk((batch, length, kv, hd), dtype),
                   mk((batch, length, kv, hd), dtype), pos)


def init_attention(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    specs = {
        "wq": ((d, cfg.num_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ((cfg.num_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    return L.build(key, specs, dtype)


def _block_attend(q, k, v, mask):
    """q (B,Sq,KV,G,hd), k/v (B,Skv,KV,hd), mask (Sq, Skv) or (B,Sq,Skv)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32)
    while mask.ndim < s.ndim:
        mask = mask[None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (e.g. cold ring slots) -> zero output
    any_valid = jnp.any(mask, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def _attend_local(q, k, v, *, q_positions, kv_positions, causal, window,
                  chunk):
    """Chunked attention on local (per-device) arrays."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = (q * hd ** -0.5).reshape(b, sq, kvh, h // kvh, hd)

    def mask_for(qpos):
        m = (kv_positions >= 0)[None, :]
        if causal:
            m = m & (kv_positions[None, :] <= qpos[:, None])
        if window:
            m = m & (kv_positions[None, :] > qpos[:, None] - window)
        return m

    if sq <= chunk:
        return _block_attend(qg, k, v, mask_for(q_positions)).reshape(
            b, sq, h, hd)
    n, rem = divmod(sq, chunk)
    main = n * chunk
    qs = qg[:, :main].reshape(b, n, chunk, kvh, h // kvh, hd).transpose(
        1, 0, 2, 3, 4, 5)
    qp = q_positions[:main].reshape(n, chunk)
    out = jax.lax.map(
        lambda args: _block_attend(args[0], k, v, mask_for(args[1])),
        (qs, qp))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, main, h, hd)
    if rem:   # tail chunk (e.g. whisper's 1500-frame encoder)
        tail = _block_attend(qg[:, main:], k, v,
                             mask_for(q_positions[main:])).reshape(
            b, rem, h, hd)
        out = jnp.concatenate([out, tail], axis=1)
    return out


def attend(q, k, v, *, q_positions, kv_positions, causal: bool = True,
           window: int = 0, chunk: int = Q_CHUNK):
    """Position-masked attention. q (B,Sq,H,hd); k/v (B,Skv,KV,hd).

    q_positions (Sq,), kv_positions (Skv,) are absolute. Mask:
      valid = kpos >= 0 & (causal -> kpos <= qpos)
                        & (window -> kpos > qpos - window)

    Context parallelism (train/prefill): the query sequence is sharded
    over the 'model' axis with an EXPLICIT shard_map — q/scores/output
    per-device, k/v replicated across TP. Head counts (40, 36, 28, 8, ...)
    don't divide TP=16 across the assigned archs; sharding the contracted
    head_dim all-reduces full f32 scores, and constraint-based seq
    sharding left GSPMD free to re-gather 3 GiB score chunks in the
    backward (§Perf nemotron iterations 0-2) — shard_map makes the
    collective schedule deterministic: none in attention itself, small
    psums for the k/v gradients only.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = shd.active_mesh()
    b, sq = q.shape[0], q.shape[1]
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or tp <= 1 or sq <= 1 or sq % tp != 0:
        return _attend_local(q, k, v, q_positions=q_positions,
                             kv_positions=kv_positions, causal=causal,
                             window=window, chunk=chunk)

    daxes = shd.data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    brow = (daxes if len(daxes) > 1 else daxes[0]) \
        if (b % dp == 0 and b >= dp) else None
    local_chunk = max(min(chunk, sq // tp), 128)

    def body(q, k, v, qpos, kvpos):
        return _attend_local(q, k, v, q_positions=qpos, kv_positions=kvpos,
                             causal=causal, window=window, chunk=local_chunk)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(brow, "model", None, None),
                  P(brow, None, None, None),
                  P(brow, None, None, None),
                  P("model"), P(None)),
        out_specs=P(brow, "model", None, None),
        check_rep=False,
    )(q, k, v, q_positions, kv_positions)


def apply_attention(cfg, params, x, *, positions, causal=True, window=0,
                    cache: Optional[KVCache] = None, pos=None,
                    kv_input=None, make_cache=False, max_len=0):
    """Attention block: projections + rope + (cache r/w) + attend + out proj.

    Modes:
      * train:            cache=None, make_cache=False
      * prefill:          cache=None, make_cache=True (returns fresh cache
                          of length max_len holding this call's k/v)
      * decode:           cache + scalar pos (one new token)
      * cross-attention:  kv_input = encoder states (no rope, no cache)
    """
    kv_src = kv_input if kv_input is not None else x
    # Sequence parallelism (Megatron-SP flavoured): head counts (40, 36,
    # 28, 8, ...) don't divide TP=16 across the assigned archs, so the
    # QKV/out projections and score/value products shard over the *query
    # sequence* instead. k/v are re-gathered across TP for the attend
    # (bf16, ~D bytes/token — cheap next to f32 score all-reduces).
    if x.shape[1] > 1 and x.shape[1] % _tp_size() == 0:
        x = shd.constrain(x, ("batch", "seq_tp", None))
    q = ft_einsum("bsd,dhk->bshk", x, params["wq"])
    k = ft_einsum("bsd,dhk->bshk", kv_src, params["wk"])
    v = ft_einsum("bsd,dhk->bshk", kv_src, params["wv"])

    if kv_input is not None:
        # cross-attention: every encoder frame visible, no rope.
        skv = k.shape[1]
        out = attend(q, k, v, q_positions=jnp.zeros((x.shape[1],), jnp.int32),
                     kv_positions=jnp.zeros((skv,), jnp.int32), causal=False)
        return ft_einsum("bshk,hkd->bsd", out, params["wo"]), cache

    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    pos1d = positions if positions.ndim == 2 else positions[..., 0]

    if cache is not None:
        # decode: write (k, v, pos) into the (ring) buffer, attend over it.
        length = cache.k.shape[1]
        slot = pos % length
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache.positions, pos[None].astype(jnp.int32)
            if jnp.ndim(pos) == 0 else pos.astype(jnp.int32), (slot,))
        new_cache = KVCache(ck, cv, cpos)
        out = attend(q, ck, cv, q_positions=pos1d[0],
                     kv_positions=cpos, causal=True, window=window)
    elif make_cache:
        sq = x.shape[1]
        length = min(max_len, window) if window else max_len
        pad = length - sq
        if pad >= 0:
            ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.pad(pos1d[0], (0, pad), constant_values=NEG_POS)
        else:  # prefill longer than ring: keep the tail, preserving the
            # ring invariant slot(p) = p % length so decode writes land
            # on the oldest entry.
            ck, cv = k[:, -length:], v[:, -length:]
            cpos = pos1d[0][-length:]
            shift = (sq - length) % length
            ck = jnp.roll(ck, shift, axis=1)
            cv = jnp.roll(cv, shift, axis=1)
            cpos = jnp.roll(cpos, shift, axis=0)
        new_cache = KVCache(ck, cv, cpos.astype(jnp.int32))
        out = attend(q, k, v, q_positions=pos1d[0],
                     kv_positions=pos1d[0], causal=causal, window=window)
    else:
        new_cache = None
        out = attend(q, k, v, q_positions=pos1d[0],
                     kv_positions=pos1d[0], causal=causal, window=window)

    return ft_einsum("bshk,hkd->bsd", out, params["wo"]), new_cache
