"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = linear in-projection to width W, short causal conv, the Real-Gated
LRU recurrence, gated by a GeLU branch, linear out-projection:

    r_t = sigmoid(w_a . x_t + b_a)          (recurrence gate, diagonal)
    i_t = sigmoid(w_i . x_t + b_i)          (input gate, diagonal)
    a_t = exp(-c * softplus(Lambda) * r_t)  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

The recurrence h_t = a_t h_{t-1} + b_t is associative — training/prefill
use ``jax.lax.associative_scan`` (log-depth, parallel over the sequence);
decode is a single elementwise update carrying h (the O(1)-state reason
the hybrid runs long_500k). Gates are diagonal per-channel (the
block-diagonal Griffin gates with block size 1 — noted in DESIGN.md).
The recurrence is elementwise -> DMR-protected, not ABFT (paper's split).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.ft.abft_dense import ft_einsum

C_FACTOR = 8.0


class RGLRUCache(NamedTuple):
    h: jax.Array          # (B, W) recurrent state
    conv: jax.Array       # (B, conv_width-1, W)


def init_rglru(key, cfg, dtype):
    d = cfg.d_model
    w = cfg.rglru_width or d
    specs = {
        "in_x": ((d, w), ("embed", "mlp")),
        "in_gate": ((d, w), ("embed", "mlp")),
        "conv_w": ((cfg.conv_width, w), ("conv", None)),
        "out": ((w, d), ("mlp", "embed")),
    }
    params, axes = L.build(key, specs, dtype)
    for name in ("lambda_p", "w_a", "b_a", "w_i", "b_i"):
        params[name] = jnp.zeros((w,), jnp.float32) if name != "lambda_p" \
            else jnp.full((w,), 0.5, jnp.float32)
        axes[name] = ("mlp",)
    return params, axes


def _recurrence(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a, b (B, S, W)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru(cfg, params, u, *, cache: RGLRUCache = None):
    """u (B, S, D) -> (B, S, D)."""
    b, s, d = u.shape
    w = cfg.rglru_width or d
    x = ft_einsum("bsd,dw->bsw", u, params["in_x"])
    gate = jax.nn.gelu(ft_einsum("bsd,dw->bsw", u, params["in_gate"]))

    width = params["conv_w"].shape[0]
    carry = None if cache is None else cache.conv
    if carry is None:
        pad = jnp.zeros((b, width - 1, w), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    full = jnp.concatenate([pad, x], axis=1)
    x = sum(full[:, i:i + s] * params["conv_w"][i] for i in range(width))
    new_conv = full[:, -(width - 1):]

    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(params["w_a"] * xf + params["b_a"])
    i = jax.nn.sigmoid(params["w_i"] * xf + params["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lambda_p"]) * r   # (B,S,W)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xf)

    if s == 1 and cache is not None:               # decode fast path
        h = a[:, 0] * cache.h + bterm[:, 0]
        hs = h[:, None]
    else:
        h0 = None if cache is None else cache.h
        hs = _recurrence(a, bterm, h0)
        h = hs[:, -1]

    y = (hs.astype(u.dtype) * gate)
    out = ft_einsum("bsw,wd->bsd", y, params["out"])
    new_cache = RGLRUCache(h, new_conv) if cache is not None else None
    return out, new_cache
