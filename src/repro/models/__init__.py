from repro.models.model import LM
from repro.models import layers, attention, moe, ssm, rglru

__all__ = ["LM", "layers", "attention", "moe", "ssm", "rglru"]
