"""Assignment-backend registry — the paper's kernel-selection surface.

The paper's code-generation pipeline (§III-B) produces a *set* of kernels
and a selection layer that picks one per problem; the stepwise ladder
(§III-A) and the ABFT variants (§IV) are alternative implementations of the
same contract. This module makes that contract explicit: every assignment
implementation is an :class:`AssignmentBackend` with declared capabilities
and one uniform call signature

    backend(x, c, *, params=None, inj=None) -> (assign, min_dist, detected)

so the driver (``repro.api.KMeans``) never branches on backend names.
Capability mismatches (e.g. an injection campaign routed into a backend
without in-kernel injection support) are rejected here, at the boundary,
instead of failing silently inside a kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax


class BackendCapabilityError(TypeError):
    """A backend was asked for a capability it does not declare."""


# Capability flags, in rendering order (also the machine-readable contract
# vocabulary consumed by repro.analysis.contracts).
_FLAG_COLUMNS = ("supports_ft", "takes_params", "takes_injection",
                 "fuses_update", "supports_batch", "supports_bounds",
                 "supports_int8")


@dataclasses.dataclass(frozen=True)
class AssignmentBackend:
    """One cluster-assignment implementation plus its capability flags.

    fn: the raw callable. Its positional signature may be any of
        ``(x, c)``, ``(x, c, params)`` or ``(x, c, params, inj=...)`` —
        the flags say which; ``__call__`` adapts uniformly.
    supports_ft:     detects (and possibly corrects) SDCs, returning a
                     nonzero detected-error count when one fires.
    takes_params:    accepts a :class:`~repro.kernels.ops.KernelParams`
                     tile selection (Pallas-backed kernels). ``x`` may then
                     also be a prebuilt :class:`~repro.kernels.ops.DataPlan`.
    takes_injection: accepts an in-kernel SEU injection descriptor.
    fuses_update:    one-pass Lloyd backend — returns the extended 5-tuple
                     ``(assign, min_dist, detected, sums, counts)`` so the
                     driver skips the separate centroid-update pass over X.
    supports_batch:  many-problem backend — ``x`` is a (B, N, F) stack and
                     ``c`` a (B, K, F) per-problem centroid stack; every
                     output gains the leading problem axis. Single-problem
                     drivers must not route (M, F) data here and batched
                     drivers (``repro.batch``) require the flag.
    supports_bounds: stateful pruned backend — accepts an iteration-carried
                     ``bounds`` state (:class:`~repro.kernels.ops.
                     BoundsState` or the backend's own shape) and returns
                     the extended 7-tuple ``(assign, min_dist, detected,
                     sums, counts, new_bounds, prune_frac)``. ``bounds=None``
                     (or a fresh state from ``bounds_init``) computes every
                     tile and seeds the bounds; anything that moves
                     centroids outside the backend's own update must pass a
                     fresh state.
    supports_int8:   quantized-distance backend — the distance GEMM runs
                     on per-row int8-quantized operands (the int8 kernel
                     template or its f32-carrier XLA analogue); ``x`` may
                     be a prebuilt :class:`~repro.kernels.ops.QuantPlan`.
                     The argmin is bit-exact vs the f32 backends on
                     quantization-safe data and error-bounded on floats;
                     tiles come from the ``int8`` autotune table.
    bounds_init:     for ``supports_bounds`` backends, a callable
                     ``(m, k, f, params=None, *, dtype=...) -> state``
                     building the fresh (all-invalid) bounds state the
                     driver threads into iteration zero.
    """

    name: str
    fn: Callable
    supports_ft: bool = False
    takes_params: bool = False
    takes_injection: bool = False
    fuses_update: bool = False
    supports_batch: bool = False
    supports_bounds: bool = False
    supports_int8: bool = False
    bounds_init: Optional[Callable] = None
    doc: str = ""

    @property
    def kernel_kind(self) -> str:
        """The autotune kernel kind this backend's tiles are selected for
        (``repro.core.autotune.KINDS``): the assignment-only kernel, the
        one-pass (fused-update) kernel, or the one-pass FT kernel — their
        VMEM footprints and traffic profiles differ, so winners must not
        cross. Only meaningful when ``takes_params`` is True, but derived
        from the capability flags either way."""
        if self.supports_int8:
            return "int8"
        if self.supports_batch:
            return "batched"
        if self.supports_bounds:
            return "pruned"
        if self.fuses_update:
            return "lloyd_ft" if self.supports_ft else "lloyd"
        return "assign"

    @property
    def protected_intervals(self) -> int:
        """How many independently verified SEU intervals one step of this
        backend exposes to an injection campaign (§II-A: at most one error
        per detection/correction interval): the distance GEMM and — for
        one-pass FT backends — the update epilogue."""
        if not self.takes_injection:
            return 0
        return 2 if self.fuses_update else 1

    @property
    def expected_arity(self) -> int:
        """Length of the uniform-call return tuple: ``(assign, min_dist,
        detected)``, extended by ``(sums, counts)`` for one-pass backends
        and further by ``(new_bounds, prune_frac)`` for bounds-carrying
        pruned backends. The contract checker verifies this against an
        abstract evaluation of the real callable."""
        if self.supports_bounds:
            return 7
        return 5 if self.fuses_update else 3

    def contract(self) -> dict[str, Any]:
        """Machine-readable contract metadata for this backend — the exact
        surface ``repro.analysis.contracts`` verifies against the kernel
        implementations (flags vs signature, descriptor slots, autotune
        kind)."""
        return {
            "name": self.name,
            "flags": {c: bool(getattr(self, c)) for c in _FLAG_COLUMNS},
            "kernel_kind": self.kernel_kind,
            "protected_intervals": self.protected_intervals,
            "expected_arity": self.expected_arity,
        }

    def __call__(self, x: jax.Array, c: jax.Array, *,
                 params: Any = None,
                 inj: Optional[jax.Array] = None,
                 bounds: Any = None) -> Any:
        if inj is not None and not self.takes_injection:
            raise BackendCapabilityError(
                f"backend {self.name!r} does not take in-kernel injections "
                f"(takes_injection=False); use a fault-tolerant backend or "
                f"drop the injection campaign")
        if params is not None and not self.takes_params:
            raise BackendCapabilityError(
                f"backend {self.name!r} does not take kernel parameters "
                f"(takes_params=False)")
        if bounds is not None and not self.supports_bounds:
            raise BackendCapabilityError(
                f"backend {self.name!r} does not carry pruning bounds "
                f"(supports_bounds=False); use a pruned backend or drop "
                f"the bounds state")
        if self.supports_bounds:
            if self.takes_params:
                return self.fn(x, c, params, bounds=bounds)
            return self.fn(x, c, bounds=bounds)
        if self.takes_injection:
            if self.takes_params:
                return self.fn(x, c, params, inj=inj)
            return self.fn(x, c, inj=inj)
        if self.takes_params:
            return self.fn(x, c, params)
        return self.fn(x, c)


# The registry itself is the one sanctioned module-level mutable: an
# append-only name->backend table populated at import time, not a cache.
_REGISTRY: dict[str, AssignmentBackend] = {}  # analysis: allow=module-state


def register_backend(backend: AssignmentBackend) -> AssignmentBackend:
    """Register (or replace) a backend under its name."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> AssignmentBackend:
    _ensure_builtin_backends()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown assignment backend {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def list_backends() -> dict[str, AssignmentBackend]:
    """Name -> backend, a snapshot of the registry."""
    _ensure_builtin_backends()
    return dict(_REGISTRY)


def _ensure_builtin_backends() -> None:
    # The built-in ladder registers itself on import; importing here (not at
    # module top) keeps registry.py import-cycle-free.
    from repro.core import assignment as _assignment  # noqa: F401


# ---------------------------------------------------------------------------
# Capability matrix rendering — ``python -m repro.api.registry --markdown``
# generates docs/backends.md; CI re-renders and diffs so the committed file
# cannot go stale (see tests/test_docs.py and the workflow doc-check step).
# ---------------------------------------------------------------------------

_MD_HEADER = """\
# Backend capability matrix

<!-- AUTO-GENERATED by `python -m repro.api.registry --markdown docs/backends.md`.
     Do not edit by hand: CI fails when this file is stale. -->

Every cluster-assignment implementation registers as an
`AssignmentBackend` with declared capabilities and the uniform call
signature `backend(x, c, *, params=None, inj=None)`; drivers select one via
`FaultPolicy.resolve_backend` or `get_backend(name)` and never branch on
backend names. See [architecture.md](architecture.md) for where the
registry sits in the stack and [kernels.md](kernels.md) for the kernels
behind the `takes_params` backends.
"""


def render_markdown() -> str:
    """The registry as a markdown document (capability flags, autotune
    kernel kind, protected injection intervals, one-line doc)."""
    backends = list_backends()
    short = {"supports_ft": "ft", "takes_params": "params",
             "takes_injection": "inject", "fuses_update": "one-pass",
             "supports_batch": "batch", "supports_bounds": "pruned",
             "supports_int8": "int8"}
    lines = [_MD_HEADER]
    lines.append("| backend | " + " | ".join(short[c] for c in _FLAG_COLUMNS)
                 + " | kernel kind | protected intervals | description |")
    lines.append("|---|" + "---|" * (len(_FLAG_COLUMNS) + 3))
    for name in sorted(backends):
        b = backends[name]
        flags = " | ".join("✓" if getattr(b, c) else "·"
                           for c in _FLAG_COLUMNS)
        lines.append(f"| `{name}` | {flags} | `{b.kernel_kind}` | "
                     f"{b.protected_intervals} | {b.doc} |")
    lines.append("")
    lines.append("Flag legend: **ft** = detects/corrects SDCs "
                 "(`supports_ft`); **params** = accepts `KernelParams` "
                 "tiles and `DataPlan`/`BatchPlan` inputs (`takes_params`); "
                 "**inject** = accepts an in-kernel SEU descriptor "
                 "(`takes_injection`); **one-pass** = returns the extended "
                 "`(assign, min_dist, detected, sums, counts)` tuple "
                 "(`fuses_update`); **batch** = operates on (B, N, F) "
                 "problem stacks (`supports_batch`); **pruned** = carries "
                 "triangle-inequality bounds between iterations and "
                 "returns the 7-tuple extended by `(new_bounds, "
                 "prune_frac)` (`supports_bounds`); **int8** = runs the "
                 "distance GEMM on per-row int8-quantized operands and "
                 "accepts `QuantPlan` inputs (`supports_int8`). "
                 "*Kernel kind* is the "
                 "autotune table the backend's tiles come from; *protected "
                 "intervals* counts the independently verified SEU "
                 "intervals one step exposes to an injection campaign.")
    lines.append("")
    return "\n".join(lines)


def main(argv: Optional[list[str]] = None) -> int:
    """CLI: render (or freshness-check) the capability matrix.

    Exit codes are shared with ``python -m repro.analysis`` (see
    ``repro.analysis.report``): 0 = clean, 1 = violations/stale file,
    2 = usage error. ``--format=github`` emits workflow-command
    annotations so CI failures point at the offending file.
    """
    import argparse

    # ``python -m repro.api.registry`` executes this module as __main__ —
    # a *second* module instance with its own empty _REGISTRY, while the
    # builtin backends register into the canonical ``repro.api.registry``.
    # Always render through the canonical instance.
    from repro.api import registry as _canonical
    from repro.analysis import report
    render = _canonical.render_markdown

    ap = argparse.ArgumentParser(
        prog="python -m repro.api.registry",
        description="render the backend capability matrix as markdown")
    ap.add_argument("--markdown", nargs="?", const="-", metavar="PATH",
                    help="write the matrix to PATH (default: stdout)")
    ap.add_argument("--check", metavar="PATH",
                    help=f"exit {report.EXIT_VIOLATIONS} if PATH differs "
                         f"from a fresh render (CI staleness gate)")
    ap.add_argument("--format", choices=report.FORMATS, default="text",
                    help="violation output style (github = workflow "
                         "annotations)")
    args = ap.parse_args(argv)
    if args.check:
        rendered = render()
        try:
            with open(args.check, encoding="utf-8") as fh:
                committed: Optional[str] = fh.read()
        except FileNotFoundError:
            committed = None
        if committed != rendered:
            stale = report.Violation(
                pass_name="docs", rule="stale-matrix", file=args.check,
                message=(f"{args.check} is stale; regenerate with "
                         f"`python -m repro.api.registry --markdown "
                         f"{args.check}`"))
            return report.emit([stale], fmt=args.format)
        print(f"{args.check} is up to date")
        return report.EXIT_OK
    out = render()
    if args.markdown in (None, "-"):
        print(out, end="")
    else:
        with open(args.markdown, "w", encoding="utf-8") as fh:
            fh.write(out)
        print(f"wrote {args.markdown}")
    return report.EXIT_OK


if __name__ == "__main__":
    raise SystemExit(main())
