"""Injectable kernel-selection cache (paper §III-B selection pipeline).

The paper benchmarks ~150 generated kernels over 64 problem sizes and
persists the per-shape winners; the runtime consults that table. The legacy
implementation hid the table behind a module global plus an env var —
untestable and shared across every estimator in the process.

:class:`AutotuneCache` is that table as an object: it owns load/save/lookup
and is passed per-estimator (``KMeans(..., autotune=cache)``), so two
estimators can run with different tables in one process and tests get a
fresh cache per case.

Schema v7: entries are keyed by *kernel kind, compute dtype and batch
bucket* as well as shape bucket, and each winner records its *template
variant* alongside the tiles::

    {"schema": 7,
     "kinds": {"assign/float32/b0":  {"14-7-7": ["smallk", 256, 128, 128]},
               "lloyd/bfloat16/b0":  {...},
               "pruned/float32/b0":  {"14-7-7": ["generic", 256, 128, 128]},
               "int8/int8/b0":       {"14-7-7": ["generic", 256, 128, 512]},
               "batched/float32/b6": {"8-3-5": ["batched", 256, 128, 128]},
               "serve/float32/b0":   {"9-6-6": ["smallk", 256, 128, 128],
                                      "ladder:6-6": [500.0, 128, 512, 2048]}}}

v7, like v5 and v6 before it, extends the *kind vocabulary* without
changing the entry format: ``ops.PLAN_KINDS`` gains ``serve`` — the
assignment kernel launched as an AOT-compiled predict cell, with winners
recorded per serving *bucket* shape. v7 additionally adds one pseudo-entry
under the serve kind, keyed ``ladder:<log2 K>-<log2 F>`` instead of a
shape bucket: ``[window_us, bucket, bucket, ...]`` — the tuned
micro-batching window and row-count bucket ladder for a model shape
(``put_ladder`` / ``lookup_ladder``; the 4-field winner accessors never
see it because ladder keys are not shape buckets). v4-v6 files load
unchanged; the version bump marks that a v7 table may hold ``serve/...``
keys an older runtime would reject at ``select_params``.

The assignment-only kernel, the one-pass Lloyd kernel and the one-pass FT
kernel (``lloyd_ft``: one-pass footprint plus checksum scratch and the
expected-checksum output blocks) share a tile-parameter type but have
different VMEM footprints and traffic profiles (schema v2's lesson), and a
winner tuned for f32 tiles is mis-sized for bf16/fp16 ones (half the bytes
per element, 16-row sublanes) — so neither kind nor dtype may cross. The
``batched`` kind adds the B bucket (log2, like the shape buckets): a B=4
launch and a B=1024 launch amortize dispatch and pipeline ramp-up very
differently at the same per-problem shape, so their winners must not cross
either. Single-problem kinds always live in bucket ``b0``.

Older files still load: v4 files pass through untouched (same entry
format), v3 files (kind/dtype keys, no batch axis) map to bucket ``b0``
of their kind/dtype, v2 files (kind-keyed, pre-dtype) are interpreted as
f32 winners of the ``generic`` template, and v1 files (flat bucket ->
blocks) as f32 ``assign``-kind generic winners; all upgrade to v6 on
``save()``.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Iterable, Optional

import numpy as np

from repro.kernels.ops import KernelParams

_DEFAULT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "core", "autotune_table.json")
_PATH_ENV = "REPRO_AUTOTUNE_TABLE"   # still honoured, but only here

SCHEMA_VERSION = 7
_DEFAULT_DTYPE = "float32"
_LEGACY_VARIANT = "generic"


def shape_bucket(m: int, k: int, f: int) -> str:
    """log2 bucket per dimension — the paper's 64-discrete-sizes granularity:
    shapes in a bucket share a winner."""
    b = lambda v: int(math.log2(max(v, 1)))
    return f"{b(m)}-{b(k)}-{b(f)}"


def batch_bucket(batch: int) -> str:
    """log2 bucket of the problem count B (``b0`` = single-problem)."""
    return f"b{int(math.log2(max(batch, 1)))}"


def _dtype_name(dtype: Any) -> str:
    """Normalize a dtype spec (None / str / np dtype / jnp scalar type) to
    the canonical name used in table keys."""
    if dtype is None:
        return _DEFAULT_DTYPE
    return np.dtype(dtype).name


def _key(kind: str, dtype: Any, batch: int = 1) -> str:
    return f"{kind}/{_dtype_name(dtype)}/{batch_bucket(batch)}"


class AutotuneCache:
    """Kind-, dtype- and shape-bucketed winner table with lazy file backing.

    path=None keeps the cache purely in-memory; a string path loads the
    JSON table on first lookup and ``save()`` writes winners back. Each
    entry is ``[variant, block_m, block_k, block_f]``.
    """

    def __init__(self, path: Optional[str] = None) -> None:
        self.path = path
        self._table: Optional[dict[str, dict[str, list]]] = None
        self._computed: dict[tuple[int, int, int, str, str, str],
                             tuple[str, KernelParams]] = {}
        self._lock = threading.RLock()   # build() holds it across put/save

    @classmethod
    def default(cls) -> "AutotuneCache":
        """Process-default cache: $REPRO_AUTOTUNE_TABLE or the packaged
        table location. Estimators that want isolation pass their own."""
        return cls(os.environ.get(_PATH_ENV, _DEFAULT_PATH))

    # -- table I/O ---------------------------------------------------------

    @staticmethod
    def _upgrade(raw: Any) -> dict[str, dict[str, list]]:
        """Any on-disk schema -> the current in-memory shape (v4-v7 share
        the entry format; v5-v7 only widen the kind vocabulary, plus v7's
        serve-kind ladder pseudo-entries)."""
        if isinstance(raw, dict) and raw.get("schema", 1) >= 4:
            return {k: dict(v) for k, v in raw["kinds"].items()}
        if isinstance(raw, dict) and raw.get("schema", 1) == 3:
            # v3: {"kind/dtype": {bucket: [variant, blocks...]}} — no batch
            # axis yet; every winner was single-problem -> bucket b0
            return {f"{k}/{batch_bucket(1)}": dict(v)
                    for k, v in raw["kinds"].items()}
        if isinstance(raw, dict) and raw.get("schema", 1) == 2:
            # v2: {kind: {bucket: [bm, bk, bf]}} — f32 generic winners
            return {_key(kind, None): {b: [_LEGACY_VARIANT, *blocks]
                                       for b, blocks in v.items()}
                    for kind, v in raw["kinds"].items()}
        # v1 flat {bucket: blocks}: winners tuned for the f32
        # assignment-only kernel, generic template
        return {_key("assign", None): {b: [_LEGACY_VARIANT, *blocks]
                                       for b, blocks in dict(raw).items()}}

    def _load(self) -> dict[str, dict[str, list]]:
        if self._table is None:
            table: dict[str, dict[str, list]] = {}
            if self.path and os.path.exists(self.path):
                with open(self.path) as fh:
                    table = self._upgrade(json.load(fh))
            self._table = table
        return self._table

    def save(self, path: Optional[str] = None) -> str:
        """Persist the current table (current schema, sorted, stable) and
        return the path. Legacy v1-v4 tables are upgraded on save."""
        path = path or self.path
        if not path:
            raise ValueError("AutotuneCache has no backing path to save to")
        with self._lock:
            kinds = self._load()   # before open(..., "w") truncates the file
            with open(path, "w") as fh:
                json.dump({"schema": SCHEMA_VERSION, "kinds": kinds},
                          fh, indent=1, sort_keys=True)
        return path

    # -- lookup / update ---------------------------------------------------

    def put(self, m: int, k: int, f: int, params: KernelParams, *,
            kind: str = "assign", dtype: Any = None,
            variant: str = _LEGACY_VARIANT, batch: int = 1) -> None:
        with self._lock:
            self._load().setdefault(_key(kind, dtype, batch), {})[
                shape_bucket(m, k, f)] = [
                variant, params.block_m, params.block_k, params.block_f]

    def lookup(self, m: int, k: int, f: int, *, kind: str = "assign",
               dtype: Any = None, batch: int = 1) -> tuple[str, KernelParams]:
        """Persisted ``(variant, params)`` winner for (kind, dtype, batch
        bucket, shape bucket), else the analytical winner computed on the
        fly (memoized per cache instance). An entry of a *different* kind,
        dtype or batch bucket is never returned — kind-crossing was the v1
        bug, dtype-crossing the v2 one, batch-crossing the v3 one (a B=1
        winner knows nothing about dispatch amortization at B=1024)."""
        with self._lock:
            hit = self._load().get(_key(kind, dtype, batch), {}).get(
                shape_bucket(m, k, f))
            if hit is not None:
                variant, bm, bk, bf = hit
                return variant, KernelParams(bm, bk, bf)
            key = (m, k, f, kind, _dtype_name(dtype), batch_bucket(batch))
            if key not in self._computed:
                import jax.numpy as jnp
                from repro.core.autotune import select_params
                self._computed[key] = select_params(
                    m, k, f, mode="model", kind=kind,
                    dtype=jnp.dtype(_dtype_name(dtype)), batch=batch)
            return self._computed[key]

    # -- serving ladder (schema v7 pseudo-entries) -------------------------

    @staticmethod
    def _ladder_bucket(k: int, f: int) -> str:
        """Ladder entries are per model shape (K, F) — the row count is
        the thing being bucketed, so it cannot be part of the key. The
        ``ladder:`` prefix keeps these out of the shape-bucket namespace."""
        b = lambda v: int(math.log2(max(v, 1)))
        return f"ladder:{b(k)}-{b(f)}"

    def put_ladder(self, k: int, f: int, *, buckets: Iterable[int],
                   window_us: float, dtype: Any = None) -> None:
        """Record a tuned serving plan — the row-count bucket ladder and
        micro-batching window (µs) — for a model shape (see
        ``repro.serve.tuning.plan_ladder``)."""
        entry = [float(window_us),  # analysis: allow=host-sync — host config
                 *(int(b) for b in buckets)]  # analysis: allow=host-sync
        with self._lock:
            self._load().setdefault(_key("serve", dtype), {})[
                self._ladder_bucket(k, f)] = entry

    def lookup_ladder(self, k: int, f: int, *, dtype: Any = None,
                      ) -> Optional[tuple[tuple[int, ...], float]]:
        """Persisted ``(buckets, window_us)`` serving plan for the model
        shape, or None — unlike ``lookup`` there is no computed fallback
        here (planning a ladder walks the whole candidate family, so the
        serve layer decides when to pay that; see ``tuning.plan_ladder``)."""
        with self._lock:
            hit = self._load().get(_key("serve", dtype), {}).get(
                self._ladder_bucket(k, f))
            if hit is None:
                return None
            window_us, *buckets = hit   # JSON floats/ints: host data
            return (tuple(int(b) for b in buckets),  # analysis: allow=host-sync
                    float(window_us))  # analysis: allow=host-sync

    def build(self, shapes: Iterable[tuple[int, int, int]], *,
              mode: str = "model", dtype: Any = None,
              kinds: Iterable[str] = ("assign",),
              batch: int = 1) -> dict[str, dict[str, list]]:
        """Run the selection pipeline over ``shapes`` for each kernel kind,
        record the winners, and persist if file-backed. Returns the
        "kind/dtype/bN" -> bucket -> [variant, blocks...] table."""
        import jax.numpy as jnp
        from repro.core.autotune import select_params
        jdtype = jnp.dtype(_dtype_name(dtype))
        with self._lock:
            for kind in kinds:
                for (m, k, f) in shapes:
                    variant, p = select_params(m, k, f, mode=mode,
                                               dtype=jdtype, kind=kind,
                                               batch=batch)
                    self.put(m, k, f, p, kind=kind, dtype=dtype,
                             variant=variant, batch=batch)
            if self.path:
                self.save()
            return {k: dict(v) for k, v in self._load().items()}


_default_cache: Optional[AutotuneCache] = None
_default_lock = threading.Lock()


def default_cache() -> AutotuneCache:
    """Shared fallback for call sites with no estimator in scope
    (e.g. ``ops.fused_assign(x, c)`` with no explicit params)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = AutotuneCache.default()
        return _default_cache
