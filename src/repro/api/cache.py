"""Injectable kernel-selection cache (paper §III-B selection pipeline).

The paper benchmarks ~150 generated kernels over 64 problem sizes and
persists the per-shape winners; the runtime consults that table. The legacy
implementation hid the table behind a module global plus an env var —
untestable and shared across every estimator in the process.

:class:`AutotuneCache` is that table as an object: it owns load/save/lookup
and is passed per-estimator (``KMeans(..., autotune=cache)``), so two
estimators can run with different tables in one process and tests get a
fresh cache per case.

Schema v2: entries are keyed by *kernel kind* as well as shape bucket. The
assignment-only kernel and the one-pass Lloyd kernel share a tile-parameter
type but have different VMEM footprints and traffic profiles, so a winner
tuned for one must never be handed to the other (the v1 table, keyed only
by shape, did exactly that). v1 files still load: their flat entries are
interpreted as ``assign``-kind winners; other kinds fall through to the
analytical selector.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Iterable, Optional

from repro.kernels.ops import KernelParams

_DEFAULT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "core", "autotune_table.json")
_PATH_ENV = "REPRO_AUTOTUNE_TABLE"   # still honoured, but only here

SCHEMA_VERSION = 2


def shape_bucket(m: int, k: int, f: int) -> str:
    """log2 bucket per dimension — the paper's 64-discrete-sizes granularity:
    shapes in a bucket share a winner."""
    b = lambda v: int(math.log2(max(v, 1)))
    return f"{b(m)}-{b(k)}-{b(f)}"


class AutotuneCache:
    """Kind- and shape-bucketed winner table with lazy file backing.

    path=None keeps the cache purely in-memory; a string path loads the
    JSON table on first lookup and ``save()`` writes winners back.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._table: Optional[dict[str, dict[str, list[int]]]] = None
        self._computed: dict[tuple, KernelParams] = {}
        self._lock = threading.RLock()   # build() holds it across put/save

    @classmethod
    def default(cls) -> "AutotuneCache":
        """Process-default cache: $REPRO_AUTOTUNE_TABLE or the packaged
        table location. Estimators that want isolation pass their own."""
        return cls(os.environ.get(_PATH_ENV, _DEFAULT_PATH))

    # -- table I/O ---------------------------------------------------------

    def _load(self) -> dict:
        if self._table is None:
            kinds: dict[str, dict[str, list[int]]] = {}
            if self.path and os.path.exists(self.path):
                with open(self.path) as fh:
                    raw = json.load(fh)
                if isinstance(raw, dict) and raw.get("schema", 1) >= 2:
                    kinds = {k: dict(v) for k, v in raw["kinds"].items()}
                else:
                    # legacy v1 flat {bucket: blocks}: those winners were
                    # tuned for the assignment-only kernel
                    kinds = {"assign": dict(raw)}
            self._table = kinds
        return self._table

    def save(self, path: Optional[str] = None) -> str:
        """Persist the current table (schema v2, sorted, stable) and return
        the path. Legacy v1 tables are upgraded on save."""
        path = path or self.path
        if not path:
            raise ValueError("AutotuneCache has no backing path to save to")
        with self._lock:
            kinds = self._load()   # before open(..., "w") truncates the file
            with open(path, "w") as fh:
                json.dump({"schema": SCHEMA_VERSION, "kinds": kinds},
                          fh, indent=1, sort_keys=True)
        return path

    # -- lookup / update ---------------------------------------------------

    def put(self, m: int, k: int, f: int, params: KernelParams, *,
            kind: str = "assign") -> None:
        with self._lock:
            self._load().setdefault(kind, {})[shape_bucket(m, k, f)] = [
                params.block_m, params.block_k, params.block_f]

    def lookup(self, m: int, k: int, f: int, *,
               kind: str = "assign") -> KernelParams:
        """Persisted winner for (kind, shape bucket), else the analytical
        winner for that kind computed on the fly (memoized per cache
        instance). An entry of a *different* kind is never returned —
        that's the v1 bug this schema fixes."""
        with self._lock:
            hit = self._load().get(kind, {}).get(shape_bucket(m, k, f))
            if hit is not None:
                bm, bk, bf = hit
                return KernelParams(bm, bk, bf)
            key = (m, k, f, kind)
            if key not in self._computed:
                from repro.core.autotune import select_params
                self._computed[key] = select_params(m, k, f, mode="model",
                                                    kind=kind)
            return self._computed[key]

    def build(self, shapes: Iterable[tuple[int, int, int]], *,
              mode: str = "model", dtype=None,
              kinds: Iterable[str] = ("assign",)) -> dict:
        """Run the selection pipeline over ``shapes`` for each kernel kind,
        record the winners, and persist if file-backed. Returns the
        kind -> bucket -> blocks table."""
        import jax.numpy as jnp
        from repro.core.autotune import select_params
        dtype = dtype if dtype is not None else jnp.float32
        with self._lock:
            for kind in kinds:
                for (m, k, f) in shapes:
                    self.put(m, k, f,
                             select_params(m, k, f, mode=mode, dtype=dtype,
                                           kind=kind),
                             kind=kind)
            if self.path:
                self.save()
            return {k: dict(v) for k, v in self._load().items()}


_default_cache: Optional[AutotuneCache] = None
_default_lock = threading.Lock()


def default_cache() -> AutotuneCache:
    """Shared fallback for call sites with no estimator in scope
    (e.g. ``ops.fused_assign(x, c)`` with no explicit params)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = AutotuneCache.default()
        return _default_cache
