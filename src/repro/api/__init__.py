"""``repro.api`` — the stable estimator surface of FT K-means.

Everything downstream (examples, benchmarks, streaming/sharding subsystems)
builds on four objects:

  * :class:`KMeans`          — cuML/sklearn-shaped estimator
                               (fit / fit_predict / predict / partial_fit /
                               transform / score, get_state / from_state);
  * :class:`FaultPolicy`     — typed protection policy (off|detect|correct,
                               DMR on the update step, injection campaigns);
  * the backend registry     — :func:`get_backend` / :func:`list_backends` /
                               :func:`register_backend` over uniform
                               :class:`AssignmentBackend` objects;
  * :class:`AutotuneCache`   — injectable kernel-selection table
                               (paper §III-B), passed per-estimator.
"""
from repro.api.cache import AutotuneCache, default_cache, shape_bucket
from repro.api.estimator import KMeans, NotFittedError
from repro.api.policy import FaultPolicy, InjectionCampaign
from repro.api.registry import (AssignmentBackend, BackendCapabilityError,
                                get_backend, list_backends, register_backend)

__all__ = [
    "KMeans", "NotFittedError",
    "FaultPolicy", "InjectionCampaign",
    "AssignmentBackend", "BackendCapabilityError",
    "get_backend", "list_backends", "register_backend",
    "AutotuneCache", "default_cache", "shape_bucket",
]
