"""``repro.api`` — the stable estimator surface of FT K-means.

Everything downstream (examples, benchmarks, streaming/sharding subsystems)
builds on four objects:

  * :class:`KMeans`          — cuML/sklearn-shaped estimator
                               (fit / fit_predict / predict / partial_fit /
                               transform / score, get_state / from_state);
  * :class:`FaultPolicy`     — typed protection policy (off|detect|correct,
                               DMR on the update step, injection campaigns);
  * the backend registry     — :func:`get_backend` / :func:`list_backends` /
                               :func:`register_backend` over uniform
                               :class:`AssignmentBackend` objects;
  * :class:`AutotuneCache`   — injectable kernel-selection table
                               (paper §III-B), passed per-estimator;
  * :class:`BatchedKMeans`   — the many-problem estimator (B stacked
                               independent problems through the batched
                               one-pass kernel; lives in ``repro.batch``).
"""
from repro.api.cache import (AutotuneCache, batch_bucket, default_cache,
                             shape_bucket)
from repro.api.estimator import KMeans, NotFittedError
from repro.api.policy import FaultPolicy, InjectionCampaign
from repro.api.registry import (AssignmentBackend, BackendCapabilityError,
                                get_backend, list_backends, register_backend)

__all__ = [
    "KMeans", "BatchedKMeans", "NotFittedError",
    "FaultPolicy", "InjectionCampaign",
    "AssignmentBackend", "BackendCapabilityError",
    "get_backend", "list_backends", "register_backend",
    "AutotuneCache", "default_cache", "shape_bucket", "batch_bucket",
]


def __getattr__(name: str) -> object:
    # Lazy re-export (PEP 562): repro.batch.estimator imports repro.api.cache,
    # so an eager import here would make a fresh ``import repro.batch`` fail
    # on the circular re-entry into this partially initialized package.
    if name == "BatchedKMeans":
        from repro.batch.estimator import BatchedKMeans
        return BatchedKMeans
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
