"""Typed fault-tolerance policy — one object instead of three knobs.

The legacy front end scattered protection across an ``assignment`` magic
string, a ``dmr_update`` bool and a ``FaultConfig`` smuggled into ``fit()``.
:class:`FaultPolicy` replaces the triple:

  * ``mode`` picks the protection level of the *assignment* step
    (compute-bound, ABFT per paper §IV):
      - ``"off"``     no checksums — the paper's "FT K-means without fault
                      tolerance outperforms cuML" configuration;
      - ``"detect"``  checksummed GEMM with offline verification on the
                      materialized product (Wu-et-al-style baseline);
      - ``"correct"`` the paper's fully-fused online ABFT
                      detect -> locate -> correct kernel.
  * ``update_dmr`` protects the *centroid update* step (memory-bound,
    DMR per §IV intro; <1 % overhead). Independent of ``mode``:
    ``FaultPolicy(mode="off", update_dmr=True)`` expresses DMR-only
    protection (unchecksummed assignment, duplicated update arithmetic).
  * ``injection`` optionally attaches an SEU injection campaign — the
    evaluation harness of §V-C — which requires a backend that takes
    in-kernel injection descriptors.

Policy resolution (:meth:`FaultPolicy.resolve_backend`) picks the kernel;
callers never name kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.api.registry import (AssignmentBackend, BackendCapabilityError,
                                get_backend)

MODES = ("off", "detect", "correct")


@dataclasses.dataclass(frozen=True)
class InjectionCampaign:
    """SEU injection campaign parameters (paper §II-A fault model).

    rate:     expected injections per Lloyd step (Bernoulli when <= 1).
    bit_low/bit_high: inclusive bit-position range of the flip; the default
              range exercises high-mantissa + exponent bits (detectable).
    seed:     host-side RNG seed for the campaign schedule.
    """

    rate: float = 1.0
    bit_low: int = 20
    bit_high: int = 30
    seed: int = 0

    def enabled(self) -> bool:
        return self.rate > 0

    def to_fault_config(self):
        """The low-level descriptor used by ft_gemm/checksum internals."""
        from repro.core.fault import FaultConfig
        return FaultConfig(rate=self.rate, bit_low=self.bit_low,
                           bit_high=self.bit_high, seed=self.seed)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Composable protection policy for one estimator."""

    mode: str = "off"                 # "off" | "detect" | "correct"
    update_dmr: bool = True           # DMR on the centroid-update step
    injection: Optional[InjectionCampaign] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"FaultPolicy.mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.injection is not None and self.mode == "off":
            raise ValueError(
                "an injection campaign needs a protected assignment backend; "
                "use mode='correct' (or 'detect') with injection=...")

    # -- constructors ------------------------------------------------------

    @classmethod
    def off(cls) -> "FaultPolicy":
        """No protection anywhere (performance baseline)."""
        return cls(mode="off", update_dmr=False)

    @classmethod
    def detect(cls, *, update_dmr: bool = True,
               injection: Optional[InjectionCampaign] = None) -> "FaultPolicy":
        return cls(mode="detect", update_dmr=update_dmr, injection=injection)

    @classmethod
    def correct(cls, *, update_dmr: bool = True,
                injection: Optional[InjectionCampaign] = None) -> "FaultPolicy":
        return cls(mode="correct", update_dmr=update_dmr, injection=injection)

    # -- resolution --------------------------------------------------------

    @property
    def protected(self) -> bool:
        return self.mode != "off"

    def resolve_backend(self, name: Optional[str] = None,
                        *, on_tpu: Optional[bool] = None) -> AssignmentBackend:
        """Pick the assignment kernel for this policy.

        ``name`` pins an explicit backend (validated against the policy);
        otherwise the policy selects: fused Pallas (TPU) / XLA-fused (host)
        when unprotected, the offline-ABFT baseline for ``detect``, and the
        fused online-ABFT kernel for ``correct``.
        """
        if on_tpu is None:
            from repro.kernels.ops import on_tpu as _on_tpu
            on_tpu = _on_tpu()
        if name is None:
            if self.injection is not None:
                # campaigns need in-kernel injection; only the fused FT
                # kernel provides it, so it hosts detect-mode campaigns too
                name = "fused_ft"
            elif self.mode == "off":
                name = "fused" if on_tpu else "gemm_fused"
            elif self.mode == "detect":
                name = "abft_offline"
            else:
                name = "fused_ft"
        backend = get_backend(name)
        if self.protected and not backend.supports_ft:
            raise BackendCapabilityError(
                f"FaultPolicy(mode={self.mode!r}) needs a fault-tolerant "
                f"assignment backend, but {backend.name!r} declares "
                f"supports_ft=False")
        if self.injection is not None and not backend.takes_injection:
            raise BackendCapabilityError(
                f"injection campaign requires takes_injection=True, but "
                f"backend {backend.name!r} cannot inject in-kernel; "
                f"use backend='fused_ft'")
        return backend
