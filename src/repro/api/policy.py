"""Typed fault-tolerance policy — one object instead of three knobs.

The legacy front end scattered protection across an ``assignment`` magic
string, a ``dmr_update`` bool and a ``FaultConfig`` smuggled into ``fit()``.
:class:`FaultPolicy` replaces the triple:

  * ``mode`` picks the protection level of the *assignment* step
    (compute-bound, ABFT per paper §IV):
      - ``"off"``     no checksums — the paper's "FT K-means without fault
                      tolerance outperforms cuML" configuration;
      - ``"detect"``  checksummed GEMM with offline verification on the
                      materialized product (Wu-et-al-style baseline);
      - ``"correct"`` the paper's fully-fused online ABFT
                      detect -> locate -> correct kernel — resolved to the
                      *one-pass* FT kernel (``lloyd_ft``), whose epilogue
                      checksums also protect the fused centroid update.
  * ``update_dmr`` protects the *centroid update* step of **two-pass**
    backends (memory-bound, DMR per §IV intro; <1 % overhead). Independent
    of ``mode``: ``FaultPolicy(mode="off", update_dmr=True)`` expresses
    DMR-only protection (unchecksummed assignment, duplicated update
    arithmetic). The default ``None`` is *auto* — DMR for two-pass
    backends, nothing extra for one-pass (``fuses_update``) backends,
    whose update runs in the kernel epilogue where the ``lloyd_ft``
    checksum scheme subsumes DMR. An explicit ``True`` on a one-pass
    backend is ignored with a deprecation note.
  * ``injection`` optionally attaches an SEU injection campaign — the
    evaluation harness of §V-C — which requires a backend that takes
    in-kernel injection descriptors.

Policy resolution (:meth:`FaultPolicy.resolve_backend`) picks the kernel;
callers never name kernels.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

from repro.api.registry import (AssignmentBackend, BackendCapabilityError,
                                get_backend)

if TYPE_CHECKING:
    from repro.core.fault import FaultConfig

MODES = ("off", "detect", "correct")


TARGETS = ("auto", "distance", "update", "both")

WORKER_LOSS = ("fail", "shrink")


@dataclasses.dataclass(frozen=True)
class InjectionCampaign:
    """SEU injection campaign parameters (paper §II-A fault model).

    Attach to a :class:`FaultPolicy` to exercise the protected kernels
    with in-kernel single-event upsets (the evaluation harness of §V-C).

    Parameters
    ----------
    rate : float, default=1.0
        Expected injections per Lloyd step.

        * ``rate <= 1`` — one Bernoulli(rate) draw per step.
        * ``rate > 1`` — an expected *count* per step: ``floor(rate)``
          guaranteed draws plus a Bernoulli on the fractional part,
          assigned to *distinct* verification intervals of the step — the
          distance GEMM and (on one-pass FT backends) the update
          epilogue. The §II-A single-event-upset model allows at most one
          error per detection/correction interval, so the per-step count
          clips at the backend's interval count
          (``AssignmentBackend.protected_intervals``: 2 for ``lloyd_ft``,
          1 for assignment-only FT kernels).
    bit_low, bit_high : int, default=(20, 30)
        Inclusive bit-position range of the flip; the default range
        exercises high-mantissa + exponent bits (detectable — flips below
        the detection threshold are the rounding floor, not SDCs).
    seed : int, default=0
        Host-side RNG seed for the campaign schedule (mixed with the
        estimator's ``random_state``, stream-tagged so it stays disjoint
        from data sampling even at seed 0).
    targets : {"auto", "distance", "update", "both"}, default="auto"
        Which verification intervals the campaign may corrupt.

        * ``"distance"`` — the distance-GEMM interval only.
        * ``"update"`` — the fused update epilogue only; requires a
          one-pass FT backend (the update of a *two-pass* pipeline is
          DMR's job, not the campaign's).
        * ``"both"`` — one optional SEU per interval per step (same
          one-pass FT requirement).
        * ``"auto"`` — every interval the resolved backend protects
          (both on ``lloyd_ft``, distance-only on ``fused_ft``).

    Raises
    ------
    ValueError
        On a negative ``rate`` or an unknown ``targets`` value, at
        construction; target/backend mismatches surface as
        :class:`BackendCapabilityError` at policy resolution.
    """

    rate: float = 1.0
    bit_low: int = 20
    bit_high: int = 30
    seed: int = 0
    targets: str = "auto"

    def __post_init__(self) -> None:
        if self.rate < 0:
            raise ValueError(f"InjectionCampaign.rate must be >= 0, "
                             f"got {self.rate}")
        if self.targets not in TARGETS:
            raise ValueError(f"InjectionCampaign.targets must be one of "
                             f"{TARGETS}, got {self.targets!r}")

    def enabled(self) -> bool:
        return self.rate > 0

    def resolved_targets(self, backend: AssignmentBackend) -> tuple[str, ...]:
        """The concrete interval list for a resolved backend."""
        wants_update = self.targets in ("update", "both")
        one_pass_ft = backend.fuses_update and backend.takes_injection
        if wants_update and not one_pass_ft:
            why = (f"backend {backend.name!r} has no in-kernel injection "
                   f"surface (takes_injection=False)"
                   if backend.fuses_update else
                   f"backend {backend.name!r} is two-pass")
            raise BackendCapabilityError(
                f"injection targets={self.targets!r} corrupts the update "
                f"epilogue, which only a one-pass FT backend with in-kernel "
                f"injection protects; {why} — use backend='lloyd_ft' or "
                f"targets='distance'")
        if self.targets == "distance":
            return ("distance",)
        if self.targets == "update":
            return ("update",)
        return ("distance", "update") if one_pass_ft else ("distance",)

    def to_fault_config(self) -> "FaultConfig":
        """The low-level descriptor used by ft_gemm/checksum internals."""
        from repro.core.fault import FaultConfig
        return FaultConfig(rate=self.rate, bit_low=self.bit_low,
                           bit_high=self.bit_high, seed=self.seed)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Composable protection policy for one estimator.

    One object instead of three knobs: the protection level of the
    assignment step, DMR on the (two-pass) update step, and an optional
    injection campaign. :meth:`resolve_backend` picks the kernel — callers
    never name kernels.

    Parameters
    ----------
    mode : {"off", "detect", "correct"}, default="off"
        Protection level of the *assignment* step (compute-bound, ABFT
        per paper §IV): ``"off"`` = no checksums (performance baseline);
        ``"detect"`` = checksummed GEMM with offline verification
        (Wu-et-al-style baseline); ``"correct"`` = the fully-fused online
        ABFT detect → locate → correct kernel, resolved to the *one-pass*
        FT kernel whose epilogue checksums also protect the fused update.
    update_dmr : bool, optional
        DMR on the *centroid update* of **two-pass** backends
        (memory-bound, <1% overhead). ``None`` (default) is *auto*: DMR
        on for two-pass backends, naturally absent on one-pass
        (``fuses_update``) backends whose update runs in the kernel
        epilogue (checksummed there under ``mode="correct"``). Explicit
        ``True`` on a one-pass backend draws a deprecation note; explicit
        ``False`` disables DMR everywhere.
    injection : InjectionCampaign, optional
        SEU campaign (§V-C); requires a backend with in-kernel injection
        support and a protected ``mode``.
    worker_loss : {"fail", "shrink"}, default="fail"
        Response to a whole-worker (fail-stop) loss mid-fit — the fault
        class the paper's SEU model doesn't cover. ``"fail"`` propagates
        :class:`~repro.ft.elastic.WorkerLossError` to the caller;
        ``"shrink"`` lets ``DistributedKMeans.fit_elastic`` rescale the
        mesh (``ft.elastic.plan_rescale_rows``), restore the last
        ``Checkpointer`` snapshot, and resume. One policy object now
        spans both fault classes: SEU -> ABFT correct in-kernel, worker
        loss -> shrink + restart.

    Examples
    --------
    >>> from repro.api import FaultPolicy, InjectionCampaign
    >>> FaultPolicy.correct().protected
    True
    >>> FaultPolicy.correct(
    ...     injection=InjectionCampaign(rate=1.5, targets="both")).mode
    'correct'
    >>> FaultPolicy.elastic().worker_loss
    'shrink'
    """

    mode: str = "off"                 # "off" | "detect" | "correct"
    update_dmr: Optional[bool] = None  # DMR on the two-pass update (auto)
    injection: Optional[InjectionCampaign] = None
    worker_loss: str = "fail"          # "fail" | "shrink"

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"FaultPolicy.mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.worker_loss not in WORKER_LOSS:
            raise ValueError(f"FaultPolicy.worker_loss must be one of "
                             f"{WORKER_LOSS}, got {self.worker_loss!r}")
        if self.injection is not None and self.mode == "off":
            raise ValueError(
                "an injection campaign needs a protected assignment backend; "
                "use mode='correct' (or 'detect') with injection=...")

    # -- constructors ------------------------------------------------------

    @classmethod
    def off(cls) -> "FaultPolicy":
        """No protection anywhere (performance baseline)."""
        return cls(mode="off", update_dmr=False)

    @classmethod
    def detect(cls, *, update_dmr: Optional[bool] = None,
               injection: Optional[InjectionCampaign] = None) -> "FaultPolicy":
        return cls(mode="detect", update_dmr=update_dmr, injection=injection)

    @classmethod
    def correct(cls, *, update_dmr: Optional[bool] = None,
                injection: Optional[InjectionCampaign] = None) -> "FaultPolicy":
        return cls(mode="correct", update_dmr=update_dmr, injection=injection)

    @classmethod
    def elastic(cls, *, mode: str = "correct",
                update_dmr: Optional[bool] = None,
                injection: Optional[InjectionCampaign] = None
                ) -> "FaultPolicy":
        """The full production ladder: SEUs corrected in-kernel (ABFT,
        ``mode="correct"`` by default), whole-worker losses survived by
        mesh shrink + checkpoint restore (``worker_loss="shrink"``)."""
        return cls(mode=mode, update_dmr=update_dmr, injection=injection,
                   worker_loss="shrink")

    # -- resolution --------------------------------------------------------

    @property
    def protected(self) -> bool:
        return self.mode != "off"

    def dmr_enabled(self, backend: AssignmentBackend) -> bool:
        """Effective DMR setting for a resolved backend: never on fused
        (one-pass) backends — their update runs in the kernel epilogue —
        and on by default (auto) for two-pass backends."""
        if backend.fuses_update:
            return False
        return True if self.update_dmr is None else self.update_dmr

    def resolve_backend(self, name: Optional[str] = None,
                        *, on_tpu: Optional[bool] = None) -> AssignmentBackend:
        """Pick the assignment kernel for this policy.

        ``name`` pins an explicit backend (validated against the policy);
        otherwise the policy selects: one-pass Pallas (TPU) / XLA-fused
        (host) when unprotected, the offline-ABFT baseline for ``detect``,
        and the *one-pass* online-ABFT kernel for ``correct`` — the paper's
        Fig. 6 scheme composed with the fused-update iteration, so enabling
        fault tolerance no longer forfeits the one-pass speedup (campaigns
        always take the Pallas kernel: in-kernel injection is its surface).
        """
        if on_tpu is None:
            from repro.kernels.ops import on_tpu as _on_tpu
            on_tpu = _on_tpu()
        if name is None:
            if self.injection is not None:
                # campaigns need in-kernel injection; the one-pass FT
                # kernel provides it for both of a step's verification
                # intervals, so it hosts detect-mode campaigns too
                name = "lloyd_ft"
            elif self.mode == "off":
                name = "fused" if on_tpu else "gemm_fused"
            elif self.mode == "detect":
                name = "abft_offline"
            else:
                name = "lloyd_ft" if on_tpu else "lloyd_ft_xla"
        backend = get_backend(name)
        if backend.supports_batch:
            raise BackendCapabilityError(
                f"backend {backend.name!r} is a batched (supports_batch) "
                f"backend with a stacked (B, N, F) contract; KMeans drives "
                f"single (M, F) problems — use repro.batch.BatchedKMeans "
                f"for problem stacks")
        if self.protected and not backend.supports_ft:
            raise BackendCapabilityError(
                f"FaultPolicy(mode={self.mode!r}) needs a fault-tolerant "
                f"assignment backend, but {backend.name!r} declares "
                f"supports_ft=False")
        if self.injection is not None:
            if not backend.takes_injection:
                raise BackendCapabilityError(
                    f"injection campaign requires takes_injection=True, but "
                    f"backend {backend.name!r} cannot inject in-kernel; "
                    f"use backend='lloyd_ft' (or 'fused_ft')")
            self.injection.resolved_targets(backend)   # target validation
        return backend
