"""cuML/sklearn-compatible K-means estimator over the FT kernel stack.

One front end for every scenario in the paper and the roadmap:

    km = KMeans(n_clusters=8, fault=FaultPolicy.correct())
    labels = km.fit_predict(x)            # full-batch Lloyd
    km.partial_fit(block)                 # streaming / mini-batch path
    state = km.get_state()                # serializable fitted state
    km2 = KMeans.from_state(state)        # restore (checkpoint/restart)

Protection is a :class:`~repro.api.policy.FaultPolicy` — policy resolution
picks the assignment kernel from the backend registry; kernel-tile selection
comes from an injectable :class:`~repro.api.cache.AutotuneCache`. The
estimator never branches on backend names.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.cache import AutotuneCache, default_cache
from repro.api.policy import FaultPolicy, InjectionCampaign
from repro.api.registry import AssignmentBackend
from repro.kernels import ops, ref

_INITS = ("kmeans++", "random")
_COMPUTE_DTYPES = ("float32", "bfloat16", "float16", "int8")

# Row-chunk size for one-shot inference (predict/transform/score): bounds
# the padded working set on large inputs instead of materializing a full
# padded copy of X. Overridable per estimator via ``predict_chunk_rows``.
_PREDICT_CHUNK_ROWS = 65_536


class NotFittedError(RuntimeError):
    pass


def _host_read(value: Any) -> Any:
    """The single device->host funnel of the fit loop.

    Every synchronization the full-batch fit performs goes through here —
    once per ``sync_every``-iteration chunk plus once for the final
    counters — so tests can count host transfers by patching one name."""
    return jax.device_get(value)


class KMeans:
    """K-means estimator with composable fault tolerance.

    The sklearn/cuML-shaped front end over the FT kernel stack: protection
    is a :class:`FaultPolicy` (resolved to an assignment backend through
    the registry), kernel tiles come from an injectable
    :class:`AutotuneCache`, and the full-batch Lloyd loop runs
    device-resident (a chunked ``lax.scan`` with the convergence test on
    device).

    Parameters
    ----------
    n_clusters : int, default=8
        Number of clusters K.
    max_iter : int, default=100
        Lloyd iteration budget.
    tol : float, default=1e-4
        Centroid-shift convergence threshold: the fit stops once
        ``||C' - C||_F < tol`` (tested on device).
    init : {"kmeans++", "random"}, default="kmeans++"
        Seeding strategy (D² sampling or uniform rows).
    fault : FaultPolicy, optional
        Protection policy — off / detect / correct, plus an optional SEU
        :class:`InjectionCampaign`. Default: no protection
        (``FaultPolicy.off()``).
    backend : str, optional
        Pin a registered assignment backend by name; default lets the
        policy resolve one (paper §III-B selection). The policy validates
        a pinned backend's capabilities.
    batch_size : int, optional
        When set, ``fit`` runs sampled mini-batches of this many rows per
        iteration; ``partial_fit`` streams caller-provided batches either
        way.
    params : KernelParams, optional
        Explicit tile override for Pallas backends (skips the autotune
        lookup).
    autotune : AutotuneCache, optional
        Injectable kernel-selection table; default = the process cache
        (``default_cache()``).
    sync_every : int, default=10
        Full-batch ``fit`` runs the Lloyd loop device-resident in chunks
        of this many iterations; the host observes progress — and replays
        ``on_iteration`` — only at chunk boundaries.
    compute_dtype : {"float32", "bfloat16", "float16", "int8"}, \
            default="float32"
        Kernel compute dtype. For the float dtypes, X and the centroids
        are cast at the kernel boundary (paper §III-B's dtype-templated
        kernels); accumulators, distances, counts and the stored
        ``cluster_centers_`` stay f32. ``"int8"`` selects the quantized
        distance template instead: X is per-row symmetrically quantized
        once per fit (centroids per iteration, since they move), the
        distance GEMM runs on int8 operands, and the scale correction,
        norms, argmin and the centroid update all stay f32 — so no data
        is ever ``astype``'d to int8. int8 needs an unprotected policy
        (``FaultPolicy.off()``): the quantized template has no FT
        variant.
    predict_chunk_rows : int, optional
        Row-chunk size for one-shot inference (predict/transform/score);
        ``None`` = module default (65 536). Bounds the padded working set
        on large inputs.
    random_state : int, default=0
        Seed for init, mini-batch sampling, empty-cluster reseeding and
        (mixed with the campaign's own seed) injection schedules.

    Attributes
    ----------
    cluster_centers_ : jax.Array, shape (n_clusters, F), float32
        Fitted centroids (always f32, whatever ``compute_dtype``).
    labels_ : jax.Array, shape (M,), int32
        Assignment of each training sample at the final iteration.
    inertia_ : float
        Sum of squared distances at the final iteration.
    n_iter_ : int
        Iterations executed.
    detected_errors_ : int
        SDCs detected (and, under ``mode="correct"``, corrected) across
        the fit — nonzero only with a fault-tolerant backend.
    prune_history_ : list of float
        Per-iteration fraction of (row tile, centroid tile) cells skipped
        by the triangle-inequality filter — populated only by full-batch
        fits on a bounds-carrying backend (``supports_bounds``), empty
        otherwise. Iteration zero is always 0.0 (the seed pass computes
        every tile).

    See Also
    --------
    FaultPolicy : protection policy and backend resolution.
    InjectionCampaign : SEU campaign semantics (``rate`` / ``targets``).
    repro.batch.BatchedKMeans : many-problem batched variant.

    Examples
    --------
    >>> from repro.api import KMeans, FaultPolicy
    >>> km = KMeans(n_clusters=4, fault=FaultPolicy.correct())
    >>> km.fault.mode
    'correct'
    """

    def __init__(self, n_clusters: int = 8, *, max_iter: int = 100,
                 tol: float = 1e-4, init: str = "kmeans++",
                 fault: Optional[FaultPolicy] = None,
                 backend: Optional[str] = None,
                 batch_size: Optional[int] = None,
                 params: Optional[ops.KernelParams] = None,
                 autotune: Optional[AutotuneCache] = None,
                 sync_every: int = 10,
                 compute_dtype: Any = "float32",
                 predict_chunk_rows: Optional[int] = None,
                 random_state: int = 0) -> None:
        if n_clusters < 1:
            raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
        if init not in _INITS:
            raise ValueError(f"init must be one of {_INITS}, got {init!r}")
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        try:
            dtype_ok = jnp.dtype(compute_dtype).name in _COMPUTE_DTYPES
        except TypeError:                  # unparseable spec, e.g. "bf16"
            dtype_ok = False
        if not dtype_ok:
            raise ValueError(
                f"compute_dtype must be one of {_COMPUTE_DTYPES}, "
                f"got {compute_dtype!r}")
        if predict_chunk_rows is not None and predict_chunk_rows < 1:
            raise ValueError(f"predict_chunk_rows must be >= 1, "
                             f"got {predict_chunk_rows}")
        self.n_clusters = n_clusters
        self.max_iter = max_iter
        self.tol = tol
        self.init = init
        self.fault = fault if fault is not None else FaultPolicy.off()
        self.backend = backend
        self.batch_size = batch_size
        self.params = params
        self.autotune = autotune if autotune is not None else default_cache()
        self.sync_every = sync_every
        self.compute_dtype = jnp.dtype(compute_dtype)
        self.predict_chunk_rows = predict_chunk_rows
        self.random_state = random_state

        is_int8 = self.compute_dtype == jnp.int8
        if is_int8 and backend is None:
            # the quantized template is assignment-only: Pallas kernel on
            # TPU, its bit-compatible XLA analogue elsewhere. The policy
            # still validates the pick (int8 has no FT variant, so a
            # protected policy is rejected there).
            backend = "int8" if ops.on_tpu() else "int8_xla"
        self._backend: AssignmentBackend = self.fault.resolve_backend(backend)
        if is_int8 != self._backend.supports_int8:
            raise ValueError(
                f"backend {self._backend.name!r} "
                + ("does not consume int8-quantized operands; pick a "
                   "supports_int8 backend or drop compute_dtype='int8'"
                   if is_int8 else
                   "is an int8 template and needs compute_dtype='int8'"))
        self._use_dmr = self.fault.dmr_enabled(self._backend)
        if self.fault.update_dmr and self._backend.fuses_update:
            # DMR was the two-pass pipeline's update protection; one-pass
            # backends compute the update in the kernel epilogue, where the
            # lloyd_ft checksum scheme subsumes it (and the plain lloyd
            # kernel offers no host-side hook to duplicate). An *explicit*
            # True is ignored with a note (the default None is auto and
            # stays silent) — one policy serves both pipeline shapes.
            import warnings
            warnings.warn(
                f"FaultPolicy.update_dmr is a two-pass-backend knob; "
                f"backend {self._backend.name!r} fuses the centroid update "
                f"into the kernel epilogue"
                + (", where its checksum protection subsumes DMR"
                   if self._backend.supports_ft else
                   " (unprotected; use FaultPolicy.correct() for the "
                   "checksummed one-pass kernel)")
                + "; the flag is ignored here",
                DeprecationWarning, stacklevel=2)
        self._step_cache: dict[tuple, Callable[..., Any]] = {}
        self._n_host_syncs: int = 0   # fit-loop host reads (observability)
        # streaming state (partial_fit)
        self._counts: Optional[jax.Array] = None

        self.cluster_centers_: Optional[jax.Array] = None
        self.labels_: Optional[jax.Array] = None
        self.inertia_: Optional[float] = None
        self.n_iter_: int = 0
        self.detected_errors_: int = 0
        self.prune_history_: list = []

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _check_fitted(self) -> None:
        if self.cluster_centers_ is None:
            raise NotFittedError(
                "this KMeans instance is not fitted yet; call fit() or "
                "partial_fit() first")

    def _cast(self, a: jax.Array) -> jax.Array:
        """Cast to the compute dtype at the kernel boundary (no-op f32).

        ``int8`` is quantization, not a cast: the backend quantizes per
        row itself (``astype(int8)`` would truncate the data), so the
        int8 kernel boundary keeps X and the centroids f32."""
        if self.compute_dtype == jnp.int8:
            return a if a.dtype == jnp.float32 else a.astype(jnp.float32)
        return a if a.dtype == self.compute_dtype else \
            a.astype(self.compute_dtype)

    def _resolve_params(self, m: int, f: int, *,
                        backend: Optional[AssignmentBackend] = None
                        ) -> Optional[ops.KernelParams]:
        """Tile selection for one problem shape: explicit override, else the
        injectable autotune cache (paper §III-B table lookup), keyed by
        kernel kind *and* compute dtype. One-pass backends consult the
        ``lloyd``-kind entries — an assignment-only winner must never be
        handed to the fused-update kernel — and a winner tuned for f32
        tiles is never handed to the bf16/fp16 templates."""
        backend = backend if backend is not None else self._backend
        if not backend.takes_params:
            return None
        if self.params is not None:
            p = self.params
        else:
            _, p = self.autotune.lookup(m, self.n_clusters, f,
                                        kind=backend.kernel_kind,
                                        dtype=self.compute_dtype)
        return ops.clamp_params(m, self.n_clusters, f, p,
                                dtype=self.compute_dtype)

    def _predict_backend(self) -> AssignmentBackend:
        """Prediction is assignment-only. A one-pass backend would compute
        the whole fused-update epilogue and throw it away (Pallas outputs
        are not dead-code-eliminated), so predict/score route through the
        assignment kernel at the *same protection level*: the one-pass FT
        backend predicts through the fused-ABFT assignment kernel, the
        plain one-pass backends through the unprotected one."""
        from repro.api.registry import get_backend
        b = self._backend
        if not b.fuses_update:
            return b
        if b.supports_ft:
            return get_backend("fused_ft" if b.takes_params
                               else "abft_offline")
        return get_backend("fused" if b.takes_params else "gemm_fused")

    def _assign_fn(self, params: Optional[ops.KernelParams]
                   ) -> Callable[..., Any]:
        """jit'd (x, c[, inj]) -> (assign, true sq-dist, detected)."""
        key = ("assign", params)
        if key not in self._step_cache:
            backend = self._predict_backend()
            cast = self._cast
            if backend.takes_injection:
                fn = jax.jit(lambda x, c, inj: backend(
                    cast(x), cast(c), params=params, inj=inj))
            else:
                fn = jax.jit(lambda x, c: backend(cast(x), cast(c),
                                                  params=params))
            self._step_cache[key] = fn
        return self._step_cache[key]

    def _apply_update(self, out: tuple, x: jax.Array,
                      centroids: jax.Array) -> tuple:
        """One centroid update from a backend result: one-pass backends
        already carry (sums, counts); two-pass backends pay the second
        pass over X (optionally DMR-protected)."""
        from repro.core.kmeans import centroid_update, means_from_sums
        if self._backend.fuses_update:
            # bounds-carrying backends extend the 5-tuple by
            # (new_bounds, prune_frac); the update only needs the head
            am, md, det, sums, counts = out[:5]
            new_c = means_from_sums(sums, counts, centroids)
        else:
            am, md, det = out
            new_c, counts = centroid_update(x, am, self.n_clusters, centroids,
                                            use_dmr=self._use_dmr)
        return am, md, det, new_c, counts

    def _lloyd_step_fn(self, params: Optional[ops.KernelParams]
                       ) -> Callable[..., Any]:
        """jit'd full Lloyd step: assignment + update (fused or two-pass)."""
        key = ("lloyd", params)
        if key not in self._step_cache:
            backend = self._backend

            def step(x: jax.Array, centroids: jax.Array,
                     inj: Any = None) -> tuple:
                x = self._cast(x)
                out = backend(x, self._cast(centroids), params=params,
                              inj=inj)
                am, md, det, new_c, counts = self._apply_update(
                    out, x, centroids)
                inertia = jnp.sum(md)
                shift = jnp.sqrt(jnp.sum((new_c - centroids) ** 2))
                return new_c, am, counts, md, inertia, shift, det

            static = () if backend.takes_injection else ("inj",)
            self._step_cache[key] = jax.jit(step, static_argnames=static)
        return self._step_cache[key]

    def _stream_step_fn(self, params: Optional[ops.KernelParams]
                        ) -> Callable[..., Any]:
        """jit'd streaming (mini-batch) step with per-center count decay —
        the partial_fit update rule (Sculley-style online k-means)."""
        from repro.core.kmeans import protected_sums
        key = ("stream", params)
        if key not in self._step_cache:
            backend, k = self._backend, self.n_clusters
            use_dmr = self._use_dmr
            fuses = backend.fuses_update

            def step(x: jax.Array, centroids: jax.Array,
                     counts: jax.Array, inj: Any = None) -> tuple:
                x = self._cast(x)
                out = backend(x, self._cast(centroids), params=params,
                              inj=inj)
                if fuses:   # block sums/counts come out of the kernel
                    # bounds backends run unpruned here (bounds=None per
                    # call — streaming blocks share no bounds lineage)
                    am, md, det, sums, bcnt = out[:5]
                else:
                    am, md, det = out
                    sums, bcnt = protected_sums(x, am, k, use_dmr=use_dmr)
                new_counts = counts + bcnt
                eta = (bcnt / jnp.maximum(new_counts, 1.0))[:, None]
                bmean = sums / jnp.maximum(bcnt, 1.0)[:, None]
                new_c = jnp.where((bcnt > 0)[:, None],
                                  (1.0 - eta) * centroids + eta * bmean,
                                  centroids)
                return new_c, new_counts, am, jnp.sum(md), det

            static = () if backend.takes_injection else ("inj",)
            self._step_cache[key] = jax.jit(step, static_argnames=static)
        return self._step_cache[key]

    def _chunk_fn(self, params: Optional[ops.KernelParams],
                  n_steps: int) -> Callable[..., Any]:
        """jit'd device-resident chunk of up to ``n_steps`` Lloyd iterations.

        The convergence test runs on device inside a ``lax.scan``: once the
        centroid shift drops below ``tol`` the remaining steps freeze into
        carry passthroughs (a ``lax.cond`` whose dead branch costs nothing),
        so a chunk never round-trips to the host mid-flight. The stacked
        per-iteration history (centroids, inertia, shift, active mask) lets
        the host replay ``on_iteration`` faithfully at the chunk boundary.
        """
        from repro.core.kmeans import reseed_empty
        tol = self.tol   # baked into the trace -> part of the cache key
        cache_key = ("chunk", params, n_steps, tol)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        backend = self._backend
        takes_inj = backend.takes_injection
        takes_params = backend.takes_params
        # int8 backends consume the QuantPlan itself even when they take
        # no tile params (the XLA analogue reuses the per-fit row
        # quantization instead of re-quantizing X every iteration)
        takes_plan = takes_params or backend.supports_int8

        if backend.supports_bounds:
            # Bounds-carrying variant: the BoundsState rides in the scan
            # carry (it is a registered pytree), so upper bounds and
            # centroid drifts survive across iterations without ever
            # touching the host. The history gains a prune-fraction
            # column. Frozen (converged) steps pass the bounds through
            # untouched — they would only decay further, and the fit is
            # over anyway.
            def chunk_bounded(plan: Any, centroids: jax.Array,
                              am0: jax.Array, det0: jax.Array,
                              inertia0: jax.Array, key: jax.Array,
                              it0: Any, bounds0: Any) -> tuple:
                def body(carry: tuple, t: jax.Array) -> tuple:
                    centroids, am, inertia, done, det, bounds = carry

                    def live(_: None) -> tuple:
                        xa = plan if takes_plan else plan.x
                        out = backend(xa, self._cast(centroids),
                                      params=params if takes_params
                                      else None, bounds=bounds)
                        am_b, md, det_i, new_c, counts = self._apply_update(
                            out, plan.x, centroids)
                        new_bounds, pfrac = out[5], out[6]
                        inertia_i = jnp.sum(md)
                        shift = jnp.sqrt(jnp.sum((new_c - centroids) ** 2))
                        new_c = reseed_empty(
                            jax.random.fold_in(key, it0 + t),
                            plan.x, new_c, counts, md)
                        return (new_c, am_b, inertia_i, shift,
                                det + det_i.astype(jnp.int32),
                                new_bounds, pfrac)

                    def frozen(_: None) -> tuple:
                        return (centroids, am, inertia, jnp.float32(0.0),
                                det, bounds, jnp.float32(0.0))

                    (new_c, am_n, inertia_n, shift, det_n, bounds_n,
                     pfrac) = jax.lax.cond(done, frozen, live, None)
                    active = jnp.logical_not(done)
                    done_n = jnp.logical_or(done, shift < tol)
                    return ((new_c, am_n, inertia_n, done_n, det_n,
                             bounds_n),
                            (new_c, inertia_n, shift, active, pfrac))

                init = (centroids, am0, inertia0, jnp.bool_(False), det0,
                        bounds0)
                (centroids, am, inertia, done, det, bounds), hist = \
                    jax.lax.scan(body, init, jnp.arange(n_steps),
                                 length=n_steps)
                return centroids, am, inertia, det, done, hist, bounds

            fn = jax.jit(chunk_bounded)
            self._step_cache[cache_key] = fn
            return fn

        def chunk(plan: Any, centroids: jax.Array, am0: jax.Array,
                  det0: jax.Array, inertia0: jax.Array, key: jax.Array,
                  it0: Any, inj_stack: Any) -> tuple:
            def body(carry: tuple, xs: tuple) -> tuple:
                centroids, am, inertia, done, det = carry
                inj, t = xs

                def live(_: None) -> tuple:
                    xa = plan if takes_plan else plan.x
                    out = backend(xa, self._cast(centroids),
                                  params=params if takes_params else None,
                                  inj=inj if takes_inj else None)
                    am_b, md, det_i, new_c, counts = self._apply_update(
                        out, plan.x, centroids)
                    inertia_i = jnp.sum(md)
                    shift = jnp.sqrt(jnp.sum((new_c - centroids) ** 2))
                    new_c = reseed_empty(jax.random.fold_in(key, it0 + t),
                                         plan.x, new_c, counts, md)
                    return (new_c, am_b, inertia_i, shift,
                            det + det_i.astype(jnp.int32))

                def frozen(_: None) -> tuple:
                    return centroids, am, inertia, jnp.float32(0.0), det

                new_c, am_n, inertia_n, shift, det_n = jax.lax.cond(
                    done, frozen, live, None)
                active = jnp.logical_not(done)
                done_n = jnp.logical_or(done, shift < tol)
                return ((new_c, am_n, inertia_n, done_n, det_n),
                        (new_c, inertia_n, shift, active))

            init = (centroids, am0, inertia0, jnp.bool_(False), det0)
            (centroids, am, inertia, done, det), hist = jax.lax.scan(
                body, init, (inj_stack, jnp.arange(n_steps)), length=n_steps)
            return centroids, am, inertia, det, done, hist

        fn = jax.jit(chunk)
        self._step_cache[cache_key] = fn
        return fn

    def _campaign_rng(self, offset: int = 0) -> np.random.Generator:
        """Injection-schedule RNG: keyed by the campaign's own seed (so
        repeated campaigns vary independently of data sampling), mixed
        with random_state for distinct estimators. The leading tag keeps
        the stream disjoint from the data-sampling rng even at seed 0."""
        camp = self.fault.injection
        camp_seed = camp.seed if camp is not None else 0
        return np.random.default_rng(
            [0x1427, camp_seed, self.random_state, offset])

    def _draw_injection(self, rng: np.random.Generator, m: int, f: int,
                        params: Optional[ops.KernelParams]) -> jax.Array:
        """Per-iteration campaign draw -> in-kernel injection descriptor
        (dual-slot for the one-pass FT kernel: distance GEMM + update
        epilogue are independently verified intervals)."""
        from repro.core.fault import draw_step_injection, no_step_injection
        camp = self.fault.injection
        kind = self._backend.kernel_kind
        if camp is None or not camp.enabled():
            return no_step_injection(kind)
        return draw_step_injection(
            rng, m, self.n_clusters, f, params, rate=camp.rate,
            targets=camp.resolved_targets(self._backend), kind=kind)

    def init_centroids(self, x: jax.Array,
                        key: Optional[jax.Array] = None) -> jax.Array:
        from repro.core.kmeans import init_kmeanspp, init_random
        key = key if key is not None else jax.random.PRNGKey(self.random_state)
        fn = init_kmeanspp if self.init == "kmeans++" else init_random
        return fn(key, x, self.n_clusters)

    # ------------------------------------------------------------------
    # estimator API
    # ------------------------------------------------------------------

    def fit(self, x: jax.Array, *, centroids: Optional[jax.Array] = None,
            on_iteration: Optional[Callable] = None) -> "KMeans":
        """Run Lloyd iterations to convergence (or ``max_iter``).

        ``centroids`` seeds the run (checkpoint restart / warm start);
        ``on_iteration(it, centroids, inertia, shift)`` observes progress.

        Full-batch fits run device-resident: the loop is a chunked
        ``lax.scan`` with the convergence test on device, the data plan
        (padding + row norms) built once, and the host synchronizing only
        every ``sync_every`` iterations (``on_iteration`` is replayed from
        the chunk history, so its per-iteration semantics are preserved).
        """
        x = jnp.asarray(x)
        key = jax.random.PRNGKey(self.random_state)
        if centroids is None:
            key, sub = jax.random.split(key)
            centroids = self.init_centroids(x, sub)
        # the estimator's centroid state is always f32; the compute dtype
        # applies at the kernel boundary only
        centroids = jnp.asarray(centroids, jnp.float32)
        if self.batch_size is not None:
            return self._fit_minibatch(x, centroids, on_iteration)
        return self._fit_fullbatch(x, centroids, key, on_iteration)

    def _fit_fullbatch(self, x: jax.Array, centroids: jax.Array,
                       key: jax.Array, on_iteration: Optional[Callable]
                       ) -> "KMeans":
        m, f = x.shape
        params = self._resolve_params(m, f)
        takes_inj = self._backend.takes_injection
        inj_rng = self._campaign_rng()
        # per-fit data plan: pad + row-norm X exactly once, reuse every
        # iteration (two-pass pipelines re-did both per kernel call). The
        # plan is built in the compute dtype so the per-iteration cost of a
        # bf16/fp16 fit is zero casts of X — only the (K, F) centroids are
        # cast per step.
        if self._backend.supports_int8:
            # quantize + pad once per fit; QuantPlan.x keeps the original
            # samples, so the two-pass centroid update and empty-cluster
            # reseeding stay full precision
            plan: Any = ops.plan_data_int8(self._cast(x), params)
        else:
            plan = ops.plan_data(self._cast(x), params)
        # bounds-carrying backends start every fit from a fresh (all-
        # compute) state: a warm start / from_state restore never inherits
        # bounds, so a centroid hot-swap can't leave stale Hamerly bounds
        supports_bounds = self._backend.supports_bounds
        bounds = self._backend.bounds_init(
            m, self.n_clusters, f, params, dtype=self.compute_dtype) \
            if supports_bounds else None
        self.prune_history_ = []

        am = jnp.zeros((m,), jnp.int32)
        det = jnp.zeros((), jnp.int32)
        inertia = jnp.float32(jnp.inf)
        inertia_host = float("inf")
        it0 = 0
        self._n_host_syncs = 0
        while it0 < self.max_iter:
            n_steps = min(self.sync_every, self.max_iter - it0)
            chunk = self._chunk_fn(params, n_steps)
            if supports_bounds:
                centroids, am, inertia, det, done_d, hist, bounds = chunk(
                    plan, centroids, am, det, inertia, key,
                    jnp.int32(it0), bounds)
            else:
                if takes_inj:
                    # pre-draw the chunk's campaign schedule: same host
                    # RNG consumption order as the per-iteration loop had
                    inj_stack = jnp.stack([
                        self._draw_injection(inj_rng, m, f, params)
                        for _ in range(n_steps)])
                else:
                    inj_stack = jnp.zeros((n_steps, 1), jnp.int32)
                centroids, am, inertia, det, done_d, hist = chunk(
                    plan, centroids, am, det, inertia, key,
                    jnp.int32(it0), inj_stack)
            # the chunk boundary: the only device->host sync of the window.
            # The (n_steps, K, F) centroid history crosses only when a
            # callback will actually read it.
            cs_d, in_d, sh_d, act_d = hist[:4]
            pf_d = hist[4] if supports_bounds else None
            if on_iteration is None:
                done, in_h, sh_h, act_h, pf_h = _host_read(
                    (done_d, in_d, sh_d, act_d, pf_d))
            else:
                done, cs_h, in_h, sh_h, act_h, pf_h = _host_read(
                    (done_d, cs_d, in_d, sh_d, act_d, pf_d))
            self._n_host_syncs += 1
            executed = int(act_h.sum())
            if on_iteration is not None:
                for t in range(executed):
                    on_iteration(it0 + t, cs_h[t], float(in_h[t]),
                                 float(sh_h[t]))
            if pf_h is not None:
                self.prune_history_.extend(
                    float(v_h) for v_h in pf_h[:executed])
            if executed:
                inertia_host = float(in_h[executed - 1])
            it0 += executed
            if bool(done):
                break

        self.cluster_centers_ = centroids
        self.n_iter_ = max(1, it0)
        self.detected_errors_ = int(_host_read(det))
        self._n_host_syncs += 1
        self._counts = None
        self.labels_ = am
        self.inertia_ = inertia_host
        return self

    def _fit_minibatch(self, x: jax.Array, centroids: jax.Array,
                       on_iteration: Optional[Callable]) -> "KMeans":
        """Sampled mini-batch Lloyd: batch selection is host-driven by
        construction, so this path keeps the per-iteration loop."""
        rng = np.random.default_rng(self.random_state + 1)
        inj_rng = self._campaign_rng()
        takes_inj = self._backend.takes_injection
        self.prune_history_ = []   # mini-batch steps run unpruned

        total_det = jnp.zeros((), jnp.int32)
        inertia = jnp.asarray(jnp.inf)
        it = 0
        for it in range(self.max_iter):
            idx = rng.choice(x.shape[0], min(self.batch_size, x.shape[0]),
                             replace=False)
            batch = x[jnp.asarray(idx)]
            params = self._resolve_params(batch.shape[0], batch.shape[1])
            step = self._lloyd_step_fn(params)

            inj = self._draw_injection(inj_rng, batch.shape[0],
                                       batch.shape[1], params) \
                if takes_inj else None
            centroids, am_b, counts, md, inertia, shift, det = step(
                batch, centroids, inj=inj)
            total_det = total_det + det
            # one funnel read per iteration covers both host consumers
            inertia_h, shift_h = _host_read((inertia, shift))
            if on_iteration is not None:
                on_iteration(it, centroids, float(inertia_h),
                             float(shift_h))
            if float(shift_h) < self.tol:
                break

        self.cluster_centers_ = centroids
        self.n_iter_ = it + 1
        self.detected_errors_ = int(_host_read(total_det))
        self._counts = None
        am, dist, det = self._predict_full(x)
        self.detected_errors_ += int(_host_read(det))
        self.labels_ = am
        self.inertia_ = float(_host_read(jnp.sum(dist)))
        return self

    def partial_fit(self, x: jax.Array) -> "KMeans":
        """One streaming update from a data block (first call initializes).

        Centers move by count-weighted running means, so a stream of blocks
        converges like mini-batch k-means regardless of block order."""
        x = jnp.asarray(x)
        if self.cluster_centers_ is None:
            self.cluster_centers_ = jnp.asarray(self.init_centroids(x),
                                                jnp.float32)
            self._counts = jnp.zeros((self.n_clusters,), jnp.float32)
            self.detected_errors_ = 0
            self.n_iter_ = 0
        elif self._counts is None:   # fitted by fit(); restart streaming
            self._counts = jnp.zeros((self.n_clusters,), jnp.float32)
        params = self._resolve_params(x.shape[0], x.shape[1])
        step = self._stream_step_fn(params)
        if self._backend.takes_injection:
            inj = self._draw_injection(self._campaign_rng(self.n_iter_),
                                       x.shape[0], x.shape[1], params)
        else:
            inj = None
        c, counts, am, inertia, det = step(
            x, self.cluster_centers_, self._counts, inj=inj)
        self.cluster_centers_ = c
        self._counts = counts
        self.labels_ = am
        inertia_h, det_h = _host_read((inertia, det))
        self.inertia_ = float(inertia_h)
        self.n_iter_ += 1
        self.detected_errors_ += int(det_h)
        return self

    def _row_chunks(self, m: int) -> list[slice]:
        """Row slices for one-shot inference: bounds the padded working set
        on large inputs (a full padded copy of X is never materialized).
        At most two distinct chunk shapes compile — the full chunk and the
        remainder."""
        chunk = self.predict_chunk_rows or _PREDICT_CHUNK_ROWS
        return [slice(s, min(s + chunk, m)) for s in range(0, m, chunk)]

    def _predict_block(self, x: jax.Array) -> tuple:
        if x.shape[0] == 0:
            # zero-row request (a serving layer sees these): no labels, no
            # kernel launch — and no autotune lookup keyed by an M=0 shape
            return (jnp.zeros((0,), jnp.int32),
                    jnp.zeros((0,), jnp.float32),
                    jnp.zeros((), jnp.int32))
        backend = self._predict_backend()
        params = self._resolve_params(x.shape[0], x.shape[1],
                                      backend=backend)
        fn = self._assign_fn(params)
        if backend.takes_injection:
            from repro.kernels.distance_argmin_ft import no_injection
            return fn(x, self.cluster_centers_, no_injection())
        return fn(x, self.cluster_centers_)

    def _predict_full(self, x: jax.Array) -> tuple:
        chunks = self._row_chunks(x.shape[0])
        if len(chunks) <= 1:              # includes zero-row input
            return self._predict_block(x)
        parts = [self._predict_block(x[s]) for s in chunks]
        am = jnp.concatenate([p[0] for p in parts])
        dist = jnp.concatenate([p[1] for p in parts])
        det = functools.reduce(lambda a, b: a + b, [p[2] for p in parts])
        return am, dist, det

    def predict(self, x: jax.Array) -> jax.Array:
        """Nearest-centroid labels for new data (no injection, ever)."""
        self._check_fitted()
        am, _, _ = self._predict_full(jnp.asarray(x))
        return am

    def fit_predict(self, x: jax.Array) -> jax.Array:
        return self.fit(x).labels_

    def transform(self, x: jax.Array) -> jax.Array:
        """Distances to every centroid, shape (M, n_clusters). Chunked over
        rows like :meth:`predict`, so the (M, F) working set stays bounded
        for large inputs."""
        self._check_fitted()
        x = jnp.asarray(x)

        def block(b: jax.Array) -> jax.Array:
            d = ref.distance_matrix(b, self.cluster_centers_)
            return jnp.sqrt(jnp.maximum(d, 0.0))

        chunks = self._row_chunks(x.shape[0])
        if len(chunks) <= 1:              # includes zero-row input
            return block(x)
        return jnp.concatenate([block(x[s]) for s in chunks])

    def score(self, x: jax.Array) -> float:
        """Negative inertia on ``x`` (sklearn convention: higher = better)."""
        self._check_fitted()
        _, dist, _ = self._predict_full(jnp.asarray(x))
        return -float(jnp.sum(dist))

    def to_service(self, *, buckets: Optional[tuple] = None,
                   window_s: Optional[float] = None) -> Any:
        """Hand the fitted model to the online serving layer: a
        :class:`repro.serve.KMeansService` with every bucketed predict
        cell AOT-compiled for this model's predict backend and compute
        dtype, centroids hot-swappable via its versioned store, and this
        estimator wired in as the background refinement loop
        (``service.refine`` -> :meth:`partial_fit`). Bucket ladder and
        batching window default to the tuned plan in the autotune cache
        (see ``repro.serve.tuning.plan_ladder``); docs/serving.md covers
        the architecture."""
        self._check_fitted()
        from repro.serve import KMeansService   # circular-import-safe
        return KMeansService.from_estimator(self, buckets=buckets,
                                            window_s=window_s)

    # ------------------------------------------------------------------
    # serializable state
    # ------------------------------------------------------------------

    def get_state(self) -> dict:
        """Fitted state as a flat dict of plain types + numpy arrays —
        feed it to ``np.savez``, JSON+base64, or ``ft.checkpoint``."""
        self._check_fitted()
        camp = self.fault.injection
        return {
            "cluster_centers": np.asarray(self.cluster_centers_),
            "counts": (np.asarray(self._counts)
                       if self._counts is not None else None),
            "n_iter": int(self.n_iter_),
            "inertia": (float(self.inertia_)
                        if self.inertia_ is not None else None),
            "detected_errors": int(self.detected_errors_),
            "config": {
                "n_clusters": self.n_clusters,
                "max_iter": self.max_iter,
                "tol": self.tol,
                "init": self.init,
                "backend": self.backend,
                "batch_size": self.batch_size,
                "sync_every": self.sync_every,
                "compute_dtype": self.compute_dtype.name,
                "predict_chunk_rows": self.predict_chunk_rows,
                "random_state": self.random_state,
                "params": (None if self.params is None else
                           [self.params.block_m, self.params.block_k,
                            self.params.block_f]),
                "fault": {
                    "mode": self.fault.mode,
                    "update_dmr": self.fault.update_dmr,
                    "worker_loss": self.fault.worker_loss,
                    "injection": (None if camp is None else {
                        "rate": camp.rate, "bit_low": camp.bit_low,
                        "bit_high": camp.bit_high, "seed": camp.seed,
                        "targets": camp.targets}),
                },
            },
        }

    @classmethod
    def from_state(cls, state: dict, *,
                   autotune: Optional[AutotuneCache] = None) -> "KMeans":
        """Reconstruct a fitted estimator from :meth:`get_state` output."""
        cfg = state["config"]
        fp = cfg["fault"]
        camp = fp.get("injection")
        fault = FaultPolicy(
            mode=fp["mode"], update_dmr=fp["update_dmr"],
            injection=None if camp is None else InjectionCampaign(**camp),
            worker_loss=fp.get("worker_loss", "fail"))  # pre-v3 states
        tiles = cfg.get("params")
        params = None if tiles is None else ops.KernelParams(*tiles)
        km = cls(cfg["n_clusters"], max_iter=cfg["max_iter"], tol=cfg["tol"],
                 init=cfg["init"], fault=fault, backend=cfg["backend"],
                 batch_size=cfg["batch_size"], params=params,
                 sync_every=cfg.get("sync_every", 10),  # pre-v2 states
                 compute_dtype=cfg.get("compute_dtype", "float32"),
                 predict_chunk_rows=cfg.get("predict_chunk_rows"),
                 random_state=cfg["random_state"], autotune=autotune)
        km.cluster_centers_ = jnp.asarray(state["cluster_centers"])
        counts = state.get("counts")
        km._counts = None if counts is None else jnp.asarray(counts)
        km.n_iter_ = int(state["n_iter"])
        inertia = state.get("inertia")
        km.inertia_ = None if inertia is None else float(inertia)
        km.detected_errors_ = int(state.get("detected_errors", 0))
        return km
