"""cuML/sklearn-compatible K-means estimator over the FT kernel stack.

One front end for every scenario in the paper and the roadmap:

    km = KMeans(n_clusters=8, fault=FaultPolicy.correct())
    labels = km.fit_predict(x)            # full-batch Lloyd
    km.partial_fit(block)                 # streaming / mini-batch path
    state = km.get_state()                # serializable fitted state
    km2 = KMeans.from_state(state)        # restore (checkpoint/restart)

Protection is a :class:`~repro.api.policy.FaultPolicy` — policy resolution
picks the assignment kernel from the backend registry; kernel-tile selection
comes from an injectable :class:`~repro.api.cache.AutotuneCache`. The
estimator never branches on backend names.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.cache import AutotuneCache, default_cache
from repro.api.policy import FaultPolicy, InjectionCampaign
from repro.api.registry import AssignmentBackend
from repro.kernels import ops, ref

_INITS = ("kmeans++", "random")


class NotFittedError(RuntimeError):
    pass


class KMeans:
    """K-means estimator with composable fault tolerance.

    Parameters mirror sklearn/cuML: ``n_clusters``, ``max_iter``, ``tol``
    (centroid-shift convergence threshold), ``init`` ("kmeans++"/"random"),
    ``random_state``. Additions:

    fault:      :class:`FaultPolicy` — off / detect / correct (+ optional
                SEU injection campaign). Default: no protection.
    backend:    pin a registered assignment backend by name; default lets
                the policy resolve one (paper §III-B selection).
    batch_size: when set, ``fit`` runs sampled mini-batches per iteration;
                ``partial_fit`` streams caller-provided batches either way.
    params:     explicit :class:`KernelParams` tile override.
    autotune:   injectable :class:`AutotuneCache`; default = process cache.

    Fitted attributes: ``cluster_centers_``, ``labels_``, ``inertia_``,
    ``n_iter_``, ``detected_errors_``.
    """

    def __init__(self, n_clusters: int = 8, *, max_iter: int = 100,
                 tol: float = 1e-4, init: str = "kmeans++",
                 fault: Optional[FaultPolicy] = None,
                 backend: Optional[str] = None,
                 batch_size: Optional[int] = None,
                 params=None,
                 autotune: Optional[AutotuneCache] = None,
                 random_state: int = 0):
        if n_clusters < 1:
            raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
        if init not in _INITS:
            raise ValueError(f"init must be one of {_INITS}, got {init!r}")
        self.n_clusters = n_clusters
        self.max_iter = max_iter
        self.tol = tol
        self.init = init
        self.fault = fault if fault is not None else FaultPolicy.off()
        self.backend = backend
        self.batch_size = batch_size
        self.params = params
        self.autotune = autotune if autotune is not None else default_cache()
        self.random_state = random_state

        self._backend: AssignmentBackend = self.fault.resolve_backend(backend)
        self._step_cache: dict = {}
        # streaming state (partial_fit)
        self._counts: Optional[jax.Array] = None

        self.cluster_centers_: Optional[jax.Array] = None
        self.labels_: Optional[jax.Array] = None
        self.inertia_: Optional[float] = None
        self.n_iter_: int = 0
        self.detected_errors_: int = 0

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _check_fitted(self):
        if self.cluster_centers_ is None:
            raise NotFittedError(
                "this KMeans instance is not fitted yet; call fit() or "
                "partial_fit() first")

    def _resolve_params(self, m: int, f: int):
        """Tile selection for one problem shape: explicit override, else the
        injectable autotune cache (paper §III-B table lookup)."""
        if not self._backend.takes_params:
            return None
        p = self.params or self.autotune.lookup(m, self.n_clusters, f)
        return ops.clamp_params(m, self.n_clusters, f, p)

    def _assign_fn(self, params):
        """jit'd (x, c[, inj]) -> (assign, true sq-dist, detected)."""
        key = ("assign", params)
        if key not in self._step_cache:
            backend = self._backend
            if backend.takes_injection:
                fn = jax.jit(lambda x, c, inj: backend(
                    x, c, params=params, inj=inj))
            else:
                fn = jax.jit(lambda x, c: backend(x, c, params=params))
            self._step_cache[key] = fn
        return self._step_cache[key]

    def _lloyd_step_fn(self, params):
        """jit'd full Lloyd step: assignment + (DMR-)protected update."""
        from repro.core.kmeans import centroid_update
        key = ("lloyd", params)
        if key not in self._step_cache:
            backend, k = self._backend, self.n_clusters
            use_dmr = self.fault.update_dmr

            def step(x, centroids, inj=None):
                am, md, det = backend(x, centroids, params=params, inj=inj)
                new_c, counts = centroid_update(x, am, k, centroids,
                                                use_dmr=use_dmr)
                inertia = jnp.sum(md)
                shift = jnp.sqrt(jnp.sum((new_c - centroids) ** 2))
                return new_c, am, counts, md, inertia, shift, det

            static = () if backend.takes_injection else ("inj",)
            self._step_cache[key] = jax.jit(step, static_argnames=static)
        return self._step_cache[key]

    def _stream_step_fn(self, params):
        """jit'd streaming (mini-batch) step with per-center count decay —
        the partial_fit update rule (Sculley-style online k-means)."""
        from repro.core.kmeans import protected_sums
        key = ("stream", params)
        if key not in self._step_cache:
            backend, k = self._backend, self.n_clusters
            use_dmr = self.fault.update_dmr

            def step(x, centroids, counts, inj=None):
                am, md, det = backend(x, centroids, params=params, inj=inj)
                sums, bcnt = protected_sums(x, am, k, use_dmr=use_dmr)
                new_counts = counts + bcnt
                eta = (bcnt / jnp.maximum(new_counts, 1.0))[:, None]
                bmean = sums / jnp.maximum(bcnt, 1.0)[:, None]
                new_c = jnp.where((bcnt > 0)[:, None],
                                  (1.0 - eta) * centroids + eta * bmean,
                                  centroids)
                return new_c, new_counts, am, jnp.sum(md), det

            static = () if backend.takes_injection else ("inj",)
            self._step_cache[key] = jax.jit(step, static_argnames=static)
        return self._step_cache[key]

    def _campaign_rng(self, offset: int = 0):
        """Injection-schedule RNG: keyed by the campaign's own seed (so
        repeated campaigns vary independently of data sampling), mixed
        with random_state for distinct estimators. The leading tag keeps
        the stream disjoint from the data-sampling rng even at seed 0."""
        camp = self.fault.injection
        camp_seed = camp.seed if camp is not None else 0
        return np.random.default_rng(
            [0x1427, camp_seed, self.random_state, offset])

    def _draw_injection(self, rng, m: int, f: int, params):
        """Per-iteration campaign draw -> in-kernel injection descriptor."""
        from repro.core.fault import draw_tile_injection
        camp = self.fault.injection
        from repro.kernels.distance_argmin_ft import no_injection
        if camp is None or not camp.enabled() or \
                rng.uniform() > min(camp.rate, 1.0):
            return no_injection()
        return draw_tile_injection(rng, m, self.n_clusters, f, params)

    def init_centroids(self, x: jax.Array,
                        key: Optional[jax.Array] = None) -> jax.Array:
        from repro.core.kmeans import init_kmeanspp, init_random
        key = key if key is not None else jax.random.PRNGKey(self.random_state)
        fn = init_kmeanspp if self.init == "kmeans++" else init_random
        return fn(key, x, self.n_clusters)

    # ------------------------------------------------------------------
    # estimator API
    # ------------------------------------------------------------------

    def fit(self, x: jax.Array, *, centroids: Optional[jax.Array] = None,
            on_iteration: Optional[Callable] = None) -> "KMeans":
        """Run Lloyd iterations to convergence (or ``max_iter``).

        ``centroids`` seeds the run (checkpoint restart / warm start);
        ``on_iteration(it, centroids, inertia, shift)`` observes progress.
        """
        from repro.core.kmeans import reseed_empty
        x = jnp.asarray(x)
        key = jax.random.PRNGKey(self.random_state)
        if centroids is None:
            key, sub = jax.random.split(key)
            centroids = self.init_centroids(x, sub)
        rng = np.random.default_rng(self.random_state + 1)
        inj_rng = self._campaign_rng()
        takes_inj = self._backend.takes_injection

        total_det = jnp.zeros((), jnp.int32)
        am = jnp.zeros((x.shape[0],), jnp.int32)
        inertia = jnp.asarray(jnp.inf)
        it = 0
        for it in range(self.max_iter):
            batch = x
            if self.batch_size is not None:
                idx = rng.choice(x.shape[0], min(self.batch_size, x.shape[0]),
                                 replace=False)
                batch = x[jnp.asarray(idx)]
            params = self._resolve_params(batch.shape[0], batch.shape[1])
            step = self._lloyd_step_fn(params)

            inj = self._draw_injection(inj_rng, batch.shape[0],
                                       batch.shape[1], params) \
                if takes_inj else None
            centroids, am_b, counts, md, inertia, shift, det = step(
                batch, centroids, inj=inj)
            total_det = total_det + det
            if self.batch_size is None:
                am = am_b
                centroids = reseed_empty(
                    jax.random.fold_in(key, it), batch, centroids, counts, md)
            if on_iteration is not None:
                on_iteration(it, centroids, float(inertia), float(shift))
            if float(shift) < self.tol:
                break

        self.cluster_centers_ = centroids
        self.n_iter_ = it + 1
        self.detected_errors_ = int(total_det)
        self._counts = None
        if self.batch_size is not None:
            am, dist, det = self._predict_full(x)
            inertia = jnp.sum(dist)
            self.detected_errors_ += int(det)
        self.labels_ = am
        self.inertia_ = float(inertia)
        return self

    def partial_fit(self, x: jax.Array) -> "KMeans":
        """One streaming update from a data block (first call initializes).

        Centers move by count-weighted running means, so a stream of blocks
        converges like mini-batch k-means regardless of block order."""
        x = jnp.asarray(x)
        if self.cluster_centers_ is None:
            self.cluster_centers_ = self.init_centroids(x)
            self._counts = jnp.zeros((self.n_clusters,), jnp.float32)
            self.detected_errors_ = 0
            self.n_iter_ = 0
        elif self._counts is None:   # fitted by fit(); restart streaming
            self._counts = jnp.zeros((self.n_clusters,), jnp.float32)
        params = self._resolve_params(x.shape[0], x.shape[1])
        step = self._stream_step_fn(params)
        if self._backend.takes_injection:
            inj = self._draw_injection(self._campaign_rng(self.n_iter_),
                                       x.shape[0], x.shape[1], params)
        else:
            inj = None
        c, counts, am, inertia, det = step(
            x, self.cluster_centers_, self._counts, inj=inj)
        self.cluster_centers_ = c
        self._counts = counts
        self.labels_ = am
        self.inertia_ = float(inertia)
        self.n_iter_ += 1
        self.detected_errors_ += int(det)
        return self

    def _predict_full(self, x: jax.Array):
        params = self._resolve_params(x.shape[0], x.shape[1])
        fn = self._assign_fn(params)
        if self._backend.takes_injection:
            from repro.kernels.distance_argmin_ft import no_injection
            return fn(x, self.cluster_centers_, no_injection())
        return fn(x, self.cluster_centers_)

    def predict(self, x: jax.Array) -> jax.Array:
        """Nearest-centroid labels for new data (no injection, ever)."""
        self._check_fitted()
        am, _, _ = self._predict_full(jnp.asarray(x))
        return am

    def fit_predict(self, x: jax.Array) -> jax.Array:
        return self.fit(x).labels_

    def transform(self, x: jax.Array) -> jax.Array:
        """Distances to every centroid, shape (M, n_clusters)."""
        self._check_fitted()
        d = ref.distance_matrix(jnp.asarray(x), self.cluster_centers_)
        return jnp.sqrt(jnp.maximum(d, 0.0))

    def score(self, x: jax.Array) -> float:
        """Negative inertia on ``x`` (sklearn convention: higher = better)."""
        self._check_fitted()
        _, dist, _ = self._predict_full(jnp.asarray(x))
        return -float(jnp.sum(dist))

    # ------------------------------------------------------------------
    # serializable state
    # ------------------------------------------------------------------

    def get_state(self) -> dict:
        """Fitted state as a flat dict of plain types + numpy arrays —
        feed it to ``np.savez``, JSON+base64, or ``ft.checkpoint``."""
        self._check_fitted()
        camp = self.fault.injection
        return {
            "cluster_centers": np.asarray(self.cluster_centers_),
            "counts": (np.asarray(self._counts)
                       if self._counts is not None else None),
            "n_iter": int(self.n_iter_),
            "inertia": (float(self.inertia_)
                        if self.inertia_ is not None else None),
            "detected_errors": int(self.detected_errors_),
            "config": {
                "n_clusters": self.n_clusters,
                "max_iter": self.max_iter,
                "tol": self.tol,
                "init": self.init,
                "backend": self.backend,
                "batch_size": self.batch_size,
                "random_state": self.random_state,
                "params": (None if self.params is None else
                           [self.params.block_m, self.params.block_k,
                            self.params.block_f]),
                "fault": {
                    "mode": self.fault.mode,
                    "update_dmr": self.fault.update_dmr,
                    "injection": (None if camp is None else {
                        "rate": camp.rate, "bit_low": camp.bit_low,
                        "bit_high": camp.bit_high, "seed": camp.seed}),
                },
            },
        }

    @classmethod
    def from_state(cls, state: dict, *,
                   autotune: Optional[AutotuneCache] = None) -> "KMeans":
        """Reconstruct a fitted estimator from :meth:`get_state` output."""
        cfg = state["config"]
        fp = cfg["fault"]
        camp = fp.get("injection")
        fault = FaultPolicy(
            mode=fp["mode"], update_dmr=fp["update_dmr"],
            injection=None if camp is None else InjectionCampaign(**camp))
        tiles = cfg.get("params")
        params = None if tiles is None else ops.KernelParams(*tiles)
        km = cls(cfg["n_clusters"], max_iter=cfg["max_iter"], tol=cfg["tol"],
                 init=cfg["init"], fault=fault, backend=cfg["backend"],
                 batch_size=cfg["batch_size"], params=params,
                 random_state=cfg["random_state"], autotune=autotune)
        km.cluster_centers_ = jnp.asarray(state["cluster_centers"])
        counts = state.get("counts")
        km._counts = None if counts is None else jnp.asarray(counts)
        km.n_iter_ = int(state["n_iter"])
        inertia = state.get("inertia")
        km.inertia_ = None if inertia is None else float(inertia)
        km.detected_errors_ = int(state.get("detected_errors", 0))
        return km
