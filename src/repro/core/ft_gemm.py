"""ABFT-protected matmul (jnp path) with online detection + correction.

This is the framework-level counterpart of the paper's fused kernel: the
Pallas kernels in ``repro.kernels`` fuse the checksums into the tile loop;
this module provides the same invariant at the XLA level so that *any*
dense layer in the LM stack (``repro.ft.abft_dense``) or the k-means
assignment can be protected on hardware where the kernel is not deployed.

Overhead model (paper §IV-A): for D = X @ Y with X (m, k), Y (k, n),
the checksummed products add O((m + n) k) encode work + four length-k
one-row GEMMs — a 2/m + 2/n relative cost, vanishing for the tall-skinny
shapes k-means produces (m = samples >> n = clusters).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import checksum
from repro.core.fault import FaultConfig, inject


@partial(jax.jit,
         static_argnames=("threshold_scale", "precision", "fault"))
def ft_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    inject_key: Optional[jax.Array] = None,
    fault: Optional[FaultConfig] = None,
    threshold_scale: float = 1.0,
    precision=None,
) -> tuple[jax.Array, jax.Array]:
    """Compute x @ y with dual-checksum ABFT detect + correct.

    Returns (d_corrected, detected_flag). When ``inject_key`` and ``fault``
    are given, a single SEU bit-flip is injected into the raw product —
    simulating a compute-unit error — before verification, so the returned
    product demonstrates end-to-end online correction.
    """
    expected = checksum.expected_checksums(x, y)
    d = jnp.matmul(x, y, precision=precision)
    if inject_key is not None and fault is not None and fault.enabled():
        d = inject(inject_key, d, fault)
    scale = jnp.maximum(jnp.max(jnp.abs(d)), 1.0)
    thr = checksum.default_threshold(x.shape[1], d.dtype, threshold_scale) * scale
    verdict = checksum.verify(d, expected, thr)
    return checksum.correct(d, verdict), verdict.detected


@partial(jax.jit, static_argnames=("threshold_scale", "precision"))
def ft_matmul_col(
    x: jax.Array,
    y: jax.Array,
    *,
    threshold_scale: float = 1.0,
    precision=None,
) -> tuple[jax.Array, jax.Array]:
    """Column-checksum-only ABFT matmul (beyond-paper optimization).

    Under the SEU model the e1/e2 *column* checksums alone both detect and
    locate: j = argmax residual column, delta = r1[j], i = r2[j]/r1[j] - 1.
    Skipping the row checksums removes two length-k one-row GEMMs and one
    full reduction pass over D — the jnp-tier overhead drops ~2x
    (EXPERIMENTS.md §Perf internlm2 iteration 2). The scale proxy uses the
    checksum row (already a full-D reduction) instead of max|D|, removing
    another pass.
    """
    c1x, c2x = checksum.encode_cols(x)
    exp_col1 = c1x @ y
    exp_col2 = c2x @ y
    d = jnp.matmul(x, y, precision=precision)
    obs_col1 = jnp.sum(d, axis=0)
    w = checksum.e2(d.shape[0], d.dtype)
    obs_col2 = w @ d
    res1 = obs_col1 - exp_col1
    res2 = obs_col2 - exp_col2
    # scale proxy: column checksums are m-fold sums of D
    scale = jnp.maximum(jnp.max(jnp.abs(exp_col1)) / max(d.shape[0], 1), 1.0)
    thr = checksum.default_threshold(
        x.shape[1], d.dtype, threshold_scale) * scale * d.shape[0]
    detected = jnp.any(jnp.abs(res1) > thr)
    j = jnp.argmax(jnp.abs(res1)).astype(jnp.int32)
    delta = res1[j]
    safe = jnp.where(delta == 0.0, 1.0, delta)
    i = jnp.clip((jnp.round(res2[j] / safe) - 1.0).astype(jnp.int32),
                 0, d.shape[0] - 1)
    fixed = d.at[i, j].add(-jnp.where(detected, delta, 0.0))
    return fixed, detected


def abft_dot(x: jax.Array, y: jax.Array, *, enabled: bool = True,
             precision=None, mode: str = "col") -> jax.Array:
    """Drop-in jnp.matmul replacement used by repro.ft.abft_dense.

    Silent-correcting variant: callers that don't care about the flag just
    get the (corrected) product. Differentiable: the backward pass re-uses
    protected matmuls (gradients of a corrected product equal gradients of
    the clean product under the SEU model, since correction restores D).

    mode: "col" (default) = column-checksum-only fast path (~2x lower
    overhead, same SEU guarantee); "full" = paper-faithful dual row+column.
    """
    if not enabled:
        return jnp.matmul(x, y, precision=precision)
    prot = ft_matmul_col if mode == "col" else ft_matmul

    @jax.custom_vjp
    def _f(x, y):
        d, _ = prot(x, y, precision=precision)
        return d

    def _fwd(x, y):
        return _f(x, y), (x, y)

    def _bwd(res, g):
        x, y = res
        # Protect the two backward GEMMs with the same invariant.
        gx, _ = prot(g, y.T, precision=precision)
        gy, _ = prot(x.T, g, precision=precision)
        return gx.astype(x.dtype), gy.astype(y.dtype)

    _f.defvjp(_fwd, _bwd)
    return _f(x, y)
