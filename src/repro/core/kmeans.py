"""FT K-means — the paper's algorithm as a composable JAX module.

Lloyd iterations with: pluggable assignment strategy (the paper's stepwise
ladder, see ``assignment.py``), DMR-protected centroid update (§IV intro),
k-means++ / random init, mini-batch mode, empty-cluster reseeding, and an
SEU injection campaign hook for the fault-tolerance benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assignment as assign_mod
from repro.core import dmr as dmr_mod
from repro.core.fault import FaultConfig
from repro.kernels import ops, ref


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    k: int
    max_iters: int = 100
    tol: float = 1e-4
    init: str = "kmeans++"            # "kmeans++" | "random"
    assignment: str = "fused"          # key into assignment.STRATEGIES
    dmr_update: bool = True            # DMR on the memory-bound update phase
    minibatch: Optional[int] = None    # None = full-batch Lloyd
    seed: int = 0
    dtype: str = "float32"


class KMeansState(NamedTuple):
    centroids: jax.Array       # (K, F)
    assign: jax.Array          # (M,) int32
    inertia: jax.Array         # scalar: sum of squared distances
    shift: jax.Array           # centroid movement (convergence metric)
    iteration: jax.Array       # int32
    detected_errors: jax.Array # cumulative SDCs corrected (int32)


class KMeansResult(NamedTuple):
    centroids: jax.Array
    assign: jax.Array
    inertia: jax.Array
    iterations: int
    detected_errors: jax.Array


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_random(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
    return x[idx]


def init_kmeanspp(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (D^2 sampling), jit-safe via fori_loop."""
    m = x.shape[0]
    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, m)]
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    d2 = jnp.sum((x - first) ** 2, axis=1)

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(sub, m, p=probs)
        nxt = x[idx]
        centroids = centroids.at[i].set(nxt)
        d2 = jnp.minimum(d2, jnp.sum((x - nxt) ** 2, axis=1))
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, d2, key))
    return centroids


# ---------------------------------------------------------------------------
# One Lloyd step
# ---------------------------------------------------------------------------

def centroid_update(x: jax.Array, assign: jax.Array, k: int,
                    prev: jax.Array, *, use_dmr: bool = True):
    """Means of assigned points; empty clusters keep their previous centroid.

    The paper's step 3: memory-bound, protected by DMR (arithmetic is
    duplicated over once-loaded data; <1 % overhead in the paper)."""
    def _sums(x, assign):
        return ref.centroid_update(x, assign, k)

    if use_dmr:
        (sums, counts), bad = dmr_mod.dmr(_sums, x, assign)
        # SEU model: a mismatch triggers one recompute (fail-continue fix).
        def recompute(_):
            s, c = _sums(jax.lax.optimization_barrier(x),
                         jax.lax.optimization_barrier(assign))
            return s, c
        sums, counts = jax.lax.cond(bad, recompute, lambda _: (sums, counts),
                                    operand=None)
    else:
        sums, counts = _sums(x, assign)

    counts_safe = jnp.maximum(counts, 1.0)
    means = sums / counts_safe[:, None]
    return jnp.where((counts > 0)[:, None], means, prev), counts


def reseed_empty(key: jax.Array, x: jax.Array, centroids: jax.Array,
                 counts: jax.Array, min_dist: jax.Array) -> jax.Array:
    """Move empty clusters onto the points farthest from their centroid —
    the standard cuML/sklearn policy, jit-safe."""
    k = centroids.shape[0]
    order = jnp.argsort(-min_dist)            # farthest points first
    empty_rank = jnp.cumsum(counts == 0) - 1  # position among empties
    donor = order[jnp.clip(empty_rank, 0, x.shape[0] - 1)]
    return jnp.where((counts == 0)[:, None], x[donor], centroids)


def make_step(cfg: KMeansConfig, params=None):
    """Build a jit-able (x, centroids, inj_or_None) -> (state pieces) step."""
    strat = assign_mod.STRATEGIES[cfg.assignment]

    def step(x, centroids, inj=None):
        if cfg.assignment == "fused_ft":
            am, md, det = strat(x, centroids, params, inj=inj)
        elif cfg.assignment == "fused":
            am, md, det = strat(x, centroids, params)
        else:
            am, md, det = strat(x, centroids)
        new_c, counts = centroid_update(
            x, am, cfg.k, centroids, use_dmr=cfg.dmr_update)
        inertia = jnp.sum(md)
        shift = jnp.sqrt(jnp.sum((new_c - centroids) ** 2))
        return new_c, am, counts, md, inertia, shift, det

    return step


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

class KMeans:
    """scikit-learn-flavoured front end over the jit'd Lloyd step."""

    def __init__(self, cfg: KMeansConfig, params=None):
        self.cfg = cfg
        self.params = params
        self._step = jax.jit(make_step(cfg, params))

    def init_centroids(self, x: jax.Array, key: Optional[jax.Array] = None):
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        fn = init_kmeanspp if self.cfg.init == "kmeans++" else init_random
        return fn(key, x, self.cfg.k)

    def fit(self, x: jax.Array, *, centroids: Optional[jax.Array] = None,
            fault: Optional[FaultConfig] = None,
            on_iteration: Optional[Callable] = None) -> KMeansResult:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        if centroids is None:
            key, sub = jax.random.split(key)
            centroids = self.init_centroids(x, sub)

        total_det = jnp.zeros((), jnp.int32)
        am = jnp.zeros((x.shape[0],), jnp.int32)
        inertia = jnp.asarray(jnp.inf)
        rng = np.random.default_rng(cfg.seed + 1)
        it = 0
        for it in range(cfg.max_iters):
            batch = x
            if cfg.minibatch is not None:
                idx = rng.choice(x.shape[0], cfg.minibatch, replace=False)
                batch = x[jnp.asarray(idx)]

            inj = None
            if cfg.assignment == "fused_ft":
                inj = self._draw_injection(rng, batch, fault)

            centroids, am_b, counts, md, inertia, shift, det = self._step(
                batch, centroids, inj)
            total_det = total_det + det
            if cfg.minibatch is None:
                am = am_b
                centroids = reseed_empty(
                    jax.random.fold_in(key, it), batch, centroids, counts, md)
            if on_iteration is not None:
                on_iteration(it, centroids, float(inertia), float(shift))
            if float(shift) < cfg.tol:
                break

        if cfg.minibatch is not None:   # final full assignment
            am, _, _ = assign_mod.STRATEGIES["gemm_fused"](x, centroids)
        return KMeansResult(centroids, am, inertia, it + 1, total_det)

    def _draw_injection(self, rng, batch, fault: Optional[FaultConfig]):
        from repro.kernels.distance_argmin_ft import no_injection
        if fault is None or not fault.enabled() or rng.uniform() > min(fault.rate, 1.0):
            return no_injection()
        m, f = batch.shape
        k = self.cfg.k
        from repro.core.autotune import lookup_params
        p = self.params or lookup_params(m, k, f)
        p = ops.clamp_params(m, k, f, p)
        # Random tile/element + a large delta (bit-flip magnitude scale).
        mp = -(-m // p.block_m)
        kp = -(-k // p.block_k)
        fp = -(-f // p.block_f)
        from repro.kernels.distance_argmin_ft import make_injection
        delta = float(rng.choice([-1.0, 1.0]) * 2.0 ** rng.integers(4, 24))
        return make_injection(int(rng.integers(mp)), int(rng.integers(kp)),
                              int(rng.integers(fp)), int(rng.integers(p.block_m)),
                              int(rng.integers(p.block_k)), delta)


def fit_kmeans(x, k: int, **kw) -> KMeansResult:
    """Convenience one-shot API."""
    cfg = KMeansConfig(k=k, **kw)
    return KMeans(cfg).fit(x)
