"""K-means numerics + legacy shims.

The estimator front end lives in ``repro.api`` (:class:`repro.api.KMeans`,
:class:`repro.api.FaultPolicy`). This module keeps the algorithmic pieces it
is built from — initialization (k-means++ / random), the DMR-protected
centroid update (paper §IV intro), empty-cluster reseeding — plus thin
deprecation shims (:class:`KMeansConfig`, :class:`KMeans`,
:func:`fit_kmeans`) that translate the old magic-string surface onto the
typed one.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import dmr as dmr_mod
from repro.core.fault import FaultConfig
from repro.kernels import ref


class KMeansState(NamedTuple):
    centroids: jax.Array       # (K, F)
    assign: jax.Array          # (M,) int32
    inertia: jax.Array         # scalar: sum of squared distances
    shift: jax.Array           # centroid movement (convergence metric)
    iteration: jax.Array       # int32
    detected_errors: jax.Array # cumulative SDCs corrected (int32)


class KMeansResult(NamedTuple):
    centroids: jax.Array
    assign: jax.Array
    inertia: jax.Array
    iterations: int
    detected_errors: jax.Array


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

# Both inits are jitted with K static: an eager fori_loop/choice retraces
# its body on every call, which put one fresh XLA compile on every
# ``fit`` — the recompile gate (repro.analysis.recompile) caught it on
# the warm-refit path.
@functools.partial(jax.jit, static_argnums=(2,))
def init_random(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
    return x[idx]


@functools.partial(jax.jit, static_argnums=(2,))
def init_kmeanspp(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (D^2 sampling), jit-safe via fori_loop."""
    m = x.shape[0]
    k0, key = jax.random.split(key)
    first = x[jax.random.randint(k0, (), 0, m)]
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    d2 = jnp.sum((x - first) ** 2, axis=1)

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(sub, m, p=probs)
        nxt = x[idx]
        centroids = centroids.at[i].set(nxt)
        d2 = jnp.minimum(d2, jnp.sum((x - nxt) ** 2, axis=1))
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, d2, key))
    return centroids


# ---------------------------------------------------------------------------
# Centroid update (paper step 3: memory-bound, DMR-protected)
# ---------------------------------------------------------------------------

def protected_sums(x: jax.Array, assign: jax.Array, k: int, *,
                   use_dmr: bool = True):
    """Per-cluster (sums, counts), optionally under DMR.

    DMR duplicates the arithmetic over once-loaded data (<1 % overhead in
    the paper); a mismatch triggers one recompute (fail-continue fix)."""
    def _sums(x, assign):
        return ref.centroid_update(x, assign, k)

    if not use_dmr:
        return _sums(x, assign)
    (sums, counts), bad = dmr_mod.dmr(_sums, x, assign)

    def recompute(_):
        return _sums(jax.lax.optimization_barrier(x),
                     jax.lax.optimization_barrier(assign))

    return jax.lax.cond(bad, recompute, lambda _: (sums, counts),
                        operand=None)


def means_from_sums(sums: jax.Array, counts: jax.Array,
                    prev: jax.Array) -> jax.Array:
    """New centroids from per-cluster (sums, counts); empty clusters keep
    their previous centroid. The single empty-cluster policy shared by the
    two-pass update, the one-pass (fused-update) step and the benchmarks."""
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    return jnp.where((counts > 0)[:, None], means, prev)


def centroid_update(x: jax.Array, assign: jax.Array, k: int,
                    prev: jax.Array, *, use_dmr: bool = True):
    """Means of assigned points; empty clusters keep their previous centroid."""
    sums, counts = protected_sums(x, assign, k, use_dmr=use_dmr)
    return means_from_sums(sums, counts, prev), counts


def reseed_empty(key: jax.Array, x: jax.Array, centroids: jax.Array,
                 counts: jax.Array, min_dist: jax.Array) -> jax.Array:
    """Move empty clusters onto the points farthest from their centroid —
    the standard cuML/sklearn policy, jit-safe."""
    order = jnp.argsort(-min_dist)            # farthest points first
    empty_rank = jnp.cumsum(counts == 0) - 1  # position among empties
    donor = order[jnp.clip(empty_rank, 0, x.shape[0] - 1)]
    return jnp.where((counts == 0)[:, None], x[donor], centroids)


# ---------------------------------------------------------------------------
# Legacy shims (deprecated): magic-string config -> repro.api
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    """Deprecated: construct ``repro.api.KMeans`` with a ``FaultPolicy``."""

    k: int
    max_iters: int = 100
    tol: float = 1e-4
    init: str = "kmeans++"            # "kmeans++" | "random"
    assignment: str = "fused"          # registered backend name
    dmr_update: bool = True            # DMR on the memory-bound update phase
    minibatch: Optional[int] = None    # None = full-batch Lloyd
    seed: int = 0
    dtype: str = "float32"


def _policy_for(cfg: KMeansConfig, fault: Optional[FaultConfig]):
    """Translate (assignment string, dmr_update, FaultConfig) -> FaultPolicy."""
    from repro.api import FaultPolicy, InjectionCampaign, get_backend
    backend = get_backend(cfg.assignment)
    campaign = None
    if fault is not None and fault.enabled() and backend.takes_injection:
        campaign = InjectionCampaign(rate=fault.rate, bit_low=fault.bit_low,
                                     bit_high=fault.bit_high, seed=fault.seed)
    if backend.supports_ft:
        mode = "correct" if backend.takes_injection else "detect"
        return FaultPolicy(mode=mode, update_dmr=cfg.dmr_update,
                           injection=campaign)
    # unprotected assignment, but dmr_update is honoured independently
    # (legacy default was DMR-on even for plain backends)
    return FaultPolicy(mode="off", update_dmr=cfg.dmr_update)


def _make_estimator(cfg: KMeansConfig, params,
                    fault: Optional[FaultConfig] = None):
    from repro.api import KMeans as ApiKMeans
    return ApiKMeans(cfg.k, max_iter=cfg.max_iters, tol=cfg.tol,
                     init=cfg.init, fault=_policy_for(cfg, fault),
                     backend=cfg.assignment, batch_size=cfg.minibatch,
                     params=params, random_state=cfg.seed)


class KMeans:
    """Deprecated front end kept for compatibility; delegates to
    ``repro.api.KMeans``. New code should use the typed API directly."""

    def __init__(self, cfg: KMeansConfig, params=None):
        warnings.warn(
            "repro.core.KMeans/KMeansConfig are deprecated; use "
            "repro.api.KMeans(n_clusters=..., fault=FaultPolicy(...))",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.params = params
        # one estimator for the shim's lifetime so repeated fits reuse the
        # jit cache (a per-fit FaultConfig only changes the host-side
        # injection schedule, never the compiled step)
        self._est = _make_estimator(cfg, params)

    def init_centroids(self, x: jax.Array, key: Optional[jax.Array] = None):
        return self._est.init_centroids(x, key)

    def fit(self, x: jax.Array, *, centroids: Optional[jax.Array] = None,
            fault: Optional[FaultConfig] = None,
            on_iteration: Optional[Callable] = None) -> KMeansResult:
        est = self._est
        est.fault = _policy_for(self.cfg, fault)
        est.fit(x, centroids=centroids, on_iteration=on_iteration)
        return KMeansResult(est.cluster_centers_, est.labels_,
                            jnp.asarray(est.inertia_), est.n_iter_,
                            jnp.asarray(est.detected_errors_, jnp.int32))


def fit_kmeans(x, k: int, **kw) -> KMeansResult:
    """Deprecated convenience one-shot API (``repro.api.KMeans(...).fit``)."""
    warnings.warn("fit_kmeans is deprecated; use repro.api.KMeans",
                  DeprecationWarning, stacklevel=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = KMeansConfig(k=k, **kw)
        return KMeans(cfg).fit(x)
