"""FT K-means core — algorithm numerics behind the ``repro.api`` surface.

Prefer ``repro.api`` (typed ``KMeans`` + ``FaultPolicy`` + backend registry
+ injectable ``AutotuneCache``) for anything user-facing; this package holds
the pieces it composes — assignment backends (stepwise ladder §III-A),
DMR/ABFT protection (§IV), kernel search/selection (§III-B) — plus
deprecated legacy shims (``KMeansConfig``, ``fit_kmeans``).
"""
from repro.core.kmeans import (KMeans, KMeansConfig, KMeansResult, fit_kmeans,
                               centroid_update, init_kmeanspp, init_random,
                               protected_sums, reseed_empty)
from repro.core.fault import FaultConfig
from repro.core.ft_gemm import ft_matmul, abft_dot
from repro.core import checksum, assignment, autotune, baselines, dmr

__all__ = [
    "KMeans", "KMeansConfig", "KMeansResult", "fit_kmeans",
    "centroid_update", "init_kmeanspp", "init_random", "protected_sums",
    "reseed_empty", "FaultConfig", "ft_matmul", "abft_dot",
    "checksum", "assignment", "autotune", "baselines", "dmr",
]
