"""FT K-means core — the paper's contribution as a composable JAX module."""
from repro.core.kmeans import (KMeans, KMeansConfig, KMeansResult, fit_kmeans,
                               init_kmeanspp, init_random)
from repro.core.fault import FaultConfig
from repro.core.ft_gemm import ft_matmul, abft_dot
from repro.core import checksum, assignment, autotune, baselines, dmr

__all__ = [
    "KMeans", "KMeansConfig", "KMeansResult", "fit_kmeans",
    "init_kmeanspp", "init_random", "FaultConfig", "ft_matmul", "abft_dot",
    "checksum", "assignment", "autotune", "baselines", "dmr",
]
