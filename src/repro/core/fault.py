"""SEU fault-injection harness (paper §II-A fault model).

Each injection flips a single bit of one element of a tensor — the model the
paper uses: "each threadblock randomly selects an element to corrupt by
flipping a single bit, either in its 32-bit float representation or 64-bit
double representation". Under the single-event-upset assumption at most one
error occurs per detection/correction interval.

Injection targets *compute results* (accumulators, products), never stored
inputs: memory errors are ECC's job per the fault model.

User-facing campaigns are configured through
``repro.api.InjectionCampaign`` on a ``FaultPolicy``; the
:class:`FaultConfig` here is the low-level descriptor those translate to
(and what ``ft_gemm``/``checksum`` consume directly).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_UINT = {jnp.dtype(jnp.float32): jnp.uint32, jnp.dtype(jnp.float64): jnp.uint64,
         jnp.dtype(jnp.bfloat16): jnp.uint16}
_NBITS = {jnp.dtype(jnp.float32): 32, jnp.dtype(jnp.float64): 64,
          jnp.dtype(jnp.bfloat16): 16}


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Describes an injection campaign.

    rate: expected number of injections per step (Bernoulli per step when
      <= 1, otherwise a fixed integer count per step).
    bit_low/bit_high: inclusive range of bit positions to flip. Defaults
      exercise high-mantissa + exponent bits (detectable range); flipping
      the sign of a denormal would be below any sane threshold and is also
      harmless to the result.
    """

    rate: float = 1.0
    bit_low: int = 20
    bit_high: int = 30
    seed: int = 0

    def enabled(self) -> bool:
        return self.rate > 0


def flip_bit(x: jax.Array, idx, bit) -> jax.Array:
    """Flip `bit` of element `idx` (flat index) of x. jit-safe."""
    dt = jnp.dtype(x.dtype)
    uint = _UINT[dt]
    flat = x.reshape(-1)
    v = flat[idx]
    as_int = jax.lax.bitcast_convert_type(v, uint)
    flipped = as_int ^ (jnp.asarray(1, uint) << jnp.asarray(bit, uint))
    out = jax.lax.bitcast_convert_type(flipped, x.dtype)
    return flat.at[idx].set(out).reshape(x.shape)


def inject(key: jax.Array, x: jax.Array, cfg: FaultConfig) -> jax.Array:
    """Inject (at most one) bit-flip into x according to cfg. jit-safe."""
    if not cfg.enabled():
        return x
    k_gate, k_idx, k_bit = jax.random.split(key, 3)
    fire = jax.random.uniform(k_gate) < jnp.minimum(cfg.rate, 1.0)
    idx = jax.random.randint(k_idx, (), 0, x.size)
    bit = jax.random.randint(k_bit, (), cfg.bit_low, cfg.bit_high + 1)
    return jnp.where(fire, flip_bit(x, idx, bit), x)


def inject_delta(key: jax.Array, x: jax.Array, cfg: FaultConfig) -> jax.Array:
    """Like inject(), but as an additive delta tensor (for in-kernel use).

    Returns a tensor that is zero everywhere except (possibly) one element
    holding the bit-flip delta; adding it to x reproduces inject(key, x).
    Useful when corruption must be applied inside a kernel accumulator.
    """
    corrupted = inject(key, x, cfg)
    return corrupted - x


def draw_tile_injection(rng, m: int, k: int, f: int, params) -> jax.Array:
    """Sample one in-kernel SEU for the fused FT kernel (campaign step).

    Picks a random tile of the (m, k, f) grid under ``params`` tiling, a
    random element of that tile, and a bit-flip-magnitude delta — the
    paper's threadblock-level injection model mapped to TPU tiles.
    ``params`` must already be clamped to the problem shape.
    """
    from repro.kernels.distance_argmin_ft import make_injection
    mp = -(-m // params.block_m)
    kp = -(-k // params.block_k)
    fp = -(-f // params.block_f)
    delta = float(rng.choice([-1.0, 1.0]) * 2.0 ** rng.integers(4, 24))
    return make_injection(int(rng.integers(mp)), int(rng.integers(kp)),
                          int(rng.integers(fp)),
                          int(rng.integers(params.block_m)),
                          int(rng.integers(params.block_k)), delta)


def host_injection_plan(cfg: FaultConfig, steps: int) -> list[Optional[tuple[int, int]]]:
    """Pre-sample a host-side plan: per step, None or (flat_idx_seed, bit)."""
    rng = np.random.default_rng(cfg.seed)
    plan: list[Optional[tuple[int, int]]] = []
    for _ in range(steps):
        if rng.uniform() < min(cfg.rate, 1.0):
            plan.append((int(rng.integers(0, 2**31 - 1)),
                         int(rng.integers(cfg.bit_low, cfg.bit_high + 1))))
        else:
            plan.append(None)
    return plan
