"""SEU fault-injection harness (paper §II-A fault model).

Each injection flips a single bit of one element of a tensor — the model the
paper uses: "each threadblock randomly selects an element to corrupt by
flipping a single bit, either in its 32-bit float representation or 64-bit
double representation". Under the single-event-upset assumption at most one
error occurs per detection/correction interval.

Injection targets *compute results* (accumulators, products), never stored
inputs: memory errors are ECC's job per the fault model.

User-facing campaigns are configured through
``repro.api.InjectionCampaign`` on a ``FaultPolicy``; the
:class:`FaultConfig` here is the low-level descriptor those translate to
(and what ``ft_gemm``/``checksum`` consume directly).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_UINT = {jnp.dtype(jnp.float32): jnp.uint32, jnp.dtype(jnp.float64): jnp.uint64,
         jnp.dtype(jnp.bfloat16): jnp.uint16}
_NBITS = {jnp.dtype(jnp.float32): 32, jnp.dtype(jnp.float64): 64,
          jnp.dtype(jnp.bfloat16): 16}


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Describes an injection campaign.

    rate: expected number of injections per step (Bernoulli per step when
      <= 1, otherwise a fixed integer count per step).
    bit_low/bit_high: inclusive range of bit positions to flip. Defaults
      exercise high-mantissa + exponent bits (detectable range); flipping
      the sign of a denormal would be below any sane threshold and is also
      harmless to the result.
    """

    rate: float = 1.0
    bit_low: int = 20
    bit_high: int = 30
    seed: int = 0

    def enabled(self) -> bool:
        return self.rate > 0


def flip_bit(x: jax.Array, idx, bit) -> jax.Array:
    """Flip `bit` of element `idx` (flat index) of x. jit-safe."""
    dt = jnp.dtype(x.dtype)
    uint = _UINT[dt]
    flat = x.reshape(-1)
    v = flat[idx]
    as_int = jax.lax.bitcast_convert_type(v, uint)
    flipped = as_int ^ (jnp.asarray(1, uint) << jnp.asarray(bit, uint))
    out = jax.lax.bitcast_convert_type(flipped, x.dtype)
    return flat.at[idx].set(out).reshape(x.shape)


def inject(key: jax.Array, x: jax.Array, cfg: FaultConfig) -> jax.Array:
    """Inject (at most one) bit-flip into x according to cfg. jit-safe."""
    if not cfg.enabled():
        return x
    k_gate, k_idx, k_bit = jax.random.split(key, 3)
    fire = jax.random.uniform(k_gate) < jnp.minimum(cfg.rate, 1.0)
    idx = jax.random.randint(k_idx, (), 0, x.size)
    bit = jax.random.randint(k_bit, (), cfg.bit_low, cfg.bit_high + 1)
    return jnp.where(fire, flip_bit(x, idx, bit), x)


def inject_delta(key: jax.Array, x: jax.Array, cfg: FaultConfig) -> jax.Array:
    """Like inject(), but as an additive delta tensor (for in-kernel use).

    Returns a tensor that is zero everywhere except (possibly) one element
    holding the bit-flip delta; adding it to x reproduces inject(key, x).
    Useful when corruption must be applied inside a kernel accumulator.
    """
    corrupted = inject(key, x, cfg)
    return corrupted - x


def planned_injections(rng, rate: float, cap: int) -> int:
    """Per-step injection count under the campaign rate semantics.

    ``rate <= 1`` is a Bernoulli draw. ``rate > 1`` is an expected count:
    ``floor(rate)`` guaranteed draws plus a Bernoulli on the fraction,
    clipped at ``cap`` — the number of independently verified intervals
    the target kernel exposes per step (§II-A: at most one SEU per
    detection/correction interval).
    """
    if rate <= 0 or cap <= 0:
        return 0
    if rate <= 1.0:
        return int(rng.uniform() < rate)
    whole = int(rate)  # analysis: allow=host-sync — rate is a host float
    n = whole + int(rng.uniform() < (rate - whole))
    return min(n, cap)


def draw_step_injection(rng, m: int, k: int, f: int, params, *,
                        rate: float,
                        targets: tuple[str, ...] = ("distance",),
                        kind: str = "assign") -> jax.Array:
    """Sample one Lloyd step's in-kernel SEU descriptor for a campaign.

    ``targets`` is the resolved interval list (see
    ``InjectionCampaign.resolved_targets``); ``kind`` selects the
    descriptor format — the assignment-only FT kernel takes the 7-slot
    distance descriptor, the one-pass FT kernel the dual-slot layout with
    an additional update-epilogue slot. Draws are assigned to *distinct*
    intervals; magnitudes (2^18..2^23) model exponent-bit flips (the
    §II-A detectable range) and sit above the bf16-scaled detection
    threshold so campaigns behave identically across compute dtypes
    (deltas below threshold are, by the same construction, below the
    harm threshold — the paper's argument for the threshold choice).
    """
    from repro.kernels import lloyd_step_ft as _llft
    if kind != "lloyd_ft":
        if planned_injections(rng, rate, 1):
            return draw_tile_injection(rng, m, k, f, params)
        from repro.kernels.distance_argmin_ft import no_injection
        return no_injection()
    n = planned_injections(rng, rate, len(targets))
    chosen = list(rng.choice(len(targets), size=n, replace=False))
    distance = update = None
    mp = -(-m // params.block_m)
    if any(targets[i] == "distance" for i in chosen):
        kp = -(-k // params.block_k)
        fp = -(-f // params.block_f)
        delta = float(rng.choice([-1.0, 1.0]) * 2.0 ** rng.integers(18, 24))
        distance = (int(rng.integers(mp)), int(rng.integers(kp)),
                    int(rng.integers(fp)), int(rng.integers(params.block_m)),
                    int(rng.integers(params.block_k)), delta)
    if any(targets[i] == "update" for i in chosen):
        delta = float(rng.choice([-1.0, 1.0]) * 2.0 ** rng.integers(18, 24))
        update = (int(rng.integers(mp)), int(rng.integers(k)),
                  int(rng.integers(f)), delta)
    return _llft.make_injection(distance=distance, update=update)


def no_step_injection(kind: str = "assign") -> jax.Array:
    """The disarmed descriptor in the format ``kind``'s kernel expects."""
    if kind == "lloyd_ft":
        from repro.kernels.lloyd_step_ft import no_injection
    else:
        from repro.kernels.distance_argmin_ft import no_injection
    return no_injection()


def draw_tile_injection(rng, m: int, k: int, f: int, params) -> jax.Array:
    """Sample one in-kernel SEU for the fused FT kernel (campaign step).

    Picks a random tile of the (m, k, f) grid under ``params`` tiling, a
    random element of that tile, and a bit-flip-magnitude delta — the
    paper's threadblock-level injection model mapped to TPU tiles.
    ``params`` must already be clamped to the problem shape. Magnitudes
    use the same 2^18..2^23 exponent-bit range as ``draw_step_injection``:
    the dtype-aware detection thresholds scale with eps(input dtype), so
    the historical 2^4 floor fell *below* the bf16/fp16 threshold — the
    SEU then corrupted the accumulator without being detected, silently
    breaking the campaign contract on low-precision assign-kind backends.
    """
    from repro.kernels.distance_argmin_ft import make_injection
    mp = -(-m // params.block_m)
    kp = -(-k // params.block_k)
    fp = -(-f // params.block_f)
    delta = float(rng.choice([-1.0, 1.0]) * 2.0 ** rng.integers(18, 24))
    return make_injection(int(rng.integers(mp)), int(rng.integers(kp)),
                          int(rng.integers(fp)),
                          int(rng.integers(params.block_m)),
                          int(rng.integers(params.block_k)), delta)


def host_injection_plan(cfg: FaultConfig, steps: int) -> list[Optional[tuple[int, int]]]:
    """Pre-sample a host-side plan: per step, None or (flat_idx_seed, bit)."""
    rng = np.random.default_rng(cfg.seed)
    plan: list[Optional[tuple[int, int]]] = []
    for _ in range(steps):
        if rng.uniform() < min(cfg.rate, 1.0):
            plan.append((int(rng.integers(0, 2**31 - 1)),
                         int(rng.integers(cfg.bit_low, cfg.bit_high + 1))))
        else:
            plan.append(None)
    return plan
