"""Kernel parameter generation + selection (paper §III-B code generation).

The paper generates ~150 CUTLASS kernels per dtype over a pruned parameter
space, keeps those that compile and run, benchmarks 64 problem sizes and
selects a per-shape winner. On TPU the "template instantiation" is a Pallas
closure specialization, but the search/selection pipeline is the same:

  1. ``parameter_space()``   — candidates under the paper's pruning rules
                               (§III-B-1): powers of two, contraction tile
                               tied to the pipeline depth, MXU-aligned tiles.
  2. ``feasible()``          — does the kernel lower (compile-time check) and
                               does the working set fit VMEM.
  3. ``score()``             — selection criterion. Two modes:
                               "model": analytical HBM-traffic/MXU-occupancy
                               model (used when the target TPU is absent —
                               this container), "measure": wall-time of the
                               real kernel (used on device; also drives the
                               CPU benchmark figures via the jnp fallback).
  4. ``AutotuneCache``       — per-shape winners, persisted as JSON: the
                               kernel-selection table the runtime consults.
                               Lives in ``repro.api.cache`` as an injectable
                               object (passed per-estimator); this module
                               keeps only the search/selection pipeline.
"""
from __future__ import annotations

import functools
import itertools
import time
import warnings
from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import (KernelParams, clamp_params, lloyd_vmem_bytes,
                               _round_up)

# TPU v5e constants (roofline/hw.py mirrors these).
MXU_FLOPS = 197e12        # bf16 peak; f32 ~ 1/2
HBM_BW = 819e9            # bytes/s
VMEM_BUDGET = 96 * 2**20  # bytes usable per core (half of 128 MiB v5e VMEM,
                          # leaving room for Mosaic's own buffers)

# Kernel kinds sharing the tile-parameter space but with distinct VMEM
# footprints and HBM-traffic profiles (winners must not cross kinds).
KINDS = ("assign", "lloyd")


def parameter_space(dtype=jnp.float32) -> list[KernelParams]:
    """Pruned candidate grid (paper rules: powers of 2; Warp.K=Threadblock.K
    maps to a single contraction tile; thread tile fixed by MXU shape)."""
    block_ms = [64, 128, 256, 512, 1024]
    block_ks = [128, 256, 512]
    block_fs = [128, 256, 512, 1024]
    out = []
    for bm, bk, bf in itertools.product(block_ms, block_ks, block_fs):
        out.append(KernelParams(block_m=bm, block_k=bk, block_f=bf))
    return out


def feasible(p: KernelParams, dtype=jnp.float32, *, kind: str = "assign",
             shape: Optional[tuple[int, int, int]] = None) -> bool:
    """VMEM fit + alignment. The lowering check happens once in tests
    (tests/test_autotune.py) — analogous to the paper's compile-and-run
    filter; here we apply the cheap structural conditions.

    The one-pass Lloyd kernel additionally keeps the whole stashed X row
    tile and its (K, F) partial-sum output block resident, so its VMEM
    model depends on the problem shape (``shape=(m, k, f)``)."""
    if p.block_m % 8 or p.block_k % 128 or p.block_f % 128:
        return False
    if kind == "lloyd" and shape is not None:
        _, k, f = shape
        return lloyd_vmem_bytes(p, k, f) <= VMEM_BUDGET
    return p.vmem_bytes() <= VMEM_BUDGET


def iteration_traffic(m: int, k: int, f: int, p: KernelParams, *,
                      pipeline: str = "one_pass",
                      dtype=jnp.float32) -> dict[str, int]:
    """Per-Lloyd-iteration HBM byte traffic, itemized by source.

    ``pipeline`` names the iteration structure (distinct from the kernel
    ``kind`` vocabulary used by selection):

    ``"two_pass"``: the seed pipeline — fused assignment kernel, then
    a separate centroid-update pass that re-reads all of X, plus the
    per-iteration re-pad/re-norm of X the seed estimator performed inside
    every kernel call.

    ``"one_pass"``: the fused ``lloyd_step`` kernel — X enters the
    kernel once per centroid tile and is never read again; the update
    costs only the per-row-tile partial sums/counts round trip of the
    tree-reduction. Padding and norms are amortized by the per-fit
    :class:`~repro.kernels.ops.DataPlan` (zero per-iteration bytes).
    """
    if pipeline not in ("one_pass", "two_pass"):
        raise ValueError(f"pipeline must be 'one_pass' or 'two_pass', "
                         f"got {pipeline!r}")
    p = clamp_params(m, k, f, p)
    b = jnp.dtype(dtype).itemsize
    mp = _round_up(m, p.block_m)
    kp = _round_up(k, p.block_k)
    fp = _round_up(f, p.block_f)
    n_ktiles = kp // p.block_k
    n_mtiles = mp // p.block_m
    t = {
        "x_read": mp * fp * n_ktiles * b,         # once per centroid tile
        "c_read": kp * fp * n_mtiles * b,         # once per sample tile
        "assign_out": mp * (b + 4),               # min-dist f32 + argmin i32
    }
    if pipeline == "two_pass":
        t["prep"] = (mp * fp + 2 * m * f) * b     # re-pad write + 2x re-read
        t["update_x_reread"] = m * f * b + m * 4  # second pass over X + labels
        t["update_out"] = (k * f + k) * b
    else:
        t["prep"] = 0
        t["update_x_reread"] = 0
        # partial blocks written by the kernel, then read + collapsed by the
        # tree-reduction into the (K, F) sums / (K,) counts
        partials = n_mtiles * (kp * fp + kp) * b
        t["update_out"] = 2 * partials + (k * f + k) * b
    t["total"] = sum(t.values())
    return t


def model_score(m: int, k: int, f: int, p: KernelParams,
                dtype=jnp.float32, kind: str = "assign") -> float:
    """Analytical time estimate (seconds) for one fused-kernel launch.

    HBM traffic: X is re-read once per centroid tile, C once per sample
    tile (the paper's §V-A-6 observation that balanced tiles minimize data
    movement); compute: 2 M K F MACs on the MXU. The kernel is pipelined,
    so time ~ max(compute, memory) + epilogue. The ``lloyd`` kind adds the
    partial-sum output traffic and the one-hot update GEMM of the fused
    epilogue.
    """
    p = clamp_params(m, k, f, p)
    bytes_per = jnp.dtype(dtype).itemsize
    mp = -(-m // p.block_m) * p.block_m
    kp = -(-k // p.block_k) * p.block_k
    fp = -(-f // p.block_f) * p.block_f
    x_reads = mp * fp * (kp // p.block_k)
    c_reads = kp * fp * (mp // p.block_m)
    hbm_bytes = (x_reads + c_reads) * bytes_per
    macs = mp * kp * fp
    if kind == "lloyd":
        # partial sums/counts blocks out + tree-reduction round trip
        partials = (mp // p.block_m) * (kp * fp + kp) * bytes_per
        hbm_bytes += 2 * partials
        macs += mp * kp * fp          # one-hot scatter GEMM in the epilogue
    hbm = hbm_bytes / HBM_BW
    peak = MXU_FLOPS if dtype == jnp.bfloat16 else MXU_FLOPS / 2
    # MXU efficiency falls off for tiles thinner than the 128x128 systolic
    # array and for padded remainders.
    util = min(p.block_k / 128.0, 1.0) * min(p.block_m / 128.0, 1.0)
    util *= (m / mp) * (k / kp) * (f / fp)
    compute = 2.0 * macs / (peak * max(util, 1e-3))
    epilogue = mp * kp * bytes_per / (HBM_BW * 16)  # VMEM-resident reduce
    return float(max(hbm, compute) + epilogue)


def measure_score(m: int, k: int, f: int, p: KernelParams, *, iters: int = 3,
                  dtype=jnp.float32, kind: str = "assign") -> float:
    """Median wall-time of the real kernel on the current backend (seconds).

    Inputs are seeded-random (all-ones invited constant folding), the
    candidate pipeline is compiled exactly once up front (naively repeating
    ``fused_assign`` re-ran its eager padding prologue every call), and
    every timed call is individually ``block_until_ready`` so candidates
    are ranked on real kernel time, not dispatch pipelining."""
    from repro.kernels.ops import fused_assign, fused_lloyd
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, f), dtype)
    c = jax.random.normal(kc, (k, f), dtype)
    p = clamp_params(m, k, f, p)
    step = fused_lloyd if kind == "lloyd" else fused_assign
    fn = jax.jit(functools.partial(step, params=p))
    jax.block_until_ready(fn(x, c))          # compile outside the timing
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, c))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def select_params(m: int, k: int, f: int, *, mode: str = "model",
                  dtype=jnp.float32, kind: str = "assign",
                  space: Optional[Iterable[KernelParams]] = None) -> KernelParams:
    """Pick the winner for one problem shape and kernel kind."""
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    best, best_s = None, float("inf")
    for p in (space or parameter_space(dtype)):
        if not feasible(p, dtype, kind=kind, shape=(m, k, f)):
            continue
        s = (model_score(m, k, f, p, dtype=dtype, kind=kind)
             if mode == "model"
             else measure_score(m, k, f, p, dtype=dtype, kind=kind))
        if s < best_s:
            best, best_s = p, s
    if best is None:
        hint = (" (the one-pass kernel keeps the stashed X row tile and "
                "its (K, F) partial-sum block VMEM-resident; use a "
                "two-pass backend for this shape)" if kind == "lloyd" else "")
        raise ValueError(f"no feasible {kind!r} kernel parameters for "
                         f"shape {(m, k, f)}: every candidate's working "
                         f"set exceeds VMEM{hint}")
    return best


# ---------------------------------------------------------------------------
# Winner table: owned by repro.api.cache.AutotuneCache (an injectable object,
# passed per-estimator). The deprecated helpers below delegate to the
# process-default cache for callers not yet migrated.
# ---------------------------------------------------------------------------


def build_table(shapes: Iterable[tuple[int, int, int]], *, mode: str = "model",
                dtype=jnp.float32, path: Optional[str] = None) -> dict:
    """Deprecated: use ``AutotuneCache(path).build(shapes, mode=...)``."""
    warnings.warn("autotune.build_table is deprecated; use "
                  "repro.api.AutotuneCache(path).build(...)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import AutotuneCache, default_cache
    cache = AutotuneCache(path) if path else default_cache()
    return cache.build(shapes, mode=mode, dtype=dtype)


def lookup_params(m: int, k: int, f: int) -> KernelParams:
    """Deprecated: use ``repro.api.AutotuneCache.lookup`` (injectable) or
    ``repro.api.default_cache()`` for the process-wide table."""
    warnings.warn("autotune.lookup_params is deprecated; use "
                  "repro.api.default_cache().lookup(m, k, f)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import default_cache
    return default_cache().lookup(m, k, f)
