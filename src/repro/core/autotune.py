"""Kernel parameter generation + selection (paper §III-B code generation).

The paper generates ~150 CUTLASS kernels per dtype over a pruned parameter
space, keeps those that compile and run, benchmarks 64 problem sizes and
selects a per-shape winner. On TPU the "template instantiation" is a Pallas
closure specialization, but the search/selection pipeline is the same:

  1. ``parameter_space()``   — candidates under the paper's pruning rules
                               (§III-B-1): powers of two, contraction tile
                               tied to the pipeline depth, MXU-aligned tiles.
  2. ``feasible()``          — does the kernel lower (compile-time check) and
                               does the working set fit VMEM.
  3. ``score()``             — selection criterion. Two modes:
                               "model": analytical HBM-traffic/MXU-occupancy
                               model (used when the target TPU is absent —
                               this container), "measure": wall-time of the
                               real kernel (used on device; also drives the
                               CPU benchmark figures via the jnp fallback).
  4. ``AutotuneCache``       — per-shape winners, persisted as JSON: the
                               kernel-selection table the runtime consults.
                               Lives in ``repro.api.cache`` as an injectable
                               object (passed per-estimator); this module
                               keeps only the search/selection pipeline.
"""
from __future__ import annotations

import itertools
import time
import warnings
from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import KernelParams, clamp_params

# TPU v5e constants (roofline/hw.py mirrors these).
MXU_FLOPS = 197e12        # bf16 peak; f32 ~ 1/2
HBM_BW = 819e9            # bytes/s
VMEM_BUDGET = 96 * 2**20  # bytes usable per core (half of 128 MiB v5e VMEM,
                          # leaving room for Mosaic's own buffers)


def parameter_space(dtype=jnp.float32) -> list[KernelParams]:
    """Pruned candidate grid (paper rules: powers of 2; Warp.K=Threadblock.K
    maps to a single contraction tile; thread tile fixed by MXU shape)."""
    block_ms = [64, 128, 256, 512, 1024]
    block_ks = [128, 256, 512]
    block_fs = [128, 256, 512, 1024]
    out = []
    for bm, bk, bf in itertools.product(block_ms, block_ks, block_fs):
        out.append(KernelParams(block_m=bm, block_k=bk, block_f=bf))
    return out


def feasible(p: KernelParams, dtype=jnp.float32) -> bool:
    """VMEM fit + alignment. The lowering check happens once in tests
    (tests/test_autotune.py) — analogous to the paper's compile-and-run
    filter; here we apply the cheap structural conditions."""
    if p.vmem_bytes() > VMEM_BUDGET:
        return False
    if p.block_m % 8 or p.block_k % 128 or p.block_f % 128:
        return False
    return True


def model_score(m: int, k: int, f: int, p: KernelParams,
                dtype=jnp.float32) -> float:
    """Analytical time estimate (seconds) for the fused kernel.

    HBM traffic: X is re-read once per centroid tile, C once per sample
    tile (the paper's §V-A-6 observation that balanced tiles minimize data
    movement); compute: 2 M K F MACs on the MXU. The kernel is pipelined,
    so time ~ max(compute, memory) + epilogue.
    """
    p = clamp_params(m, k, f, p)
    bytes_per = jnp.dtype(dtype).itemsize
    mp = -(-m // p.block_m) * p.block_m
    kp = -(-k // p.block_k) * p.block_k
    fp = -(-f // p.block_f) * p.block_f
    x_reads = mp * fp * (kp // p.block_k)
    c_reads = kp * fp * (mp // p.block_m)
    hbm = (x_reads + c_reads) * bytes_per / HBM_BW
    peak = MXU_FLOPS if dtype == jnp.bfloat16 else MXU_FLOPS / 2
    # MXU efficiency falls off for tiles thinner than the 128x128 systolic
    # array and for padded remainders.
    util = min(p.block_k / 128.0, 1.0) * min(p.block_m / 128.0, 1.0)
    util *= (m / mp) * (k / kp) * (f / fp)
    compute = 2.0 * mp * kp * fp / (peak * max(util, 1e-3))
    epilogue = mp * kp * bytes_per / (HBM_BW * 16)  # VMEM-resident reduce
    return float(max(hbm, compute) + epilogue)


def measure_score(m: int, k: int, f: int, p: KernelParams, *, iters: int = 3,
                  dtype=jnp.float32) -> float:
    """Wall-time of the fused kernel on the current backend (seconds)."""
    from repro.kernels.ops import fused_assign
    x = jnp.ones((m, f), dtype)
    c = jnp.ones((k, f), dtype)
    am, md = fused_assign(x, c, p)
    jax.block_until_ready((am, md))
    t0 = time.perf_counter()
    for _ in range(iters):
        am, md = fused_assign(x, c, p)
    jax.block_until_ready((am, md))
    return (time.perf_counter() - t0) / iters


def select_params(m: int, k: int, f: int, *, mode: str = "model",
                  dtype=jnp.float32,
                  space: Optional[Iterable[KernelParams]] = None) -> KernelParams:
    """Pick the winner for one problem shape."""
    best, best_s = None, float("inf")
    for p in (space or parameter_space(dtype)):
        if not feasible(p, dtype):
            continue
        s = (model_score if mode == "model" else measure_score)(m, k, f, p, dtype=dtype)
        if s < best_s:
            best, best_s = p, s
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Winner table: owned by repro.api.cache.AutotuneCache (an injectable object,
# passed per-estimator). The deprecated helpers below delegate to the
# process-default cache for callers not yet migrated.
# ---------------------------------------------------------------------------


def build_table(shapes: Iterable[tuple[int, int, int]], *, mode: str = "model",
                dtype=jnp.float32, path: Optional[str] = None) -> dict:
    """Deprecated: use ``AutotuneCache(path).build(shapes, mode=...)``."""
    warnings.warn("autotune.build_table is deprecated; use "
                  "repro.api.AutotuneCache(path).build(...)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import AutotuneCache, default_cache
    cache = AutotuneCache(path) if path else default_cache()
    return cache.build(shapes, mode=mode, dtype=dtype)


def lookup_params(m: int, k: int, f: int) -> KernelParams:
    """Deprecated: use ``repro.api.AutotuneCache.lookup`` (injectable) or
    ``repro.api.default_cache()`` for the process-wide table."""
    warnings.warn("autotune.lookup_params is deprecated; use "
                  "repro.api.default_cache().lookup(m, k, f)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import default_cache
    return default_cache().lookup(m, k, f)
